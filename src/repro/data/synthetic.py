"""Offline synthetic datasets shaped like the paper's benchmarks.

No internet in this environment, so F-MNIST / CIFAR-10 / KWS are generated
as class-template + structured-noise images (or MFCC grids) with the exact
input shapes and class counts of the real datasets.  The classes are
linearly separable enough for the paper's *relative* claims (optimizer
convergence order, FedOVA vs FedAvg under non-IID-l) to be measurable, which
is what the benchmarks assert.  Token streams for the LLM-scale smoke tests
come from a Zipf sampler.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.configs.paper_models import CNNConfig


class Dataset(NamedTuple):
    x: np.ndarray       # (N, H, W, C) float32
    y: np.ndarray       # (N,) int64
    n_classes: int
    name: str


def make_classification(cfg: CNNConfig, n_train: int = 4000, n_test: int = 1000,
                        seed: int = 0, noise: float = 0.35):
    """(train, test) with class-template structure at cfg.input_shape."""
    rng = np.random.default_rng(seed)
    h, w, c = cfg.input_shape
    n_cls = cfg.num_classes
    # smooth class templates: random low-frequency patterns
    freq = rng.normal(size=(n_cls, 4, 4, c))
    templates = np.stack([
        _upsample(freq[k], h, w) for k in range(n_cls)
    ])  # (n_cls, h, w, c)

    def sample(n):
        ys = rng.integers(0, n_cls, size=n)
        xs = templates[ys] + noise * rng.normal(size=(n, h, w, c))
        return xs.astype(np.float32), ys.astype(np.int64)

    xtr, ytr = sample(n_train)
    xte, yte = sample(n_test)
    return (
        Dataset(xtr, ytr, n_cls, cfg.dataset),
        Dataset(xte, yte, n_cls, cfg.dataset),
    )


def _upsample(small: np.ndarray, h: int, w: int) -> np.ndarray:
    """Bilinear-ish upsample of a (4,4,C) pattern to (h,w,C)."""
    sh, sw, c = small.shape
    yi = np.linspace(0, sh - 1, h)
    xi = np.linspace(0, sw - 1, w)
    y0 = np.floor(yi).astype(int)
    y1 = np.minimum(y0 + 1, sh - 1)
    x0 = np.floor(xi).astype(int)
    x1 = np.minimum(x0 + 1, sw - 1)
    wy = (yi - y0)[:, None, None]
    wx = (xi - x0)[None, :, None]
    a = small[y0][:, x0]
    b = small[y0][:, x1]
    cgrid = small[y1][:, x0]
    d = small[y1][:, x1]
    return (a * (1 - wy) * (1 - wx) + b * (1 - wy) * wx
            + cgrid * wy * (1 - wx) + d * wy * wx)


def zipf_tokens(n_seqs: int, seq_len: int, vocab: int, seed: int = 0) -> np.ndarray:
    """Zipfian token streams for LM smoke training."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    return rng.choice(vocab, size=(n_seqs, seq_len), p=probs).astype(np.int32)


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, rng: np.random.Generator,
            epochs: int = 1):
    """Shuffled minibatch iterator (drops ragged tail, paper-style B)."""
    n = len(x)
    bs = min(batch_size, n)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - bs + 1, bs):
            idx = order[i:i + bs]
            yield x[idx], y[idx]
