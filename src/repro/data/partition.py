"""Non-IID data partitioner (paper Sec. VI-A Remark).

"non-IID-l": each client holds exactly l distinct labels.  Implemented as in
the paper: group the training data by label, divide each label group into
(l*K)/n partitions, and assign each client l partitions with different
labels.  l = 0 (or l >= n) degrades to IID sharding.
"""
from __future__ import annotations

import numpy as np


def noniid_partition(labels: np.ndarray, num_clients: int, ell: int, n_classes: int,
                     seed: int = 0) -> list[np.ndarray]:
    """Returns a list of index arrays, one per client."""
    rng = np.random.default_rng(seed)
    if ell <= 0 or ell >= n_classes:
        idx = rng.permutation(len(labels))
        return [np.sort(part) for part in np.array_split(idx, num_clients)]

    # partitions per label group: (l*K)/n
    per_label = max(1, (ell * num_clients) // n_classes)
    shards: list[tuple[int, np.ndarray]] = []
    for c in range(n_classes):
        idx_c = np.where(labels == c)[0]
        rng.shuffle(idx_c)
        for part in np.array_split(idx_c, per_label):
            if len(part):
                shards.append((c, part))

    # deal shards so every client receives ell shards with distinct labels
    rng.shuffle(shards)
    clients: list[list[np.ndarray]] = [[] for _ in range(num_clients)]
    client_labels: list[set] = [set() for _ in range(num_clients)]
    order = list(range(num_clients))
    for c, part in shards:
        rng.shuffle(order)
        placed = False
        for k in order:  # prefer clients lacking this label and under quota
            if len(clients[k]) < ell and c not in client_labels[k]:
                clients[k].append(part)
                client_labels[k].add(c)
                placed = True
                break
        if not placed:  # fallback: least-loaded client
            k = min(order, key=lambda q: len(clients[q]))
            clients[k].append(part)
            client_labels[k].add(c)
    return [
        np.sort(np.concatenate(parts)) if parts else np.array([], np.int64)
        for parts in clients
    ]


def labels_per_client(labels: np.ndarray, partition: list[np.ndarray]) -> list[set]:
    return [set(np.unique(labels[idx]).tolist()) for idx in partition]
