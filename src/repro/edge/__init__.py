"""repro.edge — resource-constrained wireless edge runtime.

The paper's premise is *resource-constrained* FEEL: hundreds of remote
devices behind expensive uplinks.  This subsystem simulates that layer
under the existing federated loop, converting the byte counts the
``CommLedger`` already tracks into wall-clock time and energy:

  * channel.py    — Shannon-capacity uplink/downlink (per-client
                    bandwidth, per-round SNR draws, optional Rayleigh
                    fading), star and tree topologies (the two readings
                    of Theorem 3);
  * device.py     — heterogeneous compute fleet (FLOPs/s, J/FLOP, battery);
  * allocation.py — per-client resource allocation: an AllocationPolicy
                    registry whose decide(RoundState) -> RoundDecision
                    apportions a shared round bandwidth budget (and,
                    optionally, per-client upload codecs) over the
                    selected cohort — uniform (the paper's), deadline
                    straggler dropping, energy-threshold exclusion
                    (arXiv:2104.05509), capacity-proportional selection
                    the bandwidth_opt barrier-minimizing convex
                    allocation and its dual energy_opt (minimize Σ E_k
                    under a deadline, arXiv:1910.13067), channel-adaptive
                    top-k codecs;
  * scheduler.py  — back-compat shim for the PR-1 Scheduler names;
  * async_agg.py  — buffered asynchronous aggregation with
                    staleness-discounted weights (FedBuff-style);
  * events.py     — event-driven simulation clock + the deadline verdict
                    (enforce_deadlines: the runtime contract behind
                    Allocation.deadline_s — late clients are cut off at
                    the barrier, partial uploads billed but discarded);
  * runtime.py    — EdgeConfig + EdgeRuntime gluing the above under
                    ``FederatedRun`` and the vmapped simulator cohort path;
  * fleet/        — struct-of-arrays mega-scale engine: the same sync
                    round as fused array ops (vectorized policies + a
                    jitted kernel), 10⁵–10⁶-client populations;
  * scenario/     — availability churn + fault injection (the fourth
                    registry subsystem): seeded diurnal/markov/trace
                    availability processes, blackout/SNR-burst/
                    straggler/battery-gate/data-exclusion injectors, and
                    the spec-string grammar behind EdgeConfig.scenario.

Bandwidth allocation never changes WHAT is transmitted (the ledger is
ground truth); per-client codecs change bytes only through their
``wire_bytes``, and the ledger still equals the plan per client.
"""
from repro.edge.allocation import (Allocation, AllocationPolicy,
                                   AdaptiveCodecPolicy, BandwidthOptPolicy,
                                   CapacityProportionalPolicy, ClientEstimate,
                                   DeadlinePolicy, EnergyOptPolicy,
                                   EnergyThresholdPolicy, FleetDecision,
                                   FleetRoundState,
                                   RoundDecision, RoundState, UniformPolicy,
                                   make_policy)
from repro.edge.fleet import FleetEngine, FleetState
from repro.edge.async_agg import AsyncAggregator, staleness_weights
from repro.edge.channel import Channel, ChannelConfig
from repro.edge.device import DeviceConfig, DeviceFleet, flops_grad_fim, flops_local_sgd
from repro.edge.events import (DeadlineVerdict, Event, EventClock,
                               enforce_deadlines, reallocated_finish)
from repro.edge.runtime import EdgeConfig, EdgeRuntime
from repro.edge.scenario import (RoundEffects, Scenario, fault_names,
                                 make_scenario, process_names,
                                 register_fault, register_process)
from repro.edge.scheduler import (CapacityProportionalScheduler,
                                  DeadlineScheduler, EnergyThresholdScheduler,
                                  UniformScheduler, make_scheduler)

__all__ = [
    "Allocation", "AllocationPolicy", "RoundState", "RoundDecision",
    "UniformPolicy", "DeadlinePolicy", "EnergyOptPolicy",
    "EnergyThresholdPolicy",
    "CapacityProportionalPolicy", "BandwidthOptPolicy", "AdaptiveCodecPolicy",
    "make_policy",
    "AsyncAggregator", "staleness_weights",
    "Channel", "ChannelConfig",
    "DeviceConfig", "DeviceFleet", "flops_grad_fim", "flops_local_sgd",
    "DeadlineVerdict", "Event", "EventClock", "enforce_deadlines",
    "reallocated_finish",
    "EdgeConfig", "EdgeRuntime",
    "RoundEffects", "Scenario", "make_scenario", "register_process",
    "register_fault", "process_names", "fault_names",
    "FleetEngine", "FleetState", "FleetRoundState", "FleetDecision",
    "ClientEstimate",
    # legacy aliases (see edge/scheduler.py)
    "UniformScheduler", "DeadlineScheduler", "EnergyThresholdScheduler",
    "CapacityProportionalScheduler", "make_scheduler",
]
