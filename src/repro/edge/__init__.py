"""repro.edge — resource-constrained wireless edge runtime.

The paper's premise is *resource-constrained* FEEL: hundreds of remote
devices behind expensive uplinks.  This subsystem simulates that layer
under the existing federated loop, converting the byte counts the
``CommLedger`` already tracks into wall-clock time and energy:

  * channel.py   — Shannon-capacity uplink/downlink (bandwidth, per-round
                   SNR draws, optional Rayleigh fading), star and tree
                   topologies (the two readings of Theorem 3);
  * device.py    — heterogeneous compute fleet (FLOPs/s, J/FLOP, battery);
  * scheduler.py — pluggable client selection: uniform (the paper's),
                   deadline-aware straggler dropping, energy-threshold
                   data exclusion (arXiv:2104.05509), capacity-proportional
                   (arXiv:1910.13067);
  * async_agg.py — buffered asynchronous aggregation with
                   staleness-discounted weights (FedBuff-style);
  * events.py    — event-driven simulation clock;
  * runtime.py   — EdgeConfig + EdgeRuntime gluing the above under
                   ``FederatedRun`` and the vmapped simulator cohort path.

Bytes are scheduler-independent (the ledger is ground truth); only the
times and energies the runtime derives from them depend on the channel,
fleet, and scheduling policy.
"""
from repro.edge.async_agg import AsyncAggregator, staleness_weights
from repro.edge.channel import Channel, ChannelConfig
from repro.edge.device import DeviceConfig, DeviceFleet, flops_grad_fim, flops_local_sgd
from repro.edge.events import Event, EventClock
from repro.edge.runtime import EdgeConfig, EdgeRuntime
from repro.edge.scheduler import (CapacityProportionalScheduler, ClientEstimate,
                                  DeadlineScheduler, EnergyThresholdScheduler,
                                  UniformScheduler, make_scheduler)

__all__ = [
    "AsyncAggregator", "staleness_weights",
    "Channel", "ChannelConfig",
    "DeviceConfig", "DeviceFleet", "flops_grad_fim", "flops_local_sgd",
    "Event", "EventClock",
    "EdgeConfig", "EdgeRuntime",
    "ClientEstimate", "UniformScheduler", "DeadlineScheduler",
    "EnergyThresholdScheduler", "CapacityProportionalScheduler",
    "make_scheduler",
]
