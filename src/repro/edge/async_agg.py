"""Buffered asynchronous aggregation with staleness-discounted weights.

Synchronous FEEL waits for the slowest selected client every round.  The
buffered-async alternative (FedBuff-style) dispatches the cohort, then
applies a server update as soon as ``buffer_size`` client results have
arrived — stragglers keep computing and land in a *later* buffer, their
contribution discounted by how many server versions elapsed while they
were in flight:

    w_i ∝ n_i · (1 + τ_i)^(-alpha),   Σ_i w_i = 1

with τ_i = server_version_now − version the client started from.  alpha=0
recovers plain sample-count weighting; large alpha suppresses very stale
updates.  Bytes are unchanged versus sync — every dispatched client still
uploads exactly once — only the round boundaries move, which is why the
``CommLedger`` must agree between the two paths for identical cohorts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

from repro.edge.events import EventClock


def staleness_weights(n_samples, staleness, alpha: float = 0.5) -> np.ndarray:
    """Normalized aggregation weights n_i·(1+τ_i)^(−alpha); sums to 1."""
    n = np.asarray(n_samples, dtype=np.float64)
    tau = np.asarray(staleness, dtype=np.float64)
    if n.size == 0:
        return np.zeros(0)
    w = n * np.power(1.0 + np.maximum(tau, 0.0), -float(alpha))
    s = w.sum()
    if s <= 0:
        return np.full(n.shape, 1.0 / n.size)
    return w / s


@dataclass
class _InFlight:
    client: int
    finish_time: float
    version: int          # server version the client computed against
    n_samples: float
    payload: Any


class AsyncAggregator:
    """Orders in-flight client results by completion time and flushes them
    in buffers of ``buffer_size``; tracks the server version for staleness.

    The caller dispatches work with ``submit`` (one per uploading client)
    and drains with ``pop_buffer``, which advances the shared clock to the
    arrival time of the last update in the buffer and returns the buffer
    with its staleness-discounted weights."""

    def __init__(self, clock: EventClock, buffer_size: int = 1,
                 alpha: float = 0.5, tracer=None):
        self.clock = clock
        self.buffer_size = max(1, int(buffer_size))
        self.alpha = float(alpha)
        self.version = 0
        # explicit counter: the shared clock may carry events other than
        # client completions, so len(clock) over-counts pending uploads
        self._in_flight = 0
        if tracer is None:
            from repro.obs.trace import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer

    def submit(self, client: int, delay_s: float, n_samples: float,
               payload: Any) -> None:
        self.clock.push_after(
            delay_s, kind="client_done", client=int(client),
            payload=_InFlight(int(client), self.clock.now + float(delay_s),
                              self.version, float(n_samples), payload))
        self._in_flight += 1

    @property
    def in_flight(self) -> int:
        return self._in_flight

    def pop_buffer(self, size: Optional[int] = None) -> tuple[list, np.ndarray]:
        """Pop the next ``size`` completions (default buffer_size), advance
        the clock past them, bump the server version, and return
        (entries, weights) with weights summing to 1."""
        size = self.buffer_size if size is None else int(size)
        entries: list[_InFlight] = []
        while len(entries) < size:
            # stop once no completions remain in flight: the heap may
            # still hold other kinds (e.g. deadline-expiry markers for
            # dropped uploads) whose — possibly far-future — times must
            # not drag the clock forward when nothing is arriving
            if self._in_flight - len(entries) <= 0:
                break
            ev = self.clock.pop()
            if ev is None:
                break
            if ev.kind != "client_done":
                continue
            entries.append(ev.payload)
        self._in_flight -= len(entries)
        if not entries:
            return [], np.zeros(0)
        stale = [self.version - e.version for e in entries]
        w = staleness_weights([e.n_samples for e in entries], stale, self.alpha)
        self.version += 1
        if self.tracer.enabled:
            from repro.obs import trace as _t
            for e, tau in zip(entries, stale, strict=True):
                self.tracer.event(_t.LAND, _t.CAT_ASYNC, e.finish_time,
                                  client=e.client, staleness=int(tau),
                                  version=self.version)
                self.tracer.metrics.histogram("async_staleness").observe(tau)
        return entries, w
