"""EdgeConfig + EdgeRuntime: the glue under ``FederatedRun``.

``EdgeConfig`` is an optional field on ``FedConfig``; when present, the
federated loop routes client selection through a scheduling policy and
converts every round's (already ledger-counted) bytes plus the client
compute work into simulated wall-clock time and energy:

  sync round   wall = t_downlink + max_k t_comp,k + t_agg(topology)
  async round  wall = until the aggregation buffer fills (stragglers
                      land in later buffers, staleness-discounted)

The runtime never changes WHAT is transmitted — `CommLedger` byte counts
are scheduler-independent — only WHO transmits and WHEN it lands.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.edge.async_agg import AsyncAggregator
from repro.edge.channel import Channel, ChannelConfig
from repro.edge.device import DeviceConfig, DeviceFleet
from repro.edge.events import EventClock
from repro.edge.scheduler import ClientEstimate, make_scheduler


@dataclass(frozen=True)
class EdgeConfig:
    """Knobs for the simulated wireless edge (all times seconds, energies
    joules).  ``scheduler`` ∈ {uniform, deadline, energy_threshold,
    capacity_proportional}; ``mode`` ∈ {sync, async}."""
    channel: ChannelConfig = field(default_factory=ChannelConfig)
    device: DeviceConfig = field(default_factory=DeviceConfig)
    scheduler: str = "uniform"
    deadline_s: float = 1.0              # deadline policy
    min_clients: int = 1
    battery_floor_j: float = 0.0         # energy_threshold policy
    round_budget_j: float = float("inf")
    mode: str = "sync"
    buffer_size: int = 0                 # async: 0 -> ceil(cohort/2)
    staleness_alpha: float = 0.5         # async: (1+τ)^-alpha discount
    seed: int = 0


class EdgeRuntime:
    """Mutable per-run edge state: channel fading, fleet batteries, the
    simulation clock, and (in async mode) the in-flight buffer."""

    def __init__(self, cfg: EdgeConfig, num_clients: int, seed: int = 0):
        self.cfg = cfg
        self.num_clients = num_clients
        s = seed + cfg.seed
        self.channel = Channel(cfg.channel, num_clients, seed=s + 1)
        self.fleet = DeviceFleet(cfg.device, num_clients, seed=s + 2)
        self.rng = np.random.default_rng(s + 3)
        self.clock = EventClock()
        self.scheduler = make_scheduler(
            cfg.scheduler, deadline_s=cfg.deadline_s,
            min_clients=cfg.min_clients, battery_floor_j=cfg.battery_floor_j,
            round_budget_j=cfg.round_budget_j)
        self.async_agg: Optional[AsyncAggregator] = None
        if cfg.mode == "async":
            # buffer_size 0 = auto: half the dispatched cohort, resolved at
            # the first dispatch (see dispatch_async)
            self.async_agg = AsyncAggregator(
                self.clock, buffer_size=max(cfg.buffer_size, 1),
                alpha=cfg.staleness_alpha)
        self.busy: set[int] = set()      # async: clients with work in flight
        self._buffer_resolved = False    # async auto-buffer picked yet?
        self.energy_j = 0.0
        self.dropped_total = 0
        self.history: list[dict] = []

    # ------------------------------------------------------------------
    def estimate(self, clients, up_bytes: float, flops) -> ClientEstimate:
        """Predicted per-client round cost.  ``flops`` is scalar or (n,)
        aligned with ``clients`` (local work scales with |D_k|)."""
        c = np.asarray(clients, dtype=int)
        fl = np.broadcast_to(np.asarray(flops, dtype=float), c.shape)
        t_comp = fl / np.maximum(self.fleet.flops_per_s[c], 1.0)
        t_up = self.channel.uplink_time_s(up_bytes, c)
        e_comp = fl * self.fleet.cfg.joules_per_flop
        e_tx = self.channel.uplink_energy_j(up_bytes, c)
        return ClientEstimate(clients=c, time_s=t_comp + t_up,
                              energy_j=e_comp + e_tx,
                              battery_j=self.fleet.battery_j[c].copy())

    def select(self, k: int, eligible, up_bytes: float, flops
               ) -> tuple[list[int], ClientEstimate]:
        """Start a round: re-draw fading, filter dead clients, run the
        scheduling policy.  Returns (cohort, estimates for the cohort)."""
        self.channel.sample()
        alive = self.fleet.alive(np.asarray(eligible, dtype=int))
        if alive.size == 0:
            return [], ClientEstimate(np.zeros(0, int), np.zeros(0),
                                      np.zeros(0), np.zeros(0))
        fl = np.broadcast_to(np.asarray(flops, dtype=float),
                             np.asarray(eligible).shape)
        keep = np.isin(np.asarray(eligible, dtype=int), alive)
        est = self.estimate(np.asarray(eligible, dtype=int)[keep],
                            up_bytes, fl[keep])
        selected, dropped = self.scheduler.select(k, est, self.rng)
        self.dropped_total += len(dropped)
        return selected, est.for_ids(selected)

    # ------------------------------------------------------------------
    def finish_round_sync(self, est_sel: ClientEstimate, up_bytes: float,
                          down_bytes: float, aggregatable: bool = True,
                          nonagg_bytes: Optional[float] = None) -> dict:
        """Advance the clock over a synchronous round and drain batteries.

        star: barrier at the slowest client's compute+uplink finish.
        tree: compute barrier, then the aggregation phase (log2(τ) hops
        for summable payloads, serialized root link otherwise).

        ``nonagg_bytes`` carves that many of ``up_bytes`` out as
        non-aggregatable (mixed payloads, e.g. FedDANE's gradient + model
        phases); when given it overrides ``aggregatable``."""
        t_down = self.channel.downlink_time_s(down_bytes)
        c = est_sel.clients
        if nonagg_bytes is None:
            agg, nonagg = ((up_bytes, 0.0) if aggregatable
                           else (0.0, up_bytes))
        else:
            nonagg = min(float(nonagg_bytes), float(up_bytes))
            agg = float(up_bytes) - nonagg
        if c.size == 0:
            # empty cohort: nothing is broadcast or transmitted — the
            # clock must agree with the ledger's zero-byte round
            return self._record(0.0, 0.0, c)
        if self.channel.cfg.topology == "tree":
            fl_t = est_sel.time_s - self.channel.uplink_time_s(up_bytes, c)
            t_round = float(np.max(fl_t)) + self.channel.comm_round_time_split(
                agg, nonagg, c)
        else:
            # per-client completions in parallel subchannels, then the
            # shared server slice drains the cohort's payloads
            t_round = max(self.clock.round_time(est_sel.time_s),
                          self.channel.comm_round_time_split(agg, nonagg, c))
        self.clock.advance(t_down + t_round)
        # synchronous barrier: a client that finishes early sits idle until
        # the round closes, draining idle_power_w the whole wait
        idle_s = np.maximum(t_round - est_sel.time_s, 0.0)
        spend_j = est_sel.energy_j + self.fleet.cfg.idle_power_w * idle_s
        e = float(spend_j.sum())
        self.fleet.spend(c, spend_j)
        return self._record(t_down + t_round, e, c)

    def dispatch_async(self, est_sel: ClientEstimate, n_samples, payloads,
                       down_bytes: float) -> None:
        """Submit the cohort's results into the in-flight buffer (energy is
        spent at dispatch — the client does the work regardless of when
        its update lands)."""
        assert self.async_agg is not None, "EdgeConfig.mode != 'async'"
        if est_sel.clients.size == 0:
            return  # empty cohort: nothing broadcast, nothing in flight
        if self.cfg.buffer_size == 0 and not self._buffer_resolved:
            self.async_agg.buffer_size = max(1, (est_sel.clients.size + 1) // 2)
            self._buffer_resolved = True
        self.clock.advance(self.channel.downlink_time_s(down_bytes))
        self.fleet.spend(est_sel.clients, est_sel.energy_j)
        self.energy_j += float(est_sel.energy_j.sum())
        for i, cl in enumerate(est_sel.clients):
            self.busy.add(int(cl))
            self.async_agg.submit(int(cl), float(est_sel.time_s[i]),
                                  float(np.asarray(n_samples)[i]), payloads[i])

    def pop_async_buffer(self):
        """Drain the next buffer; advances the clock to its last arrival.
        Returns (entries, staleness weights summing to 1)."""
        assert self.async_agg is not None
        t0 = self.clock.now
        entries, w = self.async_agg.pop_buffer()
        for e in entries:
            self.busy.discard(e.client)
        self._record(self.clock.now - t0, 0.0,
                     np.asarray([e.client for e in entries], int))
        return entries, w

    # ------------------------------------------------------------------
    def _record(self, wall_s: float, energy_j: float, clients) -> dict:
        self.energy_j += energy_j
        rec = {"wall_s": float(wall_s), "clock_s": self.clock.now,
               "energy_j": self.energy_j, "cohort": len(clients)}
        self.history.append(rec)
        return rec

    def summary(self) -> dict:
        return {
            "wall_clock_s": self.clock.now,
            "energy_j": self.energy_j,
            "rounds": len(self.history),
            "dropped_total": self.dropped_total,
            "depleted_clients": int((self.fleet.battery_j <= 0).sum()),
            "in_flight": 0 if self.async_agg is None else self.async_agg.in_flight,
        }
