"""EdgeConfig + EdgeRuntime: the glue under ``FederatedRun``.

``EdgeConfig`` is an optional field on ``FedConfig``; when present, the
federated loop routes client selection AND per-client resource
allocation through an :class:`repro.edge.allocation.AllocationPolicy`
and converts every round's (already ledger-counted) bytes plus the
client compute work into simulated wall-clock time and energy:

  sync round   wall = t_downlink + max_k t_comp,k + t_agg(topology)
  async round  wall = until the aggregation buffer fills (stragglers
                      land in later buffers, staleness-discounted)

Each round the policy sees a :class:`RoundState` (eligible clients with
cost estimates under a nominal equal split of ``bandwidth_budget_hz``)
and returns a :class:`RoundDecision`: per selected client an uplink
subchannel width drawn from the shared budget and, optionally, a
per-client upload codec.  Bandwidth-only policies never change WHAT is
transmitted — `CommLedger` byte counts are allocation-independent, only
WHO transmits, WHEN it lands, and HOW FAST it crosses the air change;
per-client codecs change bytes only through their ``wire_bytes``, and
the ledger still equals the plan per client.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.edge.allocation import (ClientEstimate, FleetDecision,
                                   FleetRoundState, RoundDecision, RoundState,
                                   make_policy)
from repro.edge.async_agg import AsyncAggregator
from repro.edge.channel import Channel, ChannelConfig
from repro.edge.device import DeviceConfig, DeviceFleet
from repro.edge.events import (DEADLINE_EXPIRED, DeadlineVerdict, EventClock,
                               enforce_deadlines, reallocated_finish)
from repro.edge.scenario import RoundEffects, Scenario, make_scenario
from repro.obs import trace as obs
from repro.obs.metrics import reason_key


@dataclass(frozen=True)
class EdgeConfig:
    """Knobs for the simulated wireless edge (all times seconds, energies
    joules).  ``scheduler`` names the allocation policy (the legacy field
    name is kept): uniform | deadline | energy_threshold |
    capacity_proportional | bandwidth_opt | energy_opt | adaptive_codec,
    or any registered ``repro.edge.allocation`` name;
    ``mode`` ∈ {sync, async}.

    ``bandwidth_budget_hz`` is the shared round uplink budget every
    policy apportions; 0 (default) resolves to ``k × channel.bandwidth_hz``
    — the equal-split policies then reproduce the fixed-subchannel
    behavior exactly at full cohort."""
    channel: ChannelConfig = field(default_factory=ChannelConfig)
    device: DeviceConfig = field(default_factory=DeviceConfig)
    scheduler: str = "uniform"           # allocation-policy name
    bandwidth_budget_hz: float = 0.0     # 0 -> k * channel.bandwidth_hz
    deadline_s: float = 1.0              # deadline / energy_opt policies
    min_clients: int = 1
    # runtime deadline enforcement: Allocation.deadline_s is a contract —
    # a client whose realized finish exceeds min(its grant,
    # enforce_deadline_s) is cut off at the barrier (upload discarded,
    # only on-air bytes billed).  enforce_deadline_s (inf = off) is a
    # hard per-round cap applied to EVERY client regardless of policy;
    # deadline_tolerance_s is the slack before a finish counts as late
    # (absorbs predicted-vs-realized float jitter — it widens admission,
    # never the billing cutoff).
    enforce_deadline_s: float = float("inf")
    deadline_tolerance_s: float = 1e-9
    battery_floor_j: float = 0.0         # energy_threshold policy
    round_budget_j: float = float("inf")
    adaptive_ratio: float = 0.25         # adaptive_codec: top-k ratio at the
    adaptive_ratio_floor: float = 0.02   # cohort-median rate, and its floor
    mode: str = "sync"
    buffer_size: int = 0                 # async: 0 -> ceil(cohort/2)
    staleness_alpha: float = 0.5         # async: (1+τ)^-alpha discount
    seed: int = 0
    # fleet fast path (repro.edge.fleet): run the sync hot path as array
    # ops over the population instead of per-client dicts.  "auto"
    # engages it when the population reaches fleet_threshold (and the
    # policy has a vectorized form; sync mode only — the async tail
    # keeps the EventClock/dict path).  fleet_backend "exact" uses the
    # shared vectorized-numpy cores (bit-identical to the dict path);
    # "jit" the x64 lax kernels (equal up to float reassociation).
    fleet: str = "auto"                  # "auto" | "on" | "off"
    fleet_threshold: int = 4096          # auto: engage at population >= this
    fleet_backend: str = "exact"         # "exact" | "jit"
    # fleet rounds keep tracing O(summary): per-client spans/events are
    # emitted only while the cohort fits this cap (the chrome exporter's
    # top_k_clients bounds the file the same way)
    trace_top_k_clients: int = 64
    # scenario: availability churn + fault injection, a
    # repro.edge.scenario spec string (e.g. "diurnal:period=600,amp=0.4"
    # or "markov:p_drop=0.2|snr_burst:prob=0.3,scale=0.25"); None keeps
    # the static always-reachable fleet.  The scenario draws from its
    # own seeded stream (seed + cfg.seed + 4), so enabling one never
    # perturbs the channel/fleet/policy draws of an existing replay.
    scenario: Optional[str] = None
    # mid-round re-allocation: when enforce_deadlines cuts a straggler,
    # re-offer its granted width to the surviving uploaders still on the
    # air (pro rata, piecewise-constant in time) — the drop set, tx
    # fractions and billing are unchanged, only the realized barrier
    # shrinks.  Sync mode; opt-in.
    reallocate: bool = False

    def __post_init__(self):
        if self.fleet not in ("auto", "on", "off"):
            raise ValueError(f"EdgeConfig.fleet must be 'auto', 'on' or "
                             f"'off', got {self.fleet!r}")
        if self.fleet_backend not in ("exact", "jit"):
            raise ValueError(f"EdgeConfig.fleet_backend must be 'exact' or "
                             f"'jit', got {self.fleet_backend!r}")


class EdgeRuntime:
    """Mutable per-run edge state: channel fading, fleet batteries, the
    simulation clock, and (in async mode) the in-flight buffer."""

    def __init__(self, cfg: EdgeConfig, num_clients: int, seed: int = 0,
                 tracer=None):
        self.cfg = cfg
        self.num_clients = num_clients
        # obs: spans/events/metrics go here; the shared no-op default
        # keeps the untraced hot path free (one attribute check per site)
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        s = seed + cfg.seed
        self.channel = Channel(cfg.channel, num_clients, seed=s + 1)
        self.fleet = DeviceFleet(cfg.device, num_clients, seed=s + 2)
        self.rng = np.random.default_rng(s + 3)
        self.scenario: Optional[Scenario] = (
            make_scenario(cfg.scenario, num_clients, seed=s + 4)
            if cfg.scenario else None)
        self._effects: Optional[RoundEffects] = None  # this round's scenario
        self.clock = EventClock()
        # make_policy drops the knobs a policy does not accept, so every
        # EdgeConfig knob can ride along unconditionally
        self.policy = make_policy(
            cfg.scheduler, deadline_s=cfg.deadline_s,
            min_clients=cfg.min_clients, battery_floor_j=cfg.battery_floor_j,
            round_budget_j=cfg.round_budget_j, ratio=cfg.adaptive_ratio,
            ratio_floor=cfg.adaptive_ratio_floor)
        self.async_agg: Optional[AsyncAggregator] = None
        if cfg.mode == "async":
            # buffer_size 0 = auto: half the dispatched cohort, resolved at
            # the first dispatch (see dispatch_async)
            self.async_agg = AsyncAggregator(
                self.clock, buffer_size=max(cfg.buffer_size, 1),
                alpha=cfg.staleness_alpha, tracer=self.tracer)
        self.busy: set[int] = set()      # async: clients with work in flight
        self._held_hz: dict[int, float] = {}  # async: spectrum still on the
                                              # air from earlier dispatches
        self._expiry: dict[int, float] = {}   # async: client -> clock time a
                                              # busted grant lapses (spectrum
                                              # + busy released then)
        self._expired_unrecorded = 0     # async: grants that lapsed outside
                                         # a pop (decide-time release), still
                                         # owed to a history record
        self._buffer_resolved = False    # async auto-buffer picked yet?
        self.energy_j = 0.0
        self.dropped_total = 0           # policy exclusions (a priori)
        self.deadline_dropped_total = 0  # runtime cutoffs (at the barrier)
        self.unavailable_total = 0       # scenario: never answered the round
        self.realloc_rounds = 0          # rounds where freed width re-landed
        # breakdowns for summary(): why clients never landed (exclusion
        # reason buckets + runtime "deadline" cutoffs), and where the
        # simulated seconds went — maintained unconditionally (cheap),
        # mirrored into tracer metrics when tracing is on
        self.drop_reasons: dict[str, int] = {}
        self.phase_s = {"downlink": 0.0, "barrier": 0.0, "drain": 0.0}
        self.history: list[dict] = []
        self.decisions: list[RoundDecision] = []
        # one verdict per decision (None when no finite deadline applies);
        # _verdict is the pending one finish_round_sync / dispatch_async
        # consumes for the in-progress round
        self.verdicts: list[Optional[DeadlineVerdict]] = []
        self._verdict: Optional[DeadlineVerdict] = None
        self._fleet_round = False   # last commit used the fleet fast path
                                    # (caps per-client tracing to
                                    # cfg.trace_top_k_clients)

    # ------------------------------------------------------------------
    def fleet_active(self) -> bool:
        """Whether rounds run on the struct-of-arrays fast path: enabled
        by cfg.fleet ("on", or "auto" once the population reaches
        fleet_threshold), sync mode only (the async tail keeps the
        EventClock/dict path), and only for policies with a vectorized
        form — others silently fall back to the scalar path."""
        cfg = self.cfg
        if cfg.mode != "sync" or cfg.fleet == "off":
            return False
        if cfg.fleet == "auto" and self.num_clients < cfg.fleet_threshold:
            return False
        return bool(getattr(self.policy, "vectorized", False))

    # ------------------------------------------------------------------
    def budget_hz(self, k: int) -> float:
        """The shared round bandwidth budget (0 = auto: k subchannels).
        In async mode, spectrum still held by in-flight uploads from
        earlier dispatches is subtracted — a straggler keeps its granted
        subchannel until its payload lands, so a new cohort can only be
        carved from what is actually free (the pool is never
        oversubscribed; with the auto budget and equal splits this
        reproduces the fixed-subchannel model exactly)."""
        if self.cfg.bandwidth_budget_hz > 0:
            total = float(self.cfg.bandwidth_budget_hz)
        else:
            total = float(max(k, 1)) * self.channel.cfg.bandwidth_hz
        return max(total - sum(self._held_hz.values()), 0.0)

    def estimate(self, clients, up_bytes, flops) -> ClientEstimate:
        """Predicted per-client round cost at the channel's CURRENT
        per-client rates.  ``up_bytes`` and ``flops`` are scalars or (n,)
        arrays aligned with ``clients`` (per-client codecs / |D_k|)."""
        c = np.asarray(clients, dtype=int)
        fl = np.broadcast_to(np.asarray(flops, dtype=float), c.shape)
        t_comp = fl / np.maximum(self.fleet.flops_per_s[c], 1.0)
        t_up = self.channel.uplink_time_s(up_bytes, c)
        e_comp = fl * self.fleet.cfg.joules_per_flop
        e_tx = self.channel.uplink_energy_j(up_bytes, c)
        return ClientEstimate(clients=c, time_s=t_comp + t_up,
                              energy_j=e_comp + e_tx,
                              battery_j=self.fleet.battery_j[c].copy())

    def _empty_est(self) -> ClientEstimate:
        return ClientEstimate(np.zeros(0, int), np.zeros(0), np.zeros(0),
                              np.zeros(0))

    def _round_state(self, k: int, clients: np.ndarray, wire_fn, flops,
                     summable: bool, codec=None, payload_mult=None
                     ) -> RoundState:
        """Nominal equal split of the budget -> estimates -> RoundState."""
        budget = self.budget_hz(k)
        self.channel.set_bandwidth(clients, budget / max(k, 1))
        agg0, nonagg0 = wire_fn(None)
        mult = (np.ones(clients.shape) if payload_mult is None
                else np.asarray(payload_mult, dtype=float))
        fl = np.broadcast_to(np.asarray(flops, dtype=float), clients.shape)
        est = self.estimate(clients, (agg0 + nonagg0) * mult, fl)
        t_comp = fl / np.maximum(self.fleet.flops_per_s[clients], 1.0)
        return RoundState(
            k=k, est=est, t_comp_s=t_comp,
            spectral_eff=self.channel.spectral_efficiency(clients),
            budget_hz=budget, rng=self.rng, codec=codec, summable=summable,
            wire_fn=wire_fn, payload_mult=payload_mult)

    def _apply(self, decision: RoundDecision, state: RoundState, wire_fn,
               flops) -> ClientEstimate:
        """Commit a decision: per-client subchannel widths into the
        channel, re-estimate the selected cohort at its allocated rates
        and per-client wire bytes, then judge the realized finishes
        against the granted deadlines (``_enforce``).  ``flops`` aligns
        with ``state.est.clients``."""
        self._fleet_round = False
        self.decisions.append(decision)
        self.dropped_total += len(decision.excluded)
        rid = len(self.decisions) - 1
        for reason in decision.excluded.values():
            key = f"excluded:{reason_key(reason)}"
            self.drop_reasons[key] = self.drop_reasons.get(key, 0) + 1
        tr = self.tracer
        if tr.enabled:
            for _cid, reason in decision.excluded.items():
                tr.metrics.counter("excluded_total").inc(
                    1, reason=reason_key(reason), policy=self.policy.name)
            for cid, a in decision.allocations.items():
                tr.event(obs.ALLOCATE, obs.CAT_CLIENT, self.clock.now,
                         round_id=rid, client=int(cid),
                         bandwidth_hz=float(a.bandwidth_hz),
                         deadline_s=(float(a.deadline_s)
                                     if np.isfinite(a.deadline_s) else None),
                         codec=(None if a.codec is None else a.codec.spec()))
        sel = decision.selected
        if not sel:
            self.verdicts.append(None)
            self._verdict = None
            return self._empty_est()
        pos = {int(c): j for j, c in enumerate(state.est.clients)}
        missing = [int(i) for i in sel if int(i) not in pos]
        if missing:
            raise ValueError(
                f"allocation policy {self.policy.name!r} selected client "
                f"ids {missing} outside the round's eligible set of "
                f"{len(state.est.clients)} clients")
        self.channel.set_bandwidth(sel, decision.bandwidth())
        mult = state.mult()
        up = np.asarray([sum(wire_fn(decision.codec_for(i)))
                         * mult[pos[int(i)]] for i in sel], dtype=float)
        fl_sel = np.asarray([flops[pos[int(i)]] for i in sel], dtype=float)
        fl_sel = self._realized_faults(sel, fl_sel, decision.bandwidth())
        est_sel = self.estimate(sel, up, fl_sel)
        self._enforce(decision, est_sel, fl_sel)
        return est_sel

    def _enforce(self, decision: RoundDecision, est_sel: ClientEstimate,
                 fl_sel: np.ndarray) -> None:
        """Judge the allocated cohort's REALIZED finishes (compute +
        uplink at the granted widths, this round's channel draw) against
        the effective per-client deadlines: min(the policy's grant,
        cfg.enforce_deadline_s).  Late clients are marked dropped on the
        decision with a reason; the verdict (drop mask + on-air byte
        fractions) is held for finish_round_sync / dispatch_async."""
        c = est_sel.clients
        grants = np.asarray([decision.allocations[int(i)].deadline_s
                             for i in c], dtype=float)
        d_eff = np.minimum(grants, self.cfg.enforce_deadline_s)
        if not np.isfinite(d_eff).any():
            self.verdicts.append(None)
            self._verdict = None
            return
        t_comp = fl_sel / np.maximum(self.fleet.flops_per_s[c], 1.0)
        verdict = enforce_deadlines(c, est_sel.time_s, t_comp, d_eff,
                                    self.cfg.deadline_tolerance_s,
                                    tracer=self.tracer, t0=self.clock.now,
                                    round_id=len(self.decisions) - 1)
        decision.dropped.update(verdict.reasons())
        self._maybe_reallocate(
            est_sel, verdict,
            [decision.allocations[int(i)].bandwidth_hz for i in c], d_eff)
        self.deadline_dropped_total += verdict.n_dropped
        if verdict.n_dropped:
            self.drop_reasons["deadline_cutoff"] = (
                self.drop_reasons.get("deadline_cutoff", 0)
                + verdict.n_dropped)
            if self.tracer.enabled:
                self.tracer.metrics.counter("drops_total").inc(
                    verdict.n_dropped, reason="deadline",
                    policy=self.policy.name)
        self.verdicts.append(verdict)
        self._verdict = verdict

    def _fleet_state(self, k: int, clients: np.ndarray, wire_fn, fl,
                     payload_mult=None) -> tuple[FleetRoundState, float]:
        """The struct-of-arrays twin of :meth:`_round_state`: identical
        channel writes and float ops, no per-client dicts and no eligible-
        set estimate (the vectorized policies never consult it)."""
        budget = self.budget_hz(k)
        self.channel.set_bandwidth(clients, budget / max(k, 1))
        agg0, nonagg0 = wire_fn(None)
        t_comp = fl / np.maximum(self.fleet.flops_per_s[clients], 1.0)
        fstate = FleetRoundState(
            k=k, ids=clients, t_comp_s=t_comp,
            spectral_eff=self.channel.spectral_efficiency(clients),
            budget_hz=budget, rng=self.rng, up_bits=8.0 * (agg0 + nonagg0),
            payload_mult=payload_mult, backend=self.cfg.fleet_backend)
        return fstate, agg0 + nonagg0

    def _decide_fleet(self, k: int, clients: np.ndarray, wire_fn, fl,
                      payload_mult=None
                      ) -> tuple[FleetDecision, ClientEstimate]:
        fstate, tot_bytes = self._fleet_state(k, clients, wire_fn, fl,
                                              payload_mult=payload_mult)
        decision = self.policy.decide_vectorized(fstate)
        assert decision is not None, \
            f"policy {self.policy.name!r} advertises vectorized=True but " \
            f"decide_vectorized returned None"
        decision.validate()
        est_sel = self._commit_fleet(decision, fstate, tot_bytes, fl)
        return decision, est_sel

    def _commit_fleet(self, decision: FleetDecision,
                      fstate: FleetRoundState, tot_bytes: float,
                      fl: np.ndarray) -> ClientEstimate:
        """The fleet twin of :meth:`_apply` + :meth:`_enforce`: identical
        bookkeeping and float ops (realized estimate at granted widths,
        deadline verdict), array-shaped.  Tracing is summary-level past
        ``cfg.trace_top_k_clients`` — counters stay exact, per-client
        events are skipped — so a traced fleet round stays O(cohort) in
        metrics and O(top-k) in span volume."""
        self._fleet_round = True
        self.decisions.append(decision)
        self.dropped_total += decision.n_excluded
        rid = len(self.decisions) - 1
        if decision.n_excluded:
            key = f"excluded:{decision.excluded_bucket or 'policy'}"
            self.drop_reasons[key] = (self.drop_reasons.get(key, 0)
                                      + decision.n_excluded)
        tr = self.tracer
        trace_clients = (tr.enabled and decision.n_selected
                         <= self.cfg.trace_top_k_clients)
        if tr.enabled:
            if decision.n_excluded:
                tr.metrics.counter("excluded_total").inc(
                    decision.n_excluded,
                    reason=decision.excluded_bucket or "policy",
                    policy=self.policy.name)
            if trace_clients:
                for cid, w, d in zip(decision.ids,
                                     decision.bandwidth_hz_arr,
                                     decision.deadline_s_arr,
                                     strict=True):
                    tr.event(obs.ALLOCATE, obs.CAT_CLIENT, self.clock.now,
                             round_id=rid, client=int(cid),
                             bandwidth_hz=float(w),
                             deadline_s=(float(d) if np.isfinite(d)
                                         else None),
                             codec=None)
            elif decision.n_selected:
                tr.event(obs.ALLOCATE, obs.CAT_ROUND, self.clock.now,
                         round_id=rid, cohort=decision.n_selected,
                         total_hz=decision.total_bandwidth_hz(),
                         min_hz=float(decision.bandwidth_hz_arr.min()),
                         max_hz=float(decision.bandwidth_hz_arr.max()))
        if decision.n_selected == 0:
            self.verdicts.append(None)
            self._verdict = None
            return self._empty_est()
        sel = decision.positions
        self.channel.set_bandwidth(decision.ids, decision.bandwidth_hz_arr)
        up = tot_bytes * fstate.mult()[sel]
        fl_sel = self._realized_faults(decision.ids, fl[sel],
                                       decision.bandwidth_hz_arr)
        est_sel = self.estimate(decision.ids, up, fl_sel)
        d_eff = np.minimum(decision.deadline_s_arr,
                           self.cfg.enforce_deadline_s)
        if not np.isfinite(d_eff).any():
            self.verdicts.append(None)
            self._verdict = None
            return est_sel
        t_comp = fl_sel / np.maximum(
            self.fleet.flops_per_s[decision.ids], 1.0)
        verdict = enforce_deadlines(
            decision.ids, est_sel.time_s, t_comp, d_eff,
            self.cfg.deadline_tolerance_s,
            tracer=(self.tracer if trace_clients else None),
            t0=self.clock.now, round_id=rid)
        decision.set_verdict(verdict)
        self._maybe_reallocate(est_sel, verdict, decision.bandwidth_hz_arr,
                               d_eff)
        self.deadline_dropped_total += verdict.n_dropped
        if verdict.n_dropped:
            self.drop_reasons["deadline_cutoff"] = (
                self.drop_reasons.get("deadline_cutoff", 0)
                + verdict.n_dropped)
            if tr.enabled:
                tr.metrics.counter("drops_total").inc(
                    verdict.n_dropped, reason="deadline",
                    policy=self.policy.name)
        self.verdicts.append(verdict)
        self._verdict = verdict
        return est_sel

    # -- scenario (repro.edge.scenario): churn, faults, re-allocation --
    def _begin_scenario_round(self, eligible: np.ndarray, fl: np.ndarray
                              ) -> tuple[np.ndarray, np.ndarray,
                                         Optional[np.ndarray]]:
        """Draw this round's scenario effects and apply the
        allocation-visible ones: the availability mask filters the
        eligible set (absences bucketed ``unavailable`` for the process,
        ``fault`` for blackout/battery-gate injectors), and workload
        shedding scales the FLOPs + upload floats every policy sizes
        against.  Returns the filtered ``(eligible, flops,
        payload_mult)``; the realized-side faults are held on
        ``self._effects`` for :meth:`_realized_faults`."""
        self._effects = None
        if self.scenario is None:
            return eligible, fl, None
        eff = self._effects = self.scenario.begin_round(
            len(self.decisions), self.clock.now, self.fleet.battery_j)
        avail = eff.available[eligible]
        n_fault = int(eff.fault_off[eligible].sum())
        n_proc = int((eff.proc_off[eligible]
                      & ~eff.fault_off[eligible]).sum())
        self.unavailable_total += n_proc + n_fault
        if n_proc:
            self.drop_reasons["unavailable"] = (
                self.drop_reasons.get("unavailable", 0) + n_proc)
        if n_fault:
            self.drop_reasons["fault"] = (
                self.drop_reasons.get("fault", 0) + n_fault)
        tr = self.tracer
        if tr.enabled:
            tr.metrics.gauge("availability_frac").set(
                float(avail.mean()) if avail.size else 0.0)
            if n_proc:
                tr.metrics.counter("excluded_total").inc(
                    n_proc, reason="unavailable", policy=self.policy.name)
            if n_fault:
                tr.metrics.counter("excluded_total").inc(
                    n_fault, reason="fault", policy=self.policy.name)
            if (n_fault or eff.has_channel_fault or eff.has_compute_fault
                    or eff.has_shedding):
                tr.event(obs.FAULT, obs.CAT_ROUND, self.clock.now,
                         round_id=len(self.decisions), forced_off=n_fault,
                         snr_hit=int((eff.snr_scale != 1.0).sum()),
                         slowed=int((eff.compute_scale != 1.0).sum()),
                         workload_frac=float(eff.workload_frac.mean()))
        eligible, fl = eligible[avail], fl[avail]
        mult = None
        if eligible.size and eff.has_shedding:
            mult = eff.workload_frac[eligible]
            fl = fl * mult
        return eligible, fl, mult

    def _realized_faults(self, ids, fl_sel: np.ndarray,
                         widths) -> np.ndarray:
        """Apply the realized-side scenario faults to a committed
        cohort: SNR bursts degrade the channel AFTER the grant (the
        policy provisioned against the clean draw; the granted widths
        are re-applied at the degraded SNR), and straggler slowdowns
        scale the realized FLOPs — time and, at fixed power, energy.
        Returns the (possibly scaled) per-client flops."""
        eff = self._effects
        if eff is None:
            return fl_sel
        ids = np.asarray(ids, dtype=int)
        if eff.has_channel_fault:
            self.channel.scale_snr(eff.snr_scale)
            self.channel.set_bandwidth(ids, widths)
        if eff.has_compute_fault:
            fl_sel = fl_sel * eff.compute_scale[ids]
        return fl_sel

    def _maybe_reallocate(self, est_sel: ClientEstimate,
                          verdict: DeadlineVerdict, widths,
                          d_eff: np.ndarray) -> None:
        """Opt-in mid-round re-allocation (``cfg.reallocate``): the
        widths of cut clients re-land on the survivors still on the air
        (see :func:`repro.edge.events.reallocated_finish`).  Runs
        strictly after the verdict — the drop set, tx fractions and
        billing are untouched, so "ledger <= plan" and seeded replays
        hold — and rewrites the survivors' realized finishes and tx
        energy in place, so the barrier/idle/battery math downstream
        sees the shrunk round for free.  Sync mode only (async grants
        release spectrum through the expiry path instead)."""
        if (not self.cfg.reallocate or self.async_agg is not None
                or not verdict.any_dropped
                or verdict.n_dropped == verdict.clients.size):
            return
        w = np.broadcast_to(np.asarray(widths, dtype=float),
                            verdict.clients.shape)
        new_fin = reallocated_finish(est_sel.time_s, verdict.t_comp_s,
                                     verdict.deadline_s, w, verdict.dropped)
        if not np.any(new_fin < est_sel.time_s):
            return
        tr = self.tracer
        before = (float(np.max(np.minimum(est_sel.time_s, d_eff)))
                  if tr.enabled else 0.0)
        dt = est_sel.time_s - new_fin
        # the freed spectrum re-landed on the survivors mid-round: their
        # realized subchannel rate rose, so the air-time floor inside
        # finish_round_sync's server-drain term must see the effective
        # rate (same bits, less air time), or the stale granted widths
        # would hold the round open past the shrunk barrier
        air_old = est_sel.time_s - verdict.t_comp_s
        air_new = new_fin - verdict.t_comp_s
        improved = (~verdict.dropped) & (dt > 0.0)
        scale = np.where(improved & (air_new > 0.0),
                         air_old / np.maximum(air_new, 1e-300), 1.0)
        c = est_sel.clients
        self.channel.rates_bps[c] = self.channel.rates_bps[c] * scale
        est_sel.energy_j = (est_sel.energy_j
                            - self.channel.cfg.tx_power_w * dt)
        est_sel.time_s = new_fin
        verdict.finish_s = new_fin
        self.realloc_rounds += 1
        if tr.enabled:
            after = float(np.max(np.minimum(new_fin, d_eff)))
            tr.event(obs.REALLOC, obs.CAT_ROUND, self.clock.now,
                     round_id=len(self.decisions) - 1,
                     freed_hz=float(w[verdict.dropped].sum()),
                     n_dropped=int(verdict.n_dropped),
                     barrier_before=before, barrier_after=after)
            tr.metrics.counter("realloc_rounds_total").inc(
                1, policy=self.policy.name)
            tr.metrics.histogram("realloc_barrier_saved_s").observe(
                before - after)

    def decide(self, k: int, eligible, wire_fn: Callable, flops,
               summable: bool = True, codec=None
               ) -> tuple[list[int], ClientEstimate, RoundDecision]:
        """Start a round: re-draw fading, filter dead clients, run the
        allocation policy.  ``wire_fn(codec_override|None)`` maps a codec
        to one client's (aggregatable, non-aggregatable) upload wire
        bytes.  Returns (cohort ids, allocation-aware estimates for the
        cohort, the RoundDecision)."""
        # grants that lapsed since the last pop free their spectrum now;
        # the next pop's history record picks up the count so
        # Σ history['dropped'] reconciles with deadline_dropped_total
        self._expired_unrecorded += self._release_expired()
        self.channel.sample()
        eligible = np.asarray(eligible, dtype=int)
        fl = np.broadcast_to(np.asarray(flops, dtype=float), eligible.shape)
        # scenario availability filters BEFORE the policy runs: no
        # registered policy can select an unavailable client, and an
        # all-unavailable round degrades to the standard empty-cohort
        # round below (clock unchanged, nothing billed)
        eligible, fl, mult = self._begin_scenario_round(eligible, fl)
        alive = self.fleet.alive(eligible)
        if alive.size == 0:
            decision = RoundDecision(budget_hz=self.budget_hz(k))
            self.decisions.append(decision)
            self.verdicts.append(None)
            self._verdict = None
            return [], self._empty_est(), decision
        keep = np.isin(eligible, alive)
        if self.fleet_active():
            decision, est_sel = self._decide_fleet(
                k, eligible[keep], wire_fn, fl[keep],
                payload_mult=None if mult is None else mult[keep])
            return decision.selected, est_sel, decision
        state = self._round_state(k, eligible[keep], wire_fn, fl[keep],
                                  summable, codec,
                                  payload_mult=None if mult is None
                                  else mult[keep])
        decision = self.policy.decide(state)
        est_sel = self._apply(decision, state, wire_fn, fl[keep])
        if self.async_agg is not None:
            # the grant persists until the upload lands (pop_async_buffer
            # releases it); only this driver path dispatches into the
            # buffer, so only it holds spectrum
            for i in decision.selected:
                self._held_hz[int(i)] = decision.allocations[i].bandwidth_hz
        return decision.selected, est_sel, decision

    def allocate_for(self, clients, wire_fn: Callable, flops,
                     summable: bool = True, codec=None
                     ) -> tuple[ClientEstimate, RoundDecision]:
        """Allocation without selection: the cohort is already fixed
        (the vmapped simulator path), so run only the policy's
        ``allocate`` stage over it and commit the result.

        Cohort slots may repeat a fleet entry (the with_edge mod
        fallback when the cohort outnumbers the fleet): a device has one
        radio, so it gets ONE subchannel and carries one payload per
        slot — the returned estimate covers the unique clients with
        their payload multiplicity priced in, never silently dropping
        slots.  The budget is still provisioned per slot (k × W auto)."""
        clients = np.asarray(clients, dtype=int)
        self.channel.sample()
        fl = np.broadcast_to(np.asarray(flops, dtype=float), clients.shape)
        uniq, inv, counts = np.unique(clients, return_inverse=True,
                                      return_counts=True)
        fl_uniq = np.zeros(len(uniq))
        np.add.at(fl_uniq, inv, fl)
        # scenario: this cohort is externally fixed, so the availability
        # mask does not filter here (decide() is the selection path) —
        # but faults still strike: workload shedding scales the
        # allocation-visible FLOPs/floats now, and the realized-side
        # faults hit in _apply/_commit_fleet as usual
        self._effects = None
        counts = np.asarray(counts, dtype=float)
        if self.scenario is not None:
            eff = self._effects = self.scenario.begin_round(
                len(self.decisions), self.clock.now, self.fleet.battery_j)
            if eff.has_shedding:
                frac = eff.workload_frac[uniq]
                fl_uniq = fl_uniq * frac
                counts = counts * frac
        if self.fleet_active():
            fstate, tot_bytes = self._fleet_state(
                len(clients), uniq, wire_fn, fl_uniq, payload_mult=counts)
            sel = np.arange(len(uniq))
            w, d = self.policy.allocate_vectorized(fstate, sel)
            decision = FleetDecision(uniq, w, d, fstate.budget_hz,
                                     positions=sel).validate()
            est_sel = self._commit_fleet(decision, fstate, tot_bytes,
                                         fl_uniq)
            return est_sel, decision
        # payload_mult: m slots on one device = m payloads over its single
        # subchannel — the policy sizes allocations against m·bits, and
        # the estimates/clock bill every slot
        state = self._round_state(len(clients), uniq, wire_fn, fl_uniq,
                                  summable, codec, payload_mult=counts)
        decision = RoundDecision(
            allocations=self.policy.allocate([int(c) for c in uniq], state),
            excluded={}, budget_hz=state.budget_hz).validate()
        est_sel = self._apply(decision, state, wire_fn, fl_uniq)
        return est_sel, decision

    # ------------------------------------------------------------------
    def finish_round_sync(self, est_sel: ClientEstimate, up_bytes,
                          down_bytes: float, aggregatable: bool = True,
                          nonagg_bytes=None) -> dict:
        """Advance the clock over a synchronous round and drain batteries.

        star: barrier at the slowest client's compute+uplink finish.
        tree: compute barrier, then the aggregation phase (log2(τ) hops
        for summable payloads, serialized root link otherwise).

        Deadline enforcement: if the round's decision granted finite
        deadlines (the verdict ``decide``/``allocate_for`` computed), the
        barrier is min(deadline, max_k t_k) — a late client is cut off
        at its grant and never holds the round open.  Its on-air bytes
        (``tx_frac`` of the upload) still cross the shared server slice
        and its battery is drained for the work actually done (compute
        up to the cutoff, transmit up to the cutoff), but the payload is
        gone: ``up_bytes`` here are the wire bytes the caller billed,
        scaled internally by the verdict's fractions.

        ``up_bytes`` / ``nonagg_bytes`` are scalars or per-client arrays
        aligned with ``est_sel.clients`` (heterogeneous codecs);
        ``nonagg_bytes`` carves that share of ``up_bytes`` out as
        non-aggregatable (mixed payloads, e.g. FedDANE's gradient + model
        phases) and overrides ``aggregatable`` when given."""
        verdict, self._verdict = self._verdict, None
        c = est_sel.clients
        if c.size == 0:
            # empty cohort: nothing is broadcast or transmitted — the
            # clock must agree with the ledger's zero-byte round
            return self._record(0.0, 0.0, c)
        if verdict is not None and not np.array_equal(verdict.clients, c):
            verdict = None      # est does not cover the judged cohort
        t_down = self.channel.downlink_time_s(down_bytes)
        up = np.broadcast_to(np.asarray(up_bytes, dtype=float), c.shape)
        if nonagg_bytes is None:
            nonagg = up * 0.0 if aggregatable else up
        else:
            nonagg = np.minimum(
                np.broadcast_to(np.asarray(nonagg_bytes, dtype=float),
                                c.shape), up)
        if verdict is None:
            deadlines = np.full(c.shape, np.inf)
            frac = np.ones(c.shape)
            n_dropped = 0
        else:
            deadlines = verdict.deadline_s
            frac = verdict.tx_frac
            n_dropped = verdict.n_dropped
        # only the bytes on the air before each cutoff cross the network
        agg = (up - nonagg) * frac
        nonagg = nonagg * frac
        # a client is active until min(its finish, its deadline)
        active = np.minimum(est_sel.time_s, deadlines)
        t_comp = (verdict.t_comp_s if verdict is not None
                  else est_sel.time_s - self.channel.uplink_time_s(up, c))
        if self.channel.cfg.topology == "tree":
            fl_t = np.minimum(est_sel.time_s
                              - self.channel.uplink_time_s(up, c), deadlines)
            barrier = float(np.max(fl_t))
            t_round = barrier + self.channel.comm_round_time_split(
                agg, nonagg, c)
        else:
            # per-client completions in parallel subchannels, then the
            # shared server slice drains the cohort's payloads
            barrier = self.clock.round_time(est_sel.time_s, cap_s=deadlines)
            t_round = max(barrier,
                          self.channel.comm_round_time_split(agg, nonagg, c))
        t0 = self.clock.now
        self.phase_s["downlink"] += t_down
        self.phase_s["barrier"] += barrier
        self.phase_s["drain"] += max(t_round - barrier, 0.0)
        if self.tracer.enabled:
            self._trace_sync_round(t0, t_down, t_round, barrier, c, t_comp,
                                   active, verdict)
        self.clock.advance(t_down + t_round)
        # synchronous barrier: a client that finishes early (or was cut
        # off) sits idle until the round closes, draining idle_power_w
        idle_s = np.maximum(t_round - active, 0.0)
        if verdict is None:
            spend_j = est_sel.energy_j
        else:
            spend_j = verdict.capped_spend_j(est_sel.time_s,
                                             est_sel.energy_j,
                                             self.channel.cfg.tx_power_w)
        spend_j = spend_j + self.fleet.cfg.idle_power_w * idle_s
        e = float(spend_j.sum())
        self.fleet.spend(c, spend_j)
        if self.tracer.enabled:
            self._meter_energy(c, e)
        landed = c if verdict is None else c[~verdict.dropped]
        return self._record(t_down + t_round, e, landed,
                            dropped=n_dropped, barrier_s=barrier)

    def _trace_sync_round(self, t0: float, t_down: float, t_round: float,
                          barrier: float, c: np.ndarray, t_comp: np.ndarray,
                          active: np.ndarray,
                          verdict: Optional[DeadlineVerdict]) -> None:
        """Emit the round's span tree on the simulated timeline: the
        round envelope, the shared downlink, per-client compute+uplink
        children (uplink truncated at any enforced cutoff), and the
        aggregation drain past the barrier.  One client's span durations
        sum to its active time min(finish, deadline), so under star
        topology max_k Σ durations == the recorded ``barrier_s``."""
        tr = self.tracer
        rid = len(self.decisions) - 1
        tr.span(obs.ROUND, obs.CAT_ROUND, t0, t0 + t_down + t_round,
                round_id=rid, cohort=int(c.size))
        if t_down > 0:
            tr.span(obs.DOWNLINK, obs.CAT_ROUND, t0, t0 + t_down,
                    round_id=rid)
        start = t0 + t_down
        tr.metrics.histogram("barrier_s").observe(barrier)
        for phase, dt in (("downlink", t_down), ("barrier", barrier),
                          ("drain", max(t_round - barrier, 0.0))):
            tr.metrics.counter("phase_s_total").inc(dt, phase=phase)
        idx = range(len(c))
        if self._fleet_round and c.size > self.cfg.trace_top_k_clients:
            # fleet rounds keep span volume O(top-k): only the slowest
            # (latest-active) clients get per-client tracks — the same
            # clients export.to_chrome(top_k_clients=...) would keep
            idx = np.argsort(active, kind="stable")
            idx = idx[-self.cfg.trace_top_k_clients:]
        for j in idx:
            cl = int(c[j])
            comp_end = start + min(float(t_comp[j]), float(active[j]))
            tr.span(obs.COMPUTE, obs.CAT_CLIENT, start, comp_end,
                    round_id=rid, client=cl)
            tr.span(obs.UPLINK, obs.CAT_CLIENT, comp_end,
                    start + float(active[j]), round_id=rid, client=cl,
                    dropped=(bool(verdict.dropped[j])
                             if verdict is not None else False))
        tr.span(obs.AGGREGATE, obs.CAT_ROUND, start + barrier,
                t0 + t_down + t_round, round_id=rid)

    def _meter_energy(self, c: np.ndarray, spent_j: float) -> None:
        m = self.tracer.metrics
        m.counter("energy_j_total").inc(spent_j)
        if self._fleet_round and c.size > self.cfg.trace_top_k_clients:
            # summary-level battery metering at fleet scale: label
            # cardinality stays O(1) instead of O(population)
            batt = self.fleet.battery_j[c]
            m.gauge("battery_j_min").set(float(batt.min()))
            m.gauge("battery_j_mean").set(float(batt.mean()))
            return
        for cl in c:
            m.gauge("battery_j").set(float(self.fleet.battery_j[int(cl)]),
                                     client=int(cl))

    def dispatch_async(self, est_sel: ClientEstimate, n_samples, payloads,
                       down_bytes: float) -> None:
        """Submit the cohort's results into the in-flight buffer (energy is
        spent at dispatch — the client does the work regardless of when
        its update lands).

        Deadline enforcement: a dispatched client whose realized finish
        busts its granted deadline never lands — instead of a completion
        it gets a per-client *expiry event* at its cutoff; when the clock
        passes it, the granted spectrum returns to the pool and the
        device becomes selectable again (``_release_expired``).  Its
        battery is drained only for the work done before the cutoff.
        ``n_samples`` / ``payloads`` align with the SURVIVORS — a cut-off
        client's payload is never materialized."""
        assert self.async_agg is not None, "EdgeConfig.mode != 'async'"
        verdict, self._verdict = self._verdict, None
        if est_sel.clients.size == 0:
            return  # empty cohort: nothing broadcast, nothing in flight
        if verdict is not None and not np.array_equal(verdict.clients,
                                                      est_sel.clients):
            verdict = None
        drop = (np.zeros(est_sel.clients.shape, bool) if verdict is None
                else verdict.dropped)
        n_surv = int((~drop).sum())
        if len(payloads) != n_surv:
            raise ValueError(
                f"dispatch_async got {len(payloads)} payloads for "
                f"{n_surv} surviving clients (cohort {est_sel.clients.size}, "
                f"{int(drop.sum())} past deadline)")
        if self.cfg.buffer_size == 0 and not self._buffer_resolved:
            self.async_agg.buffer_size = max(1, (n_surv + 1) // 2)
            self._buffer_resolved = True
        self.clock.advance(self.channel.downlink_time_s(down_bytes))
        if verdict is None:
            spend_j = est_sel.energy_j
        else:
            spend_j = verdict.capped_spend_j(est_sel.time_s,
                                             est_sel.energy_j,
                                             self.channel.cfg.tx_power_w)
        self.fleet.spend(est_sel.clients, spend_j)
        self.energy_j += float(spend_j.sum())
        tr = self.tracer
        if tr.enabled:
            self._meter_energy(est_sel.clients, float(spend_j.sum()))
        rid = len(self.decisions) - 1
        j = 0
        for i, cl in enumerate(est_sel.clients):
            cl = int(cl)
            self.busy.add(cl)
            if drop[i]:
                # the grant lapses at the cutoff: spectrum + device are
                # released when the clock reaches it, the upload never
                # enters the buffer
                expires = self.clock.now + float(verdict.deadline_s[i])
                self._expiry[cl] = expires
                self.clock.push(expires, kind=DEADLINE_EXPIRED, client=cl)
                if tr.enabled:
                    tr.event(obs.EXPIRE, obs.CAT_ASYNC, expires,
                             round_id=rid, client=cl,
                             deadline_s=float(verdict.deadline_s[i]),
                             tx_frac=float(verdict.tx_frac[i]))
            else:
                if tr.enabled:
                    tr.event(obs.DISPATCH, obs.CAT_ASYNC, self.clock.now,
                             round_id=rid, client=cl,
                             eta_s=float(est_sel.time_s[i]),
                             version=self.async_agg.version)
                self.async_agg.submit(cl, float(est_sel.time_s[i]),
                                      float(np.asarray(n_samples)[j]),
                                      payloads[j])
                j += 1

    def _release_expired(self) -> int:
        """Release spectrum + busy state for every expired grant the
        clock has passed; returns how many lapsed."""
        lapsed = [cl for cl, t in self._expiry.items()
                  if t <= self.clock.now + 1e-12]
        for cl in lapsed:
            del self._expiry[cl]
            self._held_hz.pop(cl, None)
            self.busy.discard(cl)
        return len(lapsed)

    def pop_async_buffer(self):
        """Drain the next buffer; advances the clock to its last arrival.
        Returns (entries, staleness weights summing to 1)."""
        assert self.async_agg is not None
        t0 = self.clock.now
        entries, w = self.async_agg.pop_buffer()
        for e in entries:
            self.busy.discard(e.client)
            self._held_hz.pop(e.client, None)  # subchannel released
        expired = self._release_expired() + self._expired_unrecorded
        self._expired_unrecorded = 0
        self._record(self.clock.now - t0, 0.0,
                     np.asarray([e.client for e in entries], int),
                     dropped=expired)
        return entries, w

    # ------------------------------------------------------------------
    def _record(self, wall_s: float, energy_j: float, clients,
                dropped: int = 0, barrier_s: Optional[float] = None) -> dict:
        """``clients`` are the LANDED cohort (an all-dropped round records
        cohort=0); ``barrier_s`` is the enforced client-completion
        barrier — min(deadline, max_k t_k) — before the shared server
        drain and downlink are added.  Sync rounds record ``dropped`` at
        judgment; async records a drop when its lapsed grant is released
        (Σ history drops == deadline_dropped_total once every pending
        expiry has passed)."""
        self.energy_j += energy_j
        rec = {"wall_s": float(wall_s), "clock_s": self.clock.now,
               "energy_j": self.energy_j, "cohort": len(clients),
               "dropped": int(dropped)}
        if barrier_s is not None:
            rec["barrier_s"] = float(barrier_s)
        self.history.append(rec)
        if self.tracer.enabled:
            rec_t = dict(rec)
            rec_t["round_id"] = len(self.history) - 1
            self.tracer.record_round(rec_t)
            self.tracer.metrics.histogram("cohort_size").observe(len(clients))
        return rec

    def summary(self) -> dict:
        return {
            "wall_clock_s": self.clock.now,
            "energy_j": self.energy_j,
            "rounds": len(self.history),
            "dropped_total": self.dropped_total,
            "deadline_dropped_total": self.deadline_dropped_total,
            "unavailable_total": self.unavailable_total,
            "realloc_rounds": self.realloc_rounds,
            "depleted_clients": int((self.fleet.battery_j <= 0).sum()),
            "in_flight": 0 if self.async_agg is None else self.async_agg.in_flight,
            # why clients never landed, and where the simulated seconds
            # went — maintained whether or not a tracer is attached
            "drop_reasons": dict(self.drop_reasons),
            "phase_s": dict(self.phase_s),
        }
