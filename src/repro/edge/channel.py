"""Wireless channel model: Shannon-capacity links per client.

Each client k gets an uplink rate drawn per round,

    r_k = W · log2(1 + γ_k · h_k)

with W the allotted bandwidth (OFDMA subchannel — uplinks proceed in
parallel), γ_k the mean linear SNR of client k (lognormal shadowing across
the fleet, fixed per client), and h_k ~ Exp(1) optional per-round Rayleigh
fading power.  Transmission time for a payload of b bytes is 8b / r_k and
uplink energy is P_tx · t (the transmit-power model of arXiv:2104.05509
Sec. II; arXiv:1910.13067 uses the same capacity form for its resource
allocation).

Topologies (mirrors ``CommLedger``):
  * star — every selected client transmits its full payload to the server
    over its own subchannel; the round's comm phase ends when the slowest
    finishes.
  * tree — in-network aggregation along a binary tree of the selected
    clients: each node forwards ONE aggregated payload per level, so a
    round's comm time is depth × (slowest single hop), and the server link
    carries a single payload — Theorem 3's O(d log τ) reading.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ChannelConfig:
    bandwidth_hz: float = 1e6        # W — per-client uplink subchannel
    snr_db_mean: float = 10.0        # fleet-mean uplink SNR
    snr_db_std: float = 4.0          # lognormal shadowing across clients
    fading: str = "rayleigh"         # "none" | "rayleigh" (per-round Exp(1))
    tx_power_w: float = 0.5          # P_tx during uplink transmission
    downlink_rate_bps: float = 50e6  # base-station broadcast (fast, shared)
    server_rate_bps: float = 5e6     # base-station uplink slice: the SHARED
                                     # capacity every payload reaching the
                                     # server must cross (Theorem 3's O(k·d)
                                     # server-link term lives here)
    topology: str = "star"           # "star" | "tree"


def draw_snr_lin(cfg: ChannelConfig, num_clients: int,
                 rng: np.random.Generator) -> np.ndarray:
    """Static per-client mean linear SNR (lognormal shadowing in dB) —
    the array-state constructor shared by :class:`Channel` and the fleet
    engine's :class:`~repro.edge.fleet.FleetState` (identical rng call,
    so both paths draw identical populations from the same seed)."""
    snr_db = rng.normal(cfg.snr_db_mean, cfg.snr_db_std, num_clients)
    return 10.0 ** (snr_db / 10.0)


def draw_snr_round(cfg: ChannelConfig, snr_lin: np.ndarray,
                   rng: np.random.Generator) -> np.ndarray:
    """One round's effective per-client SNR: the static shadowing scaled
    by an Exp(1) Rayleigh fading power when configured (shared with the
    fleet engine — one draw per round over the whole population)."""
    if cfg.fading == "rayleigh":
        return snr_lin * rng.exponential(1.0, len(snr_lin))
    return snr_lin


class Channel:
    """Per-client link state; rates are re-drawn each round via ``sample``."""

    def __init__(self, cfg: ChannelConfig, num_clients: int, seed: int = 0):
        self.cfg = cfg
        self.num_clients = num_clients
        self._rng = np.random.default_rng(seed)
        # static per-client mean SNR (shadowing): lognormal in dB
        self._snr_lin = draw_snr_lin(cfg, num_clients, self._rng)
        self.rates_bps = self._draw_rates()

    def _draw_rates(self) -> np.ndarray:
        # this round's effective per-client SNR: set_bandwidth() re-derives
        # rates from it when an AllocationPolicy reapportions the budget
        self._snr_round = draw_snr_round(self.cfg, self._snr_lin, self._rng)
        return self.cfg.bandwidth_hz * np.log2(1.0 + self._snr_round)

    def sample(self) -> np.ndarray:
        """Re-draw fading for a new round; returns uplink rates (bit/s)
        at the nominal per-client subchannel ``cfg.bandwidth_hz``."""
        self.rates_bps = self._draw_rates()
        return self.rates_bps

    def scale_snr(self, factor) -> None:
        """Scale this round's effective linear SNR in place (scenario
        SNR-degradation faults, applied *after* allocation so the grant
        was provisioned against the clean draw); re-derives the nominal
        rates — callers re-apply :meth:`set_bandwidth` for granted
        widths.  The next ``sample()`` resets the draw."""
        self._snr_round = self._snr_round * np.asarray(factor, dtype=float)
        self.rates_bps = self.cfg.bandwidth_hz * np.log2(
            1.0 + self._snr_round)

    # ------------------------------------------------------------------
    def spectral_efficiency(self, clients) -> np.ndarray:
        """Per-client bits/s/Hz under this round's fading draw,
        log2(1 + γ_k·h_k) — the capacity form per unit bandwidth that a
        resource-allocation policy (arXiv:1910.13067) divides the budget
        against."""
        c = np.asarray(clients, dtype=int)
        return np.log2(1.0 + self._snr_round[c])

    def set_bandwidth(self, clients, bandwidth_hz) -> None:
        """Apply a RoundDecision's per-client subchannel widths for this
        round: rate_k = W_k · log2(1 + γ_k·h_k).  ``bandwidth_hz`` is a
        scalar (equal split) or an array aligned with ``clients``; the
        next ``sample()`` resets everyone to the nominal width."""
        c = np.asarray(clients, dtype=int)
        w = np.broadcast_to(np.asarray(bandwidth_hz, dtype=float), c.shape)
        self.rates_bps[c] = w * np.log2(1.0 + self._snr_round[c])

    def uplink_time_s(self, n_bytes, clients) -> np.ndarray:
        """Per-client transmission time; ``n_bytes`` is a scalar or an
        array aligned with ``clients`` (per-client codecs differ)."""
        c = np.asarray(clients, dtype=int)
        r = self.rates_bps[c]
        b = np.broadcast_to(np.asarray(n_bytes, dtype=float), c.shape)
        return 8.0 * b / np.maximum(r, 1e-6)

    def uplink_energy_j(self, n_bytes, clients) -> np.ndarray:
        return self.cfg.tx_power_w * self.uplink_time_s(n_bytes, clients)

    def downlink_time_s(self, n_bytes: float) -> float:
        """Broadcast time (one multicast payload on the shared downlink)."""
        return 8.0 * float(n_bytes) / max(self.cfg.downlink_rate_bps, 1e-6)

    # ------------------------------------------------------------------
    def comm_round_time_s(self, n_bytes: float, clients,
                          aggregatable: bool = True) -> float:
        """Wall time of the upload phase for the selected cohort.

        star: parallel subchannels -> max over clients.
        tree, aggregatable payloads (gradients/FIM — anything summed in-
        network): ceil(log2 k) levels, each bounded by the slowest hop; an
        aggregated payload is the same size as a client payload — the
        O(d log τ) reading of Theorem 3.
        tree, non-aggregatable payloads (FedAvg's k distinct local models):
        no in-network gain — the root link must carry every payload, so
        the bottleneck serializes k transfers on the best link (Theorem
        3's O(k·d) term survives the topology change)."""
        n_bytes = float(n_bytes)
        if aggregatable:
            return self.comm_round_time_split(n_bytes, 0.0, clients)
        return self.comm_round_time_split(0.0, n_bytes, clients)

    def comm_round_time_split(self, agg_bytes, nonagg_bytes,
                              clients) -> float:
        """Upload-phase wall time for a payload that is part aggregatable
        (summed in-network: gradients/FIM) and part not (distinct local
        models the server must see individually) — e.g. FedDANE's
        gradient + model phases.  Byte args are scalars or per-client
        arrays aligned with ``clients`` (heterogeneous upload codecs)."""
        clients = np.asarray(clients, dtype=int)
        k = clients.size
        if k == 0:
            return 0.0
        agg = np.broadcast_to(np.asarray(agg_bytes, dtype=float),
                              clients.shape)
        nonagg = np.broadcast_to(np.asarray(nonagg_bytes, dtype=float),
                                 clients.shape)
        total = agg + nonagg
        if total.sum() <= 0:
            return 0.0
        per = self.uplink_time_s(total, clients)
        srv = max(self.cfg.server_rate_bps, 1e-6)
        if self.cfg.topology == "tree":
            # aggregation parents are chosen among well-connected neighbours,
            # so a level costs a *representative* (median) hop, not the
            # fleet-worst deep fade.  Aggregatable bytes cross the server
            # link ONCE as a single summed payload — sized by the densest
            # contribution (O(d log τ)); non-aggregatable bytes cross it
            # once per client (Theorem 3's O(k·d) survives the topology
            # change).
            depth = max(1, math.ceil(math.log2(max(k, 2))))
            hops = depth * float(np.median(per))
            return hops + 8.0 * (float(agg.max()) + float(nonagg.sum())) / srv
        # star: subchannel air times in parallel, then every payload (both
        # classes) must cross the shared server slice
        return max(float(per.max()), 8.0 * float(total.sum()) / srv)
