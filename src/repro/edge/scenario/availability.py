"""Availability processes: who is reachable, per round.

All processes are vectorized over the population, keyed to EventClock
time (never a wall clock), and draw from the scenario's private RNG
stream with a *fixed* number of draws per round — so a seeded replay,
and a checkpoint/resume at any round boundary, is bit-identical."""
from __future__ import annotations

import json

import numpy as np

from repro.edge.scenario.base import (AvailabilityProcess, register_process)


class AlwaysOn(AvailabilityProcess):
    """The PR-8 static fleet: every client reachable every round."""

    name = "always_on"

    def mask(self, round_id: int, t_s: float,
             rng: np.random.Generator) -> np.ndarray:
        return np.ones(self.population, dtype=bool)


class Diurnal(AvailabilityProcess):
    """Sinusoidal connect probability with per-client phase.

    ``p_i(t) = clip(base + amp * sin(2*pi*(t/period + phase_i)), 0, 1)``
    where ``phase_i`` is a static per-client draw — clients in different
    "time zones" churn out of phase, the classic cross-device diurnal
    pattern (arXiv:2009.00081 §device availability).

    ``unit="round"`` counts the period in *rounds* instead of simulated
    seconds: same sinusoid, but invariant to anything that moves the
    clock (mid-round re-allocation, backend float drift) — the variant
    A/B comparisons like benchmarks Part F need, where both arms must
    draw identical churn while their barriers differ."""

    name = "diurnal"

    def __init__(self, period: float = 86400.0, amp: float = 0.4,
                 base: float = 0.6, phase_jitter: float = 1.0,
                 unit: str = "s"):
        if unit not in ("s", "round"):
            raise ValueError(f"diurnal unit must be 's' or 'round', "
                             f"got {unit!r}")
        self.period = float(period)
        self.amp = float(amp)
        self.base = float(base)
        self.phase_jitter = float(phase_jitter)
        self.unit = unit

    def reset(self, population: int, rng: np.random.Generator) -> None:
        super().reset(population, rng)
        self.phase = rng.uniform(0.0, 1.0, population) * self.phase_jitter

    def mask(self, round_id: int, t_s: float,
             rng: np.random.Generator) -> np.ndarray:
        x = (float(round_id) if self.unit == "round" else t_s) / self.period
        p = self.base + self.amp * np.sin(2.0 * np.pi * (x + self.phase))
        u = rng.uniform(0.0, 1.0, self.population)
        return u < np.clip(p, 0.0, 1.0)


class Markov(AvailabilityProcess):
    """Per-client two-state on/off chain: sticky sessions rather than
    independent coin flips — an on client drops with ``p_drop``, an off
    client rejoins with ``p_join``.  Starts from the stationary mix so
    round 0 is not a transient."""

    name = "markov"

    def __init__(self, p_drop: float = 0.1, p_join: float = 0.3,
                 p_start: float | None = None):
        self.p_drop = float(p_drop)
        self.p_join = float(p_join)
        denom = self.p_drop + self.p_join
        self.p_start = (float(p_start) if p_start is not None
                        else (self.p_join / denom if denom > 0 else 1.0))

    def reset(self, population: int, rng: np.random.Generator) -> None:
        super().reset(population, rng)
        self.state = rng.uniform(0.0, 1.0, population) < self.p_start

    def mask(self, round_id: int, t_s: float,
             rng: np.random.Generator) -> np.ndarray:
        u = rng.uniform(0.0, 1.0, self.population)
        self.state = np.where(self.state, u >= self.p_drop, u < self.p_join)
        return self.state

    def state_dict(self) -> dict[str, np.ndarray]:
        return {"state": self.state}

    def load_state_dict(self, state: dict[str, np.ndarray]) -> None:
        self.state = np.asarray(state["state"], dtype=bool)


class Trace(AvailabilityProcess):
    """Replay availability deltas from a JSONL trace.

    Each line is ``{"t": <event-clock seconds>, ...}`` with any of
    ``"on": [ids]``, ``"off": [ids]``, or ``"set": [ids]`` (wholesale
    replacement).  Records must be sorted by ``t``; every record with
    ``t <= now`` is applied once, cursor-style, so the process is a pure
    function of EventClock time and resumes from a checkpointed cursor."""

    name = "trace"

    def __init__(self, path: str):
        self.path = str(path)
        with open(self.path) as fh:
            self.records = [json.loads(line) for line in fh
                            if line.strip()]
        ts = [float(r.get("t", 0.0)) for r in self.records]
        if ts != sorted(ts):
            raise ValueError(f"availability trace {self.path} is not "
                             f"sorted by 't'")

    def reset(self, population: int, rng: np.random.Generator) -> None:
        super().reset(population, rng)
        self.state = np.ones(population, dtype=bool)
        self.cursor = 0

    def mask(self, round_id: int, t_s: float,
             rng: np.random.Generator) -> np.ndarray:
        while (self.cursor < len(self.records)
               and float(self.records[self.cursor].get("t", 0.0)) <= t_s):
            rec = self.records[self.cursor]
            if "set" in rec:
                self.state = np.zeros(self.population, dtype=bool)
                self.state[np.asarray(rec["set"], dtype=int)] = True
            if "on" in rec:
                self.state[np.asarray(rec["on"], dtype=int)] = True
            if "off" in rec:
                self.state[np.asarray(rec["off"], dtype=int)] = False
            self.cursor += 1
        return self.state.copy()

    def state_dict(self) -> dict[str, np.ndarray]:
        return {"state": self.state, "cursor": np.asarray(self.cursor)}

    def load_state_dict(self, state: dict[str, np.ndarray]) -> None:
        self.state = np.asarray(state["state"], dtype=bool)
        self.cursor = int(state["cursor"])


register_process("always_on", AlwaysOn)
register_process("diurnal", Diurnal)
register_process("markov", Markov)
register_process("trace", Trace)
