"""repro.edge.scenario — availability churn, fault injection, and the
spec-string registry (the fourth registry subsystem; see base.py for
the grammar and the two effect phases)."""
from repro.edge.scenario.base import (AvailabilityProcess, FaultInjector,
                                      RoundEffects, Scenario, fault_names,
                                      make_scenario, parse_spec,
                                      process_names, register_fault,
                                      register_process)
from repro.edge.scenario.availability import (AlwaysOn, Diurnal, Markov,
                                              Trace)
from repro.edge.scenario.faults import (BatteryGate, Blackout, DataExclusion,
                                        SnrBurst, Straggler)

__all__ = [
    "AvailabilityProcess", "FaultInjector", "RoundEffects", "Scenario",
    "register_process", "register_fault", "process_names", "fault_names",
    "parse_spec", "make_scenario",
    "AlwaysOn", "Diurnal", "Markov", "Trace",
    "Blackout", "SnrBurst", "Straggler", "BatteryGate", "DataExclusion",
]
