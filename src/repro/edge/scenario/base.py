"""Scenario layer: seeded availability churn and fault injection.

The edge runtime up to PR-8 modeled a *static* fleet — every client
reachable every round, channels that never black out, stragglers whose
granted spectrum dies with them at the barrier.  The FEEL design-issues
survey (arXiv:2009.00081) names device mobility/availability as exactly
the gap this ignores.  This package closes it with a fourth registry
subsystem (mirroring strategies / codecs / allocation policies): a
:class:`Scenario` composes one pluggable **availability process** with
any number of **fault injectors**, all driven by a dedicated seeded RNG
stream and by *EventClock* time only — never a wall clock (RPL001).

Spec-string grammar
-------------------
A scenario is configured on ``EdgeConfig.scenario`` as a ``|``-separated
spec string.  Each component is ``name``, ``name:<positional>`` or
``name:key=val,key=val``; at most one component may name an availability
process (default ``always_on``), the rest name fault injectors::

    "diurnal:period=600,amp=0.4,base=0.7"
    "markov:p_drop=0.2,p_join=0.5|snr_burst:prob=0.3,scale=0.25"
    "trace:/tmp/avail.jsonl|blackout:start=10,end=20|data_exclusion:0.5"

Two effect phases
-----------------
*Allocation-visible* effects are applied **before** the policy runs:
the availability mask (process ``off`` states, ``blackout`` windows,
``battery_gate``) filters the eligible set — no registered policy can
select an unavailable client — and ``data_exclusion`` workload shedding
scales the FLOPs and upload floats every policy sizes against (the
threshold-exclusion knob of arXiv:2104.05509, generalized to partial
per-client workloads).

*Realized-side* faults (``snr_burst``, ``straggler``) strike **after**
allocation, between the grant and the transmission — the channel the
policy provisioned against is not the channel the upload sees.  That is
what makes deadline enforcement bite (a provisioned client can now bust
its granted cutoff) and mid-round re-allocation meaningful.
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np


# ---------------------------------------------------------------------------
# Per-round effect bundle
# ---------------------------------------------------------------------------
@dataclass
class RoundEffects:
    """Vectorized scenario output for one round, over the full population.

    ``available`` is the composed mask the runtime filters eligibility
    with; ``proc_off`` / ``fault_off`` split it by cause so drops land
    in distinct ``unavailable`` / ``fault`` reason buckets.  The scale
    arrays are realized-side unless noted; all default to no-op."""
    proc_off: np.ndarray       # (N,) bool — availability process says off
    fault_off: np.ndarray      # (N,) bool — a fault injector forced off
    snr_scale: np.ndarray      # (N,) float — realized-side channel fault
    compute_scale: np.ndarray  # (N,) float >= 1 — realized-side slowdown
    workload_frac: np.ndarray  # (N,) float in (0,1] — allocation-visible
    available: np.ndarray = field(init=False)

    def __post_init__(self) -> None:
        self.available = ~(self.proc_off | self.fault_off)

    @property
    def has_channel_fault(self) -> bool:
        return bool(np.any(self.snr_scale != 1.0))

    @property
    def has_compute_fault(self) -> bool:
        return bool(np.any(self.compute_scale != 1.0))

    @property
    def has_shedding(self) -> bool:
        return bool(np.any(self.workload_frac != 1.0))


def _noop_effects(population: int) -> RoundEffects:
    return RoundEffects(
        proc_off=np.zeros(population, dtype=bool),
        fault_off=np.zeros(population, dtype=bool),
        snr_scale=np.ones(population, dtype=float),
        compute_scale=np.ones(population, dtype=float),
        workload_frac=np.ones(population, dtype=float),
    )


# ---------------------------------------------------------------------------
# Component protocols
# ---------------------------------------------------------------------------
class AvailabilityProcess:
    """Who is reachable this round.  Stateful components keep their
    evolution in arrays exposed via ``state_dict`` so a checkpointed run
    resumes bit-identically.  ``mask`` must consume a *fixed* number of
    RNG draws per round regardless of the outcome."""

    name = "base"

    def reset(self, population: int, rng: np.random.Generator) -> None:
        self.population = population

    def mask(self, round_id: int, t_s: float,
             rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError

    def state_dict(self) -> dict[str, np.ndarray]:
        return {}

    def load_state_dict(self, state: dict[str, np.ndarray]) -> None:
        pass


class FaultInjector:
    """A per-round perturbation written into :class:`RoundEffects`.
    Injectors mutate ``eff`` in place and are applied in spec order."""

    name = "base"

    def reset(self, population: int, rng: np.random.Generator) -> None:
        self.population = population

    def apply(self, round_id: int, t_s: float, battery_j: np.ndarray,
              eff: RoundEffects, rng: np.random.Generator) -> None:
        raise NotImplementedError

    def state_dict(self) -> dict[str, np.ndarray]:
        return {}

    def load_state_dict(self, state: dict[str, np.ndarray]) -> None:
        pass


# ---------------------------------------------------------------------------
# The Scenario object
# ---------------------------------------------------------------------------
class Scenario:
    """One availability process + ordered fault injectors, on a private
    seeded stream (``seed + cfg.seed + 4`` in the runtime's layout, so
    enabling a scenario never perturbs the channel/fleet/policy draws).

    ``begin_round`` must be called exactly once per round, with the
    EventClock time — both the dict runtime and the fleet engine call it
    at the same point, so the two paths consume an identical stream and
    stay bit-identical under churn."""

    def __init__(self, availability: AvailabilityProcess,
                 faults: list[FaultInjector], population: int, seed: int,
                 spec: str = ""):
        self.availability = availability
        self.faults = list(faults)
        self.population = int(population)
        self.seed = int(seed)
        self.spec = spec
        self.rng = np.random.default_rng(self.seed)
        self.availability.reset(self.population, self.rng)
        for f in self.faults:
            f.reset(self.population, self.rng)
        self.rounds_seen = 0

    def begin_round(self, round_id: int, t_s: float,
                    battery_j: np.ndarray) -> RoundEffects:
        eff = _noop_effects(self.population)
        eff.proc_off = ~self.availability.mask(round_id, t_s, self.rng)
        for f in self.faults:
            f.apply(round_id, t_s, battery_j, eff, self.rng)
        eff.__post_init__()  # recompose the mask after injectors ran
        self.rounds_seen += 1
        return eff

    # -- checkpoint/resume support (repro.checkpoint.save_run) ---------
    def state_dict(self) -> dict[str, Any]:
        arrays: dict[str, np.ndarray] = {}
        for k, v in self.availability.state_dict().items():
            arrays[f"avail/{k}"] = v
        for i, f in enumerate(self.faults):
            for k, v in f.state_dict().items():
                arrays[f"fault{i}/{k}"] = v
        meta = {"rng": self.rng.bit_generator.state,
                "rounds_seen": self.rounds_seen, "spec": self.spec}
        return {"arrays": arrays, "meta": meta}

    def load_state_dict(self, state: dict[str, Any]) -> None:
        meta = state["meta"]
        if meta.get("spec", self.spec) != self.spec:
            raise ValueError(
                f"scenario spec mismatch: checkpoint has {meta['spec']!r}, "
                f"this run has {self.spec!r}")
        self.rng.bit_generator.state = meta["rng"]
        self.rounds_seen = int(meta["rounds_seen"])
        arrays = state["arrays"]
        self.availability.load_state_dict(
            {k[len("avail/"):]: v for k, v in arrays.items()
             if k.startswith("avail/")})
        for i, f in enumerate(self.faults):
            pre = f"fault{i}/"
            f.load_state_dict({k[len(pre):]: v for k, v in arrays.items()
                               if k.startswith(pre)})


# ---------------------------------------------------------------------------
# Registries + spec-string parsing (the fourth registry subsystem)
# ---------------------------------------------------------------------------
_PROCESSES: dict[str, Callable[..., AvailabilityProcess]] = {}
_FAULTS: dict[str, Callable[..., FaultInjector]] = {}


def register_process(name: str,
                     factory: Callable[..., AvailabilityProcess]) -> None:
    _PROCESSES[name] = factory


def register_fault(name: str, factory: Callable[..., FaultInjector]) -> None:
    _FAULTS[name] = factory


def process_names() -> list[str]:
    return sorted(_PROCESSES)


def fault_names() -> list[str]:
    return sorted(_FAULTS)


def _coerce(text: str) -> Any:
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            continue
    return text


def _parse_component(token: str) -> tuple[str, list[Any], dict[str, Any]]:
    """``name``, ``name:<positional>`` or ``name:k=v,k=v``."""
    name, _, rest = token.partition(":")
    name = name.strip()
    if not rest:
        return name, [], {}
    if "=" not in rest:
        return name, [_coerce(rest.strip())], {}
    kw: dict[str, Any] = {}
    for pair in rest.split(","):
        k, eq, v = pair.partition("=")
        if not eq:
            raise ValueError(f"bad scenario component {token!r}: "
                             f"expected key=val, got {pair!r}")
        kw[k.strip()] = _coerce(v.strip())
    return name, [], kw


def _build(factory: Callable[..., Any], name: str, args: list[Any],
           kw: dict[str, Any]) -> Any:
    sig = inspect.signature(factory)
    unknown = [k for k in kw if k not in sig.parameters]
    if unknown:
        raise ValueError(f"scenario component {name!r} does not accept "
                         f"{unknown} (accepts {list(sig.parameters)})")
    return factory(*args, **kw)


def parse_spec(spec: str) -> tuple[AvailabilityProcess, list[FaultInjector]]:
    """Parse a ``|``-separated spec string into instantiated components."""
    availability: AvailabilityProcess | None = None
    faults: list[FaultInjector] = []
    for token in spec.split("|"):
        token = token.strip()
        if not token:
            continue
        name, args, kw = _parse_component(token)
        if name in _PROCESSES:
            if availability is not None:
                raise ValueError(f"scenario spec {spec!r} names two "
                                 f"availability processes")
            availability = _build(_PROCESSES[name], name, args, kw)
        elif name in _FAULTS:
            faults.append(_build(_FAULTS[name], name, args, kw))
        else:
            raise ValueError(
                f"unknown scenario component {name!r}; processes: "
                f"{process_names()}, faults: {fault_names()}")
    if availability is None:
        availability = _PROCESSES["always_on"]()
    return availability, faults


def make_scenario(spec: "str | Scenario", population: int,
                  seed: int = 0) -> Scenario:
    """Build a seeded :class:`Scenario` from a spec string (the
    ``EdgeConfig.scenario`` entry point)."""
    if isinstance(spec, Scenario):
        if spec.population != population:
            raise ValueError(f"scenario built for population "
                             f"{spec.population}, runtime has {population}")
        return spec
    availability, faults = parse_spec(spec)
    return Scenario(availability, faults, population, seed, spec=spec)
