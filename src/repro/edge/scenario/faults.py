"""Fault injectors: per-round perturbations layered on the availability
process.

Two phases (see the package docstring): ``blackout`` and
``battery_gate`` fold into the *allocation-visible* availability mask
(``fault_off`` — no policy can select a faulted client, and its absence
is bucketed under ``fault`` rather than ``unavailable``);
``data_exclusion`` scales the allocation-visible workload; ``snr_burst``
and ``straggler`` are *realized-side* — they strike after the policy
granted widths and deadlines, which is what makes ``enforce_deadlines``
cut provisioned clients and gives mid-round re-allocation freed
spectrum to hand out."""
from __future__ import annotations

import numpy as np

from repro.edge.scenario.base import (FaultInjector, RoundEffects,
                                      register_fault)


class Blackout(FaultInjector):
    """A channel blackout window on EventClock time: clients in the
    affected subset are unreachable while ``start <= t mod period < end``
    (``period=0`` makes it a one-shot window on absolute clock time)."""

    name = "blackout"

    def __init__(self, start: float = 0.0, end: float = 0.0,
                 period: float = 0.0, frac: float = 1.0):
        self.start = float(start)
        self.end = float(end)
        self.period = float(period)
        self.frac = float(frac)

    def reset(self, population: int, rng: np.random.Generator) -> None:
        super().reset(population, rng)
        self.affected = (rng.uniform(0.0, 1.0, population) < self.frac
                         if self.frac < 1.0
                         else np.ones(population, dtype=bool))

    def apply(self, round_id: int, t_s: float, battery_j: np.ndarray,
              eff: RoundEffects, rng: np.random.Generator) -> None:
        t = t_s % self.period if self.period > 0 else t_s
        if self.start <= t < self.end:
            eff.fault_off |= self.affected


class SnrBurst(FaultInjector):
    """Per-round, per-client SNR-degradation bursts: each client's
    realized linear SNR is scaled by ``scale`` with probability
    ``prob`` — *after* allocation, so the policy provisioned against
    the clean channel and the upload sees the degraded one."""

    name = "snr_burst"

    def __init__(self, prob: float = 0.1, scale: float = 0.1):
        self.prob = float(prob)
        self.scale = float(scale)

    def apply(self, round_id: int, t_s: float, battery_j: np.ndarray,
              eff: RoundEffects, rng: np.random.Generator) -> None:
        hit = rng.uniform(0.0, 1.0, self.population) < self.prob
        eff.snr_scale = np.where(hit, eff.snr_scale * self.scale,
                                 eff.snr_scale)


class Straggler(FaultInjector):
    """Compute slowdown bursts: a hit client's realized FLOP count is
    scaled by ``slow`` (a throttled clock at fixed power — both compute
    time *and* compute energy grow), after the policy already committed
    to the nominal profile."""

    name = "straggler"

    def __init__(self, prob: float = 0.1, slow: float = 4.0):
        self.prob = float(prob)
        self.slow = float(slow)

    def apply(self, round_id: int, t_s: float, battery_j: np.ndarray,
              eff: RoundEffects, rng: np.random.Generator) -> None:
        hit = rng.uniform(0.0, 1.0, self.population) < self.prob
        eff.compute_scale = np.where(hit, eff.compute_scale * self.slow,
                                     eff.compute_scale)


class BatteryGate(FaultInjector):
    """Battery-gated dropout: a client whose remaining battery is at or
    below ``floor_j`` refuses the round entirely (stricter than the
    policies' ``battery_floor_j`` exclusion — the device never answers
    the scheduler, so it is a ``fault`` bucket absence, not a policy
    exclusion)."""

    name = "battery_gate"

    def __init__(self, floor_j: float = 0.0):
        self.floor_j = float(floor_j)

    def apply(self, round_id: int, t_s: float, battery_j: np.ndarray,
              eff: RoundEffects, rng: np.random.Generator) -> None:
        eff.fault_off |= np.asarray(battery_j) <= self.floor_j


class DataExclusion(FaultInjector):
    """Per-client workload shedding à la threshold-based data exclusion
    (arXiv:2104.05509): each round every client keeps an independent
    uniform fraction in ``[thresh, 1]`` of its local workload, scaling
    the *allocation-visible* FLOPs and upload floats the policies size
    widths and deadlines against.  Billing stays at full plan bytes —
    the ledger's "equal to plan iff no drops" invariant is about what
    the protocol commits to, not what the device elects to run."""

    name = "data_exclusion"

    def __init__(self, thresh: float = 0.5):
        if not 0.0 < float(thresh) <= 1.0:
            raise ValueError(f"data_exclusion threshold must be in (0, 1], "
                             f"got {thresh}")
        self.thresh = float(thresh)

    def apply(self, round_id: int, t_s: float, battery_j: np.ndarray,
              eff: RoundEffects, rng: np.random.Generator) -> None:
        frac = rng.uniform(self.thresh, 1.0, self.population)
        eff.workload_frac = eff.workload_frac * frac


register_fault("blackout", Blackout)
register_fault("snr_burst", SnrBurst)
register_fault("straggler", Straggler)
register_fault("battery_gate", BatteryGate)
register_fault("data_exclusion", DataExclusion)
