"""Event-driven simulation clock + deadline events for the edge runtime.

A minimal discrete-event core: the runtime pushes client-completion (or
arbitrary) events tagged with absolute times and pops them in time order.
Synchronous rounds reduce to ``advance(max_k t_k)`` — capped per client
by any enforced deadline — and the buffered asynchronous aggregator pops
completions one by one and lets the round boundary fall wherever its
buffer fills.

This module also owns the *deadline verdict*: the one predicted-vs-
realized authority (:func:`enforce_deadlines`) both the synchronous
barrier and the async expiry path consult, so a policy's admission rule
and the runtime's cutoff can never disagree about what "finishing in
time" means.  A client is late iff its realized finish (compute plus
uplink at its *granted* subchannel width) exceeds its granted deadline
by more than the tolerance; a late client's upload is cut off at the
deadline — the bytes it put on the air before the cutoff are billed,
the payload itself is discarded whole (a hard drop, never a silent
partial delta).
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

DEADLINE_EXPIRED = "deadline_expired"   # event kind: an async grant lapsed


@dataclass
class DeadlineVerdict:
    """The runtime's enforcement of the deadlines a RoundDecision granted.

    All arrays align with ``clients`` (the round's allocated cohort).
    ``tx_frac`` is the fraction of the upload's wire bytes that made it
    onto the air before the cutoff — 1.0 for every on-time client, and
    strictly < 1 for every dropped one (transmission is linear in time,
    so the byte fraction equals the air-time fraction)."""
    clients: np.ndarray      # (k,) allocated cohort ids
    deadline_s: np.ndarray   # (k,) effective per-client deadlines (inf = none)
    finish_s: np.ndarray     # (k,) realized finish at granted widths
    t_comp_s: np.ndarray     # (k,) compute-only share of finish_s
    dropped: np.ndarray      # (k,) bool: finish_s > deadline_s + tolerance
    tx_frac: np.ndarray      # (k,) upload byte fraction on the air by cutoff

    @property
    def any_dropped(self) -> bool:
        return bool(self.dropped.any())

    @property
    def n_dropped(self) -> int:
        return int(self.dropped.sum())

    def survivor_ids(self) -> list[int]:
        return [int(c) for c in self.clients[~self.dropped]]

    def capped_spend_j(self, time_s, energy_j, tx_power_w) -> np.ndarray:
        """Battery drain capped at each client's cutoff: the estimate's
        energy is split into compute and transmit shares (E_tx = P_tx ·
        t_up, the channel's uplink energy model), compute billed up to
        min(t_comp, deadline) and transmit for the tx_frac actually on
        the air.  Reduces to ``energy_j`` exactly for on-time clients —
        the one energy rule the sync barrier and the async dispatch both
        apply."""
        t_up = np.maximum(np.asarray(time_s, dtype=float) - self.t_comp_s,
                          0.0)
        e_tx = float(tx_power_w) * t_up
        e_comp = np.maximum(np.asarray(energy_j, dtype=float) - e_tx, 0.0)
        comp_frac = np.minimum(
            1.0, self.deadline_s / np.maximum(self.t_comp_s, 1e-300))
        return e_comp * comp_frac + e_tx * self.tx_frac

    def reasons(self) -> dict[int, str]:
        """Per dropped client, why the runtime cut it off (never empty)."""
        out = {}
        for c, f, d, fr in zip(self.clients[self.dropped],
                               self.finish_s[self.dropped],
                               self.deadline_s[self.dropped],
                               self.tx_frac[self.dropped], strict=True):
            out[int(c)] = (f"realized finish {f:.3g}s > deadline {d:g}s "
                           f"({100.0 * fr:.0f}% of the upload transmitted "
                           "before cutoff, payload discarded)")
        return out


def enforce_deadlines(clients, finish_s, t_comp_s, deadline_s,
                      tolerance_s: float = 0.0, tracer=None, t0: float = 0.0,
                      round_id: int = -1) -> DeadlineVerdict:
    """Judge one allocated cohort against its granted deadlines.

    ``finish_s`` is the REALIZED per-client finish — compute plus uplink
    at the widths the RoundDecision actually granted, under this round's
    channel draw — which is exactly what an admission policy predicting
    under the *nominal* equal split upper-bounds (survivors share at
    least the nominal width), so a client admitted by the ``deadline``
    policy under zero channel noise is never dropped here.
    ``tolerance_s`` absorbs float jitter between the two computations;
    it widens the admission, never the cutoff (billing cuts at the
    deadline itself).

    ``tracer`` (a :class:`repro.obs.trace.Tracer`; default off) records
    the verdict as one traced event per judged client — the granted
    deadline vs the realized finish, the drop bit, and the on-air byte
    fraction — timestamped at ``t0 + min(finish, deadline)`` on the
    simulated timeline (``t0`` = the round's start)."""
    c = np.asarray(clients, dtype=int)
    f = np.asarray(finish_s, dtype=float)
    tc = np.asarray(t_comp_s, dtype=float)
    d = np.broadcast_to(np.asarray(deadline_s, dtype=float), c.shape)
    dropped = f > d + float(tolerance_s)
    t_up = np.maximum(f - tc, 0.0)
    air = np.clip(d - tc, 0.0, None)       # air time available before cutoff
    frac = np.where(
        dropped,
        np.where(t_up > 0.0, np.minimum(air / np.maximum(t_up, 1e-300), 1.0),
                 0.0),
        1.0)
    verdict = DeadlineVerdict(clients=c, deadline_s=np.asarray(d, dtype=float),
                              finish_s=f, t_comp_s=tc, dropped=dropped,
                              tx_frac=frac)
    if tracer is not None and tracer.enabled:
        from repro.obs import trace as _t
        for j in range(c.size):
            cut = min(float(f[j]), float(d[j])) if np.isfinite(d[j]) \
                else float(f[j])
            tracer.event(
                _t.VERDICT, _t.CAT_CLIENT, float(t0) + cut,
                round_id=round_id, client=int(c[j]),
                deadline_s=float(d[j]) if np.isfinite(d[j]) else None,
                finish_s=float(f[j]), t_comp_s=float(tc[j]),
                dropped=bool(dropped[j]), tx_frac=float(frac[j]))
    return verdict


def reallocated_finish(finish_s, t_comp_s, deadline_s, widths_hz,
                       dropped) -> np.ndarray:
    """Mid-round re-allocation: survivors' finishes after dropped
    clients' spectrum is re-offered (``EdgeConfig.reallocate``).

    When :func:`enforce_deadlines` cuts a client, its granted width
    returns to the pool at its cutoff time and is re-granted to every
    survivor still on the air, pro rata to their granted widths.
    Proportional redistribution means all survivors' widths scale by
    the *same* piecewise-constant factor ``c(t) = 1 + freed(t)/W_surv``
    (``freed(t)`` = widths of clients cut at or before ``t``), so the
    new finish of survivor *i* solves ``∫_{tc_i}^{fin} c(t) dt = A_i``
    with ``A_i`` its original air time — one cumulative segment
    integral plus two searchsorteds, fully vectorized.

    Runs strictly *after* the verdict: the drop set, tx fractions and
    billing are computed at the granted widths and are untouched — only
    survivors finish (weakly) earlier, shrinking the realized barrier.
    A survivor already off the air before the first cutoff is
    unchanged.  Returns the per-client new finishes (dropped clients
    keep theirs)."""
    f = np.asarray(finish_s, dtype=float)
    tc = np.asarray(t_comp_s, dtype=float)
    d = np.broadcast_to(np.asarray(deadline_s, dtype=float), f.shape)
    w = np.asarray(widths_hz, dtype=float)
    drop = np.asarray(dropped, dtype=bool)
    w_surv = float(w[~drop].sum())
    if not drop.any() or drop.all() or w_surv <= 0.0:
        return f.copy()
    cut = np.minimum(f, d)[drop]           # dropped => cut at the deadline
    order = np.argsort(cut, kind="stable")
    ts = cut[order]                        # (m,) cutoff breakpoints, sorted
    c_seg = 1.0 + np.cumsum(w[drop][order]) / w_surv   # factor after ts[k]
    # cumulative stretched air time at the breakpoints (factor 1 before
    # the first cutoff): integ[k] = ∫_0^{ts[k]} c(t) dt
    integ = np.empty_like(ts)
    integ[0] = ts[0]
    if ts.size > 1:
        integ[1:] = ts[0] + np.cumsum(c_seg[:-1] * np.diff(ts))

    def _cum(x: np.ndarray) -> np.ndarray:
        k = np.searchsorted(ts, x, side="right") - 1
        kk = np.maximum(k, 0)
        return np.where(k >= 0, integ[kk] + c_seg[kk] * (x - ts[kk]), x)

    surv = ~drop
    target = _cum(tc[surv]) + (f[surv] - tc[surv])   # stretched-air budget
    k = np.searchsorted(integ, target, side="right") - 1
    kk = np.maximum(k, 0)
    fin = np.where(k >= 0, ts[kk] + (target - integ[kk]) / c_seg[kk], target)
    out = f.copy()
    # c >= 1 makes fin <= f in exact arithmetic; the minimum pins the
    # "never later than the granted-width finish" invariant bitwise
    out[surv] = np.minimum(fin, f[surv])
    return out


@dataclass(order=True)
class Event:
    time: float
    seq: int = field(compare=True)          # tie-break: FIFO among equal times
    kind: str = field(compare=False, default="")
    client: int = field(compare=False, default=-1)
    payload: Any = field(compare=False, default=None)


class EventClock:
    """Monotone simulation clock + pending-event heap (seconds)."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._heap: list[Event] = []
        self._seq = itertools.count()

    @property
    def now(self) -> float:
        return self._now

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, time: float, kind: str = "", client: int = -1,
             payload: Any = None) -> Event:
        if time < self._now:
            raise ValueError(f"event at t={time} is before now={self._now}")
        ev = Event(time=float(time), seq=next(self._seq), kind=kind,
                   client=client, payload=payload)
        heapq.heappush(self._heap, ev)
        return ev

    def push_after(self, delay: float, kind: str = "", client: int = -1,
                   payload: Any = None) -> Event:
        return self.push(self._now + max(0.0, float(delay)), kind, client, payload)

    def pop(self) -> Optional[Event]:
        """Pop the earliest pending event and advance the clock to it."""
        if not self._heap:
            return None
        ev = heapq.heappop(self._heap)
        self._now = max(self._now, ev.time)
        return ev

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def advance(self, delta: float) -> float:
        """Advance the clock by ``delta`` seconds (synchronous round time)."""
        if delta < 0:
            raise ValueError(f"cannot advance by negative delta {delta}")
        self._now += float(delta)
        return self._now

    def round_time(self, client_times, quantile: float = 1.0,
                   cap_s=None) -> float:
        """Synchronous-round wall time: the ``quantile`` of per-client
        completion times (1.0 = wait for the slowest; <1 models deadline
        truncation where stragglers are dropped at the quantile).
        ``cap_s`` (scalar or per-client array) caps each completion at
        its enforced deadline first, so the barrier is
        min(deadline, max_k t_k) — a cut-off straggler never holds the
        round open past its grant."""
        ts = np.asarray(client_times, dtype=np.float64)
        if ts.size == 0:
            return 0.0
        if cap_s is not None:
            ts = np.minimum(ts, np.broadcast_to(
                np.asarray(cap_s, dtype=np.float64), ts.shape))
        q = min(max(quantile, 0.0), 1.0)
        return float(np.quantile(ts, q))
