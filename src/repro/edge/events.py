"""Event-driven simulation clock for the edge runtime.

A minimal discrete-event core: the runtime pushes client-completion (or
arbitrary) events tagged with absolute times and pops them in time order.
Synchronous rounds reduce to ``advance(max_k t_k)``; the buffered
asynchronous aggregator pops completions one by one and lets the round
boundary fall wherever its buffer fills.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Optional


@dataclass(order=True)
class Event:
    time: float
    seq: int = field(compare=True)          # tie-break: FIFO among equal times
    kind: str = field(compare=False, default="")
    client: int = field(compare=False, default=-1)
    payload: Any = field(compare=False, default=None)


class EventClock:
    """Monotone simulation clock + pending-event heap (seconds)."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._heap: list[Event] = []
        self._seq = itertools.count()

    @property
    def now(self) -> float:
        return self._now

    def __len__(self) -> int:
        return len(self._heap)

    def push(self, time: float, kind: str = "", client: int = -1,
             payload: Any = None) -> Event:
        if time < self._now:
            raise ValueError(f"event at t={time} is before now={self._now}")
        ev = Event(time=float(time), seq=next(self._seq), kind=kind,
                   client=client, payload=payload)
        heapq.heappush(self._heap, ev)
        return ev

    def push_after(self, delay: float, kind: str = "", client: int = -1,
                   payload: Any = None) -> Event:
        return self.push(self._now + max(0.0, float(delay)), kind, client, payload)

    def pop(self) -> Optional[Event]:
        """Pop the earliest pending event and advance the clock to it."""
        if not self._heap:
            return None
        ev = heapq.heappop(self._heap)
        self._now = max(self._now, ev.time)
        return ev

    def peek(self) -> Optional[Event]:
        return self._heap[0] if self._heap else None

    def advance(self, delta: float) -> float:
        """Advance the clock by ``delta`` seconds (synchronous round time)."""
        if delta < 0:
            raise ValueError(f"cannot advance by negative delta {delta}")
        self._now += float(delta)
        return self._now

    def round_time(self, client_times, quantile: float = 1.0) -> float:
        """Synchronous-round wall time: the ``quantile`` of per-client
        completion times (1.0 = wait for the slowest; <1 models deadline
        truncation where stragglers are dropped at the quantile)."""
        import numpy as np

        ts = np.asarray(list(client_times), dtype=np.float64)
        if ts.size == 0:
            return 0.0
        q = min(max(quantile, 0.0), 1.0)
        return float(np.quantile(ts, q))
