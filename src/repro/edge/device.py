"""Heterogeneous edge-device compute profiles.

A ``DeviceFleet`` draws per-client effective throughput (FLOPs/s,
lognormal across the fleet — the straggler distribution) and an energy
cost per FLOP; each client also carries a battery budget that local work
and uplink transmission drain (the depletion model behind the
energy-threshold exclusion policy of arXiv:2104.05509).

FLOP estimators cost out the client work the federated loop actually
runs: a fused gradient+FIM pass (Algorithm 1's ClientUpdate) or E epochs
of local SGD (FedAvg/FedDANE/FedOVA).  The usual dense-network
accounting applies: forward ≈ 2·P FLOPs per example, backward ≈ 2×
forward, and the per-example Fisher diagonal an extra squared-gradient
pass.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DeviceConfig:
    flops_per_s_mean: float = 5e9    # fleet-mean effective throughput
    flops_per_s_sigma: float = 0.5   # lognormal sigma (0 = homogeneous)
    joules_per_flop: float = 2e-10   # compute energy (~0.2 nJ/FLOP, mobile SoC)
    battery_j: float = float("inf")  # per-client energy budget
    idle_power_w: float = 0.0        # drain while waiting at the sync-round
                                     # barrier for stragglers (0 = ignore)


def flops_grad_fim(n_params: int, n_examples: int) -> float:
    """One full-batch gradient + Fisher-diagonal pass (Alg. 1 line 3-4):
    forward 2P + backward 4P + per-example squared-grad pass 2P."""
    return 8.0 * float(n_params) * float(n_examples)


def flops_local_sgd(n_params: int, n_examples: int, epochs: int) -> float:
    """E epochs of minibatch SGD: 6P per example per epoch."""
    return 6.0 * float(n_params) * float(n_examples) * float(max(epochs, 1))


def draw_flops_per_s(cfg: DeviceConfig, num_clients: int,
                     rng: np.random.Generator) -> np.ndarray:
    """Per-client effective throughput (lognormal straggler spread) —
    the array-state constructor shared by :class:`DeviceFleet` and the
    fleet engine's :class:`~repro.edge.fleet.FleetState` (identical rng
    call, so both paths draw identical populations from the same seed)."""
    mu = np.log(cfg.flops_per_s_mean)
    if cfg.flops_per_s_sigma > 0:
        return rng.lognormal(mu, cfg.flops_per_s_sigma, num_clients)
    return np.full(num_clients, cfg.flops_per_s_mean)


class DeviceFleet:
    """Per-client compute rates, energy rates, and mutable batteries."""

    def __init__(self, cfg: DeviceConfig, num_clients: int, seed: int = 0):
        self.cfg = cfg
        self.num_clients = num_clients
        rng = np.random.default_rng(seed)
        self.flops_per_s = draw_flops_per_s(cfg, num_clients, rng)
        self.battery_j = np.full(num_clients, float(cfg.battery_j))

    # ------------------------------------------------------------------
    def compute_time_s(self, flops: float, clients) -> np.ndarray:
        c = np.asarray(clients, dtype=int)
        return float(flops) / np.maximum(self.flops_per_s[c], 1.0)

    def compute_energy_j(self, flops: float, clients) -> np.ndarray:
        c = np.asarray(clients, dtype=int)
        return np.full(c.shape, float(flops) * self.cfg.joules_per_flop)

    def spend(self, clients, joules) -> None:
        """Drain batteries (elementwise); floors at 0."""
        c = np.asarray(clients, dtype=int)
        self.battery_j[c] = np.maximum(
            self.battery_j[c] - np.asarray(joules, dtype=float), 0.0)

    def alive(self, clients=None) -> np.ndarray:
        """Clients with battery remaining (bool mask or filtered ids)."""
        if clients is None:
            return self.battery_j > 0.0
        c = np.asarray(clients, dtype=int)
        return c[self.battery_j[c] > 0.0]
