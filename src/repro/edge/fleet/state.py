"""FleetState: the population as struct-of-arrays.

A thin owner of the same array state the dict path keeps inside
:class:`~repro.edge.channel.Channel` and
:class:`~repro.edge.device.DeviceFleet` — SNR shadowing, per-round
fades, compute rates, batteries — plus the busy mask the async tail
maintains.  ``draw`` uses the exact rng stream layout of
``EdgeRuntime`` (channel at seed+1, devices at seed+2), so a FleetState
and a runtime built from the same seed hold bit-identical populations;
``from_runtime`` wraps a live runtime's state without re-drawing.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.edge.channel import Channel, ChannelConfig
from repro.edge.device import DeviceConfig, DeviceFleet


@dataclass
class FleetState:
    """Struct-of-arrays view of one simulated population."""
    channel: Channel
    fleet: DeviceFleet
    busy: np.ndarray = field(default=None)  # (N,) async in-flight mask

    def __post_init__(self):
        if self.busy is None:
            self.busy = np.zeros(self.population, dtype=bool)

    @classmethod
    def draw(cls, channel_cfg: ChannelConfig, device_cfg: DeviceConfig,
             population: int, seed: int = 0) -> "FleetState":
        """Draw a fresh population with EdgeRuntime's stream layout."""
        return cls(Channel(channel_cfg, population, seed=seed + 1),
                   DeviceFleet(device_cfg, population, seed=seed + 2))

    @classmethod
    def from_runtime(cls, runtime) -> "FleetState":
        """Wrap a live :class:`~repro.edge.runtime.EdgeRuntime`'s state
        (shared arrays, not copies — mutations are visible both ways)."""
        st = cls(runtime.channel, runtime.fleet)
        if runtime.busy:
            st.busy[sorted(runtime.busy)] = True
        return st

    # ------------------------------------------------------------------
    @property
    def population(self) -> int:
        return self.channel.num_clients

    @property
    def snr_round(self) -> np.ndarray:
        """(N,) this round's effective per-client SNR (post-fading)."""
        return self.channel._snr_round

    @property
    def flops_per_s(self) -> np.ndarray:
        return self.fleet.flops_per_s

    @property
    def battery_j(self) -> np.ndarray:
        return self.fleet.battery_j

    def sample(self) -> None:
        """Re-draw this round's fading over the whole population (one
        vectorized rng call — the same stream the dict path consumes)."""
        self.channel.sample()

    def alive_mask(self) -> np.ndarray:
        """(N,) selectable clients: battery left and not in flight."""
        return (self.fleet.battery_j > 0.0) & ~self.busy

    def spend(self, clients, joules) -> None:
        self.fleet.spend(clients, joules)
