"""FleetEngine: drive sync rounds over a 10⁵–10⁶-client population.

A standalone round driver for mega-scale edge simulation — the same
round semantics as ``EdgeRuntime`` + ``FederatedRun``'s edge loop, with
a fixed synthetic payload (``up_bytes`` wire bytes up, ``down_bytes``
broadcast down, ``flops`` of client work) instead of a training loop:

  sample fading → filter dead clients → cohort draw → width allocation
  (the policy's vectorized form) → realized finish → deadline verdict →
  capped barrier / energy / battery update.

Backends:
  * ``"exact"`` — delegates to an internal :class:`EdgeRuntime` with the
    fleet fast path forced on (``EdgeConfig.fleet="on"``), so every
    number is bit-identical to what a full federated run would record.
  * ``"jit"`` — struct-of-arrays state (:class:`FleetState`) plus the
    fused x64 lax kernels in :mod:`repro.edge.fleet.kernel`.  The rng
    streams are laid out exactly as ``EdgeRuntime``'s (channel at
    seed+1, devices at seed+2, cohort draws at seed+3), so cohorts,
    populations, and fading draws match the exact backend bitwise;
    float results agree up to XLA reassociation.  Star topology only
    (tree aggregation stays on the numpy path).

Both backends advance a plain scalar clock — the ``EventClock`` heap is
reserved for the async tail, which the engine does not simulate.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.edge.allocation import FleetRoundState, make_policy
from repro.edge.fleet.state import FleetState
from repro.edge.runtime import EdgeConfig, EdgeRuntime
from repro.edge.scenario import make_scenario


class FleetEngine:
    """Sync-round driver over one population (see module docstring)."""

    def __init__(self, cfg: EdgeConfig, population: int, *,
                 up_bytes: float, flops: float, down_bytes: float = 0.0,
                 seed: int = 0, backend: str = None):
        backend = cfg.fleet_backend if backend is None else backend
        if backend not in ("exact", "jit"):
            raise ValueError(f"FleetEngine backend must be 'exact' or "
                             f"'jit', got {backend!r}")
        if population < 1:
            raise ValueError(f"population must be >= 1, got {population}")
        self.cfg = dataclasses.replace(cfg, mode="sync", fleet="on",
                                       fleet_backend=backend)
        self.population = int(population)
        self.up_bytes = float(up_bytes)
        self.down_bytes = float(down_bytes)
        self.flops = float(flops)
        self.backend = backend
        self.last_decision = None   # the most recent round's decision
        if backend == "exact":
            self._rt = EdgeRuntime(self.cfg, self.population, seed=seed)
            self.state = FleetState.from_runtime(self._rt)
            self.policy = self._rt.policy
            self.rng = self._rt.rng
            return
        if cfg.channel.topology != "star":
            raise ValueError(
                "FleetEngine backend='jit' implements star topology only "
                "(tree in-network aggregation needs the numpy path); use "
                "backend='exact'")
        self._rt = None
        s = seed + cfg.seed
        self.state = FleetState.draw(cfg.channel, cfg.device,
                                     self.population, seed=s)
        self.rng = np.random.default_rng(s + 3)
        self.policy = make_policy(
            cfg.scheduler, deadline_s=cfg.deadline_s,
            min_clients=cfg.min_clients, battery_floor_j=cfg.battery_floor_j,
            round_budget_j=cfg.round_budget_j, ratio=cfg.adaptive_ratio,
            ratio_floor=cfg.adaptive_ratio_floor)
        if not getattr(self.policy, "vectorized", False):
            raise ValueError(
                f"policy {cfg.scheduler!r} has no vectorized form; use "
                f"backend='exact' (scalar fallback)")
        # scenario stream at s+4, as in EdgeRuntime — same seed, same
        # population, so the availability/fault draws match the exact
        # backend's (bitwise for processes that do not read the clock)
        self.scenario = (make_scenario(cfg.scenario, self.population,
                                       seed=s + 4)
                         if cfg.scenario else None)
        self._unavailable = 0
        self._realloc_rounds = 0
        self._clock_s = 0.0
        self._energy_j = 0.0
        self._history: list[dict] = []
        self._dropped = 0
        self._dl_dropped = 0
        self._drop_reasons: dict[str, int] = {}
        self._phase = {"downlink": 0.0, "barrier": 0.0, "drain": 0.0}

    # ------------------------------------------------------------------
    @property
    def clock_s(self) -> float:
        return self._rt.clock.now if self._rt is not None else self._clock_s

    @property
    def energy_j(self) -> float:
        return self._rt.energy_j if self._rt is not None else self._energy_j

    @property
    def history(self) -> list[dict]:
        return self._rt.history if self._rt is not None else self._history

    @property
    def dropped_total(self) -> int:
        return (self._rt.dropped_total if self._rt is not None
                else self._dropped)

    @property
    def deadline_dropped_total(self) -> int:
        return (self._rt.deadline_dropped_total if self._rt is not None
                else self._dl_dropped)

    # ------------------------------------------------------------------
    def run_round(self, k: int) -> dict:
        """One sync round with a cohort target of ``k``; returns the same
        record dict ``EdgeRuntime._record`` appends to ``history``."""
        if self._rt is not None:
            rt = self._rt

            def wire(codec=None):
                return (self.up_bytes, 0.0)

            _, est, dec = rt.decide(k, np.arange(self.population), wire,
                                    self.flops, summable=True)
            rec = rt.finish_round_sync(est, self.up_bytes, self.down_bytes,
                                       aggregatable=True)
            self.last_decision = dec
            return rec
        return self._run_round_jit(k)

    def run(self, rounds: int, k: int) -> list[dict]:
        return [self.run_round(k) for _ in range(int(rounds))]

    # ------------------------------------------------------------------
    def _run_round_jit(self, k: int) -> dict:
        from repro.edge.fleet import kernel  # late: jax only on this path

        cfg, st = self.cfg, self.state
        st.sample()
        eligible = np.arange(self.population)
        eff = None
        if self.scenario is not None:
            # same sequencing as EdgeRuntime._begin_scenario_round:
            # availability filters the eligible set pre-policy, faults
            # are held for the realized side below
            eff = self.scenario.begin_round(len(self._history),
                                            self._clock_s, st.battery_j)
            n_fault = int(eff.fault_off.sum())
            n_proc = int((eff.proc_off & ~eff.fault_off).sum())
            self._unavailable += n_proc + n_fault
            if n_proc:
                self._drop_reasons["unavailable"] = (
                    self._drop_reasons.get("unavailable", 0) + n_proc)
            if n_fault:
                self._drop_reasons["fault"] = (
                    self._drop_reasons.get("fault", 0) + n_fault)
            eligible = eligible[eff.available]
        alive = eligible[st.alive_mask()[eligible]]
        if alive.size == 0:
            self.last_decision = None
            return self._record(0.0, 0.0, 0, 0, None)
        # budget_hz: no async holds in a sync-only engine
        budget = (float(cfg.bandwidth_budget_hz)
                  if cfg.bandwidth_budget_hz > 0
                  else float(max(k, 1)) * cfg.channel.bandwidth_hz)
        mult = None
        fl_alive = self.flops
        if eff is not None and eff.has_shedding:
            mult = eff.workload_frac[alive]
            fl_alive = self.flops * mult
        t_comp = fl_alive / np.maximum(st.flops_per_s[alive], 1.0)
        fstate = FleetRoundState(
            k=k, ids=alive, t_comp_s=t_comp,
            spectral_eff=st.channel.spectral_efficiency(alive),
            budget_hz=budget, rng=self.rng, up_bits=8.0 * self.up_bytes,
            payload_mult=mult, backend="jit")
        dec = self.policy.decide_vectorized(fstate)
        dec.validate()
        self.last_decision = dec
        if dec.n_excluded:
            self._dropped += dec.n_excluded
            key = f"excluded:{dec.excluded_bucket or 'policy'}"
            self._drop_reasons[key] = (self._drop_reasons.get(key, 0)
                                       + dec.n_excluded)
        if dec.n_selected == 0:
            return self._record(0.0, 0.0, 0, 0, None)
        sel = alive[dec.positions]
        d_eff = np.minimum(dec.deadline_s_arr, cfg.enforce_deadline_s)
        # realized-side faults (EdgeRuntime._realized_faults): the grant
        # was provisioned against the clean draw; the round runs on the
        # degraded channel / throttled compute
        snr_sel = st.snr_round[sel]
        fl_sel = (fl_alive[dec.positions] if mult is not None
                  else self.flops)
        t_comp_sel = t_comp[dec.positions]
        if eff is not None and eff.has_channel_fault:
            snr_sel = snr_sel * eff.snr_scale[sel]
        if eff is not None and eff.has_compute_fault:
            fl_sel = fl_sel * eff.compute_scale[sel]
            t_comp_sel = fl_sel / np.maximum(st.flops_per_s[sel], 1.0)
        up_air = (self.up_bytes if mult is None
                  else self.up_bytes * mult[dec.positions])
        out = kernel.sync_round_jit(
            dec.bandwidth_hz_arr, snr_sel, t_comp_sel, up_air,
            fl_sel * cfg.device.joules_per_flop, d_eff,
            cfg.deadline_tolerance_s, cfg.channel.tx_power_w,
            max(cfg.channel.server_rate_bps, 1e-6),
            cfg.device.idle_power_w, st.battery_j[sel],
            bill_bytes=self.up_bytes, reallocate=cfg.reallocate)
        st.fleet.battery_j[sel] = out["battery_j"]
        if out["n_realloc"]:
            self._realloc_rounds += 1
        n_drop = out["n_dropped"]
        if n_drop:
            self._dl_dropped += n_drop
            self._drop_reasons["deadline_cutoff"] = (
                self._drop_reasons.get("deadline_cutoff", 0) + n_drop)
        t_down = st.channel.downlink_time_s(self.down_bytes)
        self._phase["downlink"] += t_down
        self._phase["barrier"] += out["barrier_s"]
        self._phase["drain"] += max(out["t_round_s"] - out["barrier_s"], 0.0)
        return self._record(t_down + out["t_round_s"], out["spend_j"],
                            dec.n_selected - n_drop, n_drop,
                            out["barrier_s"])

    def _record(self, wall_s: float, energy_j: float, cohort: int,
                dropped: int, barrier_s) -> dict:
        self._clock_s += wall_s
        self._energy_j += energy_j
        rec = {"wall_s": float(wall_s), "clock_s": self._clock_s,
               "energy_j": self._energy_j, "cohort": int(cohort),
               "dropped": int(dropped)}
        if barrier_s is not None:
            rec["barrier_s"] = float(barrier_s)
        self._history.append(rec)
        return rec

    def summary(self) -> dict:
        if self._rt is not None:
            return self._rt.summary()
        return {
            "wall_clock_s": self._clock_s,
            "energy_j": self._energy_j,
            "rounds": len(self._history),
            "dropped_total": self._dropped,
            "deadline_dropped_total": self._dl_dropped,
            "depleted_clients": int((self.state.battery_j <= 0.0).sum()),
            "in_flight": 0,
            "drop_reasons": dict(self._drop_reasons),
            "phase_s": dict(self._phase),
            "unavailable_total": self._unavailable,
            "realloc_rounds": self._realloc_rounds,
        }
