"""Jitted x64 lax kernels for the fleet fast path.

Each kernel mirrors, op for op, one vectorized-numpy reference in
``repro.edge.allocation`` / ``EdgeRuntime.finish_round_sync``:

  * :func:`bandwidth_opt_widths_jit` — the barrier bisection of
    ``allocation.bandwidth_opt_widths`` (need(T) decreasing in T) as a
    branchless ``lax.while_loop`` doubling + ``fori_loop`` bisection.
  * :func:`energy_opt_widths_jit` — the KKT-λ bisection of
    ``allocation.energy_opt_widths`` (floored Σ widths increasing in λ).
  * :func:`sync_round_jit` — one fused sync round past the decision:
    Shannon capacity at the granted widths → realized finish → deadline
    verdict (drop mask + on-air byte fractions) → capped barrier /
    server-drain / idle energy / battery update.  Star topology (the
    tree aggregation path stays on the numpy backend).

Numerics: everything runs under ``jax.experimental.enable_x64`` so
dtypes match the float64 references; results still differ from numpy by
float-op reassociation (XLA reductions are not numpy's pairwise sums,
``jnp.log2`` can be 1 ULP off ``np.log2``), which is why the jit
backend's contract is allclose-plus-identical-discrete-decisions, not
bitwise (``tests/test_fleet.py``), while the "exact" backend is bitwise.

The bisections are deliberately fixed-trip (``BISECT_ITERS``), not
tolerance-terminated: a fixed trip count keeps the loop shape static
for XLA and matches the scalar reference's iteration-for-iteration
bracket sequence.
"""
from __future__ import annotations

from functools import partial

import numpy as np

from repro.edge.allocation import BISECT_EPS, BISECT_ITERS

try:  # the jit backend is optional — the exact numpy backend never needs jax
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental import enable_x64
    HAVE_JAX = True
except Exception:  # pragma: no cover - jax is baked into this toolchain
    jax = jnp = lax = enable_x64 = None
    HAVE_JAX = False

_GROW_MAX = 200   # bracket-doubling cap, as in bandwidth_opt_widths


def _require_jax() -> None:
    if not HAVE_JAX:  # pragma: no cover
        raise RuntimeError(
            "EdgeConfig.fleet_backend='jit' needs jax; use the 'exact' "
            "backend (bit-identical, numpy-only) instead")


if HAVE_JAX:

    @partial(jax.jit, static_argnames=("iters",))
    def _bw_widths(bits, s, tc, budget, iters):
        def need(T):
            gap = T - tc
            safe = jnp.where(gap <= 0.0, 1.0, gap)
            return jnp.where(jnp.any(gap <= 0.0), jnp.inf,
                             jnp.sum(bits / (s * safe)))

        lo = jnp.max(tc)                     # infeasible: zero air time
        hi = jnp.maximum(2.0 * lo, lo + 1e-6)

        def grow_cond(carry):
            h, i = carry
            return (need(h) > budget) & (i < _GROW_MAX)

        def grow(carry):
            h, i = carry
            return h * 2.0, i + 1

        hi, _ = lax.while_loop(grow_cond, grow, (hi, 0))

        def bis(_, bracket):
            b_lo, b_hi = bracket
            mid = 0.5 * (b_lo + b_hi)
            ok = need(mid) <= budget
            return jnp.where(ok, b_lo, mid), jnp.where(ok, mid, b_hi)

        _, hi = lax.fori_loop(0, iters, bis, (lo, hi))
        w = bits / (s * jnp.maximum(hi - tc, BISECT_EPS))
        return w * (budget / jnp.sum(w))     # hand back the bracket slack

    @partial(jax.jit, static_argnames=("iters",))
    def _energy_widths(c, w_min, feas, budget, iters):
        n = c.shape[0]
        w_floor = jnp.where(feas, w_min, budget / n)
        total_floor = jnp.sum(w_floor)
        w_floor = jnp.where(total_floor > budget,
                            w_floor * (budget / total_floor), w_floor)
        sq = jnp.sqrt(jnp.maximum(c, 0.0))
        ssq = jnp.sum(sq)

        def floored(lam):
            return jnp.sum(jnp.maximum(w_floor, lam * sq))

        def bis(_, bracket):
            b_lo, b_hi = bracket
            mid = 0.5 * (b_lo + b_hi)
            ok = floored(mid) <= budget
            return jnp.where(ok, mid, b_lo), jnp.where(ok, b_hi, mid)

        lam, _ = lax.fori_loop(0, iters, bis,
                               (0.0, budget / jnp.maximum(ssq, 1e-300)))
        w = jnp.where(ssq > 0.0, jnp.maximum(w_floor, lam * sq),
                      jnp.maximum(w_floor, budget / n))
        tot = jnp.sum(w)
        return jnp.where(tot > 0.0, w * (budget / tot),
                         jnp.full_like(w, budget / n))

    def _realloc_finish(f, tc, d, w, dropped):
        """Jit twin of :func:`repro.edge.events.reallocated_finish` in
        fixed shapes: survivors absorb the width each dropped client
        frees at its cutoff.  Non-dropped entries take a finite sentinel
        cut far beyond any real time (inf would poison the segment
        integrals), so the sorted breakpoint sweep keeps a static
        shape."""
        surv = ~dropped
        w_b = jnp.broadcast_to(w, f.shape)
        w_surv = jnp.sum(jnp.where(surv, w_b, 0.0))
        ok = (jnp.sum(dropped) > 0) & (w_surv > 0.0)
        w_safe = jnp.where(ok, w_surv, 1.0)
        big = 1e300
        cut = jnp.where(dropped, jnp.minimum(f, d), big)
        order = jnp.argsort(cut)
        ts = cut[order]
        c_seg = 1.0 + (jnp.cumsum(jnp.where(dropped, w_b, 0.0)[order])
                       / w_safe)
        integ = jnp.concatenate(
            [ts[:1], ts[0] + jnp.cumsum(c_seg[:-1] * jnp.diff(ts))])

        def cum(x):
            k = jnp.searchsorted(ts, x, side="right") - 1
            kk = jnp.clip(k, 0, ts.shape[0] - 1)
            return jnp.where(k >= 0,
                             integ[kk] + c_seg[kk] * (x - ts[kk]), x)

        target = cum(tc) + (f - tc)
        j = jnp.searchsorted(integ, target, side="right") - 1
        jj = jnp.clip(j, 0, ts.shape[0] - 1)
        fin = jnp.where(j >= 0,
                        ts[jj] + (target - integ[jj]) / c_seg[jj], target)
        fin = jnp.minimum(fin, f)      # never-later pin, as in numpy
        return jnp.where(ok & surv, fin, f)

    @partial(jax.jit, static_argnames=("reallocate",))
    def _sync_round(w, snr, t_comp, up_bytes, e_comp, deadline, tol,
                    tx_power, srv_rate, idle_power, battery, bill_bytes,
                    reallocate):
        # capacity at the granted widths (Channel.set_bandwidth), clamped
        # as in uplink_time_s
        rate = jnp.maximum(w * jnp.log2(1.0 + snr), 1e-6)
        t_up = 8.0 * up_bytes / rate
        time_s = t_comp + t_up
        e_tx = tx_power * t_up
        energy = e_comp + e_tx
        # deadline verdict (enforce_deadlines): the drop mask and the
        # byte fraction on the air before each cutoff
        dropped = time_s > deadline + tol
        air = jnp.clip(deadline - t_comp, 0.0, None)
        frac = jnp.where(
            dropped,
            jnp.where(t_up > 0.0,
                      jnp.minimum(air / jnp.maximum(t_up, 1e-300), 1.0),
                      0.0),
            1.0)
        # mid-round re-allocation (EdgeConfig.reallocate): each dropped
        # straggler's freed width re-lands on the surviving uploads from
        # its cutoff on, pulling survivor finishes — and the barrier —
        # earlier.  Drops, fractions and billing above are already fixed
        # at the granted widths, so the ledger/verdict is untouched.
        e_tx_plan = e_tx
        n_realloc = jnp.asarray(0)
        rate_eff = rate
        if reallocate:
            new_t = _realloc_finish(time_s, t_comp, deadline, w, dropped)
            n_realloc = jnp.sum((~dropped) & (new_t < time_s))
            # survivors absorbed the freed width mid-round: the realized
            # effective rate (same bits, less air time) is what the
            # server-drain air-time floor below must see — mirrors the
            # rate rescale in EdgeRuntime._maybe_reallocate
            air_old = time_s - t_comp
            air_new = new_t - t_comp
            improved = (~dropped) & (new_t < time_s)
            scale = jnp.where(improved & (air_new > 0.0),
                              air_old / jnp.maximum(air_new, 1e-300), 1.0)
            rate_eff = rate * scale
            e_tx = jnp.where(dropped, e_tx,
                             e_tx - tx_power * (time_s - new_t))
            time_s = new_t
        # star-topology finish (finish_round_sync): enforced barrier,
        # then the shared server slice drains the on-air bytes
        active = jnp.minimum(time_s, deadline)
        barrier = jnp.max(active)
        billed = bill_bytes * frac
        per = 8.0 * billed / jnp.maximum(rate_eff, 1e-6)
        t_round = jnp.maximum(
            barrier,
            jnp.maximum(jnp.max(per), 8.0 * jnp.sum(billed) / srv_rate))
        # capped battery drain (DeadlineVerdict.capped_spend_j) + idle
        # drain until the round closes
        idle = jnp.maximum(t_round - active, 0.0)
        e_comp_v = jnp.maximum(energy - e_tx_plan, 0.0)
        comp_frac = jnp.minimum(1.0,
                                deadline / jnp.maximum(t_comp, 1e-300))
        spend = e_comp_v * comp_frac + e_tx * frac + idle_power * idle
        battery_new = jnp.maximum(battery - spend, 0.0)
        return (barrier, t_round, jnp.sum(spend), jnp.sum(dropped),
                battery_new, frac, n_realloc)


def bandwidth_opt_widths_jit(bits, s, tc, budget: float,
                             iters: int = BISECT_ITERS) -> np.ndarray:
    """Jitted twin of :func:`repro.edge.allocation.bandwidth_opt_widths`."""
    _require_jax()
    with enable_x64():
        w = _bw_widths(jnp.asarray(bits, jnp.float64),
                       jnp.asarray(s, jnp.float64),
                       jnp.asarray(tc, jnp.float64),
                       jnp.float64(budget), int(iters))
    return np.asarray(w, dtype=np.float64)


def energy_opt_widths_jit(c, w_min, feas, budget: float,
                          iters: int = BISECT_ITERS) -> np.ndarray:
    """Jitted twin of :func:`repro.edge.allocation.energy_opt_widths`."""
    _require_jax()
    with enable_x64():
        w = _energy_widths(jnp.asarray(c, jnp.float64),
                           jnp.asarray(w_min, jnp.float64),
                           jnp.asarray(feas, bool),
                           jnp.float64(budget), int(iters))
    return np.asarray(w, dtype=np.float64)


def sync_round_jit(w, snr, t_comp, up_bytes, e_comp, deadline,
                   tol: float, tx_power: float, srv_rate: float,
                   idle_power: float, battery, bill_bytes=None,
                   reallocate: bool = False) -> dict:
    """One fused star-topology sync round past the decision.

    All per-client arrays align with the selected cohort; ``up_bytes``
    may be per-client (scenario workload shedding).  ``bill_bytes``
    (default ``up_bytes``) are the bytes the ledger meters — under
    shedding the plan is billed in full while the air time runs on the
    shed payload, exactly as ``finish_round_sync`` does.  ``reallocate``
    (static) re-lands freed straggler width on survivors mid-round.
    Returns a dict of host values: ``barrier_s``, ``t_round_s`` (barrier
    + server drain, pre-downlink), ``spend_j`` (cohort total incl. idle
    drain), ``n_dropped``, ``battery_j`` (updated per-client),
    ``tx_frac``, ``n_realloc`` (survivors whose finish moved earlier).
    """
    _require_jax()
    if bill_bytes is None:
        bill_bytes = up_bytes
    with enable_x64():
        out = _sync_round(
            jnp.asarray(w, jnp.float64), jnp.asarray(snr, jnp.float64),
            jnp.asarray(t_comp, jnp.float64),
            jnp.asarray(up_bytes, jnp.float64),
            jnp.asarray(e_comp, jnp.float64),
            jnp.asarray(deadline, jnp.float64), jnp.float64(tol),
            jnp.float64(tx_power), jnp.float64(srv_rate),
            jnp.float64(idle_power), jnp.asarray(battery, jnp.float64),
            jnp.asarray(bill_bytes, jnp.float64), bool(reallocate))
    barrier, t_round, spend, n_dropped, battery_new, frac, n_realloc = out
    return {"barrier_s": float(barrier), "t_round_s": float(t_round),
            "spend_j": float(spend), "n_dropped": int(n_dropped),
            "battery_j": np.asarray(battery_new, dtype=np.float64),
            "tx_frac": np.asarray(frac, dtype=np.float64),
            "n_realloc": int(n_realloc)}
