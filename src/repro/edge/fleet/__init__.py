"""repro.edge.fleet — struct-of-arrays mega-scale fleet engine.

The dict-per-client hot path in :class:`repro.edge.runtime.EdgeRuntime`
is interpreter-bound past ~10⁴ clients.  This subsystem keeps the same
round semantics over arrays:

  * :class:`FleetState` — the population as struct-of-arrays (static SNR
    shadowing, per-round fades, compute rates, batteries, busy/alive
    masks), drawn by the SAME constructors and rng streams as the dict
    path (`edge.channel.draw_snr_lin`, `edge.device.draw_flops_per_s`).
  * :mod:`kernel` — jitted x64 lax kernels: the branchless while-loop
    bisections mirroring the shared scalar cores in
    ``edge.allocation`` (``bandwidth_opt_widths`` / ``energy_opt_widths``)
    plus one fused sync-round kernel (capacity → realized finish →
    deadline verdict → capped barrier/energy/battery update).
  * :class:`FleetEngine` — a standalone sync-round driver over a
    population: ``backend="exact"`` delegates to an ``EdgeRuntime`` with
    the fleet fast path on (bit-identical to the dict path by
    construction), ``backend="jit"`` runs the fused kernels (equal up to
    float-op reassociation; identical rng streams, so cohorts and
    typically drop sets match the exact backend).

`EdgeRuntime` itself engages the array fast path automatically
(``EdgeConfig.fleet``) — the engine here is for driving rounds at
10⁵–10⁶ clients without a federated training loop attached, e.g.
``benchmarks/fleet_bench.py``.  The ``EventClock`` stays reserved for
the async tail; sync fleet rounds advance a plain accumulator.
"""
from repro.edge.fleet.engine import FleetEngine
from repro.edge.fleet.state import FleetState

__all__ = ["FleetEngine", "FleetState"]
