"""Per-client resource allocation for the edge runtime.

The paper's resource-constrained FEEL formulation is about *how much* of
the wireless budget each client gets, not just *who* transmits.  An
``AllocationPolicy`` therefore returns a :class:`RoundDecision` — per
selected client an :class:`Allocation` (uplink ``bandwidth_hz`` drawn
from a shared round budget, an optional per-client upload codec, and a
deadline) plus the ids it deliberately excluded, with reasons.  Client
*selection* (the old ``Scheduler.select`` API) is the degenerate case
where every selected client gets an equal split of the budget.

Policies (register your own with :func:`register`):
  * uniform               — sample k uniformly (the paper's protocol),
                            equal bandwidth split.
  * deadline              — uniform proposal, then exclude clients whose
                            predicted finish exceeds the round deadline
                            (straggler dropping; the quantile-barrier
                            view of synchronous FEEL); equal split.
  * energy_threshold      — exclude clients whose battery is below a
                            floor or whose round energy exceeds a budget,
                            à la the threshold-based exclusion design of
                            arXiv:2104.05509 (exclusion == an allocation
                            of zero); equal split.
  * capacity_proportional — sample with probability ∝ predicted capacity
                            1/t_k, the resource-allocation reading of
                            arXiv:1910.13067; equal split.
  * bandwidth_opt         — uniform cohort, then minimize the sync-round
                            barrier max_k t_k subject to Σ_k W_k ≤ budget
                            by bisection on the arXiv:1910.13067 capacity
                            form t_k = t_comp,k + bits / (W_k·log2(1+γ_k)).
  * energy_opt            — the dual: minimize Σ_k E_k subject to every
                            selected client finishing within the round
                            deadline (and Σ_k W_k ≤ budget), by bisection
                            on the same capacity form; feasibility-aware
                            (clients that cannot meet the deadline at any
                            width within budget are excluded, with
                            reasons).
  * adaptive_codec        — uniform cohort + equal split, but each
                            client's top-k upload ratio is scheduled from
                            its sampled channel rate (fast links send
                            denser payloads); summable plans only.

Every policy sees the same :class:`RoundState`: the eligible ids with a
per-client :class:`ClientEstimate` under a *nominal* equal split, the
compute-only times, this round's spectral efficiencies, the shared
bandwidth budget, and the upload wire format.  Bandwidth-only policies
never change WHAT is transmitted — CommLedger bytes are allocation-
independent; per-client codecs change bytes only through the codec's
``wire_bytes``, and the ledger still equals the plan per client.
"""
from __future__ import annotations

import inspect
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Shared bisection core (the ONE scalar reference the fleet kernel mirrors)
# ---------------------------------------------------------------------------
# Both optimizing policies search a monotone scalar -> Σ widths map against
# the shared budget: bandwidth_opt bisects the barrier T (Σ W_k(T)
# decreasing in T), energy_opt the KKT multiplier λ (Σ max(floor, λ·√c)
# increasing in λ).  They share one iteration count and one width/slack
# tolerance so the vectorized fleet kernel (repro.edge.fleet.kernel) has
# exactly one reference to mirror.
BISECT_ITERS = 64       # bisection refinement steps (both policies)
BISECT_EPS = 1e-12      # width / budget slack floor shared by both searches


def bisect_budget(fn: Callable[[float], float], lo: float, hi: float,
                  budget: float, iters: int = BISECT_ITERS,
                  increasing: bool = False) -> float:
    """Bisect a monotone ``fn: scalar -> Σ widths`` against ``budget`` and
    return the feasible endpoint (``fn(x) <= budget``).  ``increasing``
    states fn's direction: False (bandwidth_opt's barrier T — feasible at
    large T, returns the shrunken hi), True (energy_opt's λ — feasible at
    small λ, returns the grown lo)."""
    lo, hi = float(lo), float(hi)
    for _ in range(int(iters)):
        mid = 0.5 * (lo + hi)
        if fn(mid) <= budget:
            lo, hi = (mid, hi) if increasing else (lo, mid)
        else:
            lo, hi = (lo, mid) if increasing else (mid, hi)
    return lo if increasing else hi


def bandwidth_opt_widths(bits: np.ndarray, s: np.ndarray, tc: np.ndarray,
                         budget: float,
                         iters: int = BISECT_ITERS) -> np.ndarray:
    """Barrier-minimizing subchannel widths on the arXiv:1910.13067
    capacity form (the bandwidth_opt objective), vectorized over the
    cohort: W_k(T) = bits_k / (s_k · (T − t_comp,k)) with the minimal
    feasible barrier T* pinned by Σ_k W_k(T) = budget; the final
    bracket's slack is handed back pro rata.  This is the scalar
    reference the jitted fleet kernel mirrors op-for-op."""
    bits = np.asarray(bits, dtype=float)
    s = np.asarray(s, dtype=float)
    tc = np.asarray(tc, dtype=float)
    budget = float(budget)

    def need(T: float) -> float:
        gap = T - tc
        if np.any(gap <= 0.0):
            return float("inf")
        return float((bits / (s * gap)).sum())

    lo = float(tc.max())                  # infeasible: zero air time
    hi = max(2.0 * lo, lo + 1e-6)
    for _ in range(200):
        if need(hi) <= budget:
            break
        hi *= 2.0
    hi = bisect_budget(need, lo, hi, budget, iters, increasing=False)
    w = bits / (s * np.maximum(hi - tc, BISECT_EPS))
    return w * (budget / w.sum())         # hand back the bracket slack


def deadline_min_widths(bits: np.ndarray, s: np.ndarray, tc: np.ndarray,
                        deadline_s: float) -> tuple[np.ndarray, np.ndarray]:
    """(c_k, W_min,k) on the capacity form: c_k = bits_k / s_k is the
    Hz·s each upload needs, W_min,k the narrowest subchannel that still
    meets the deadline (inf where compute alone busts it, 0 where there
    is nothing to send)."""
    c = np.asarray(bits, dtype=float) / np.asarray(s, dtype=float)
    tc = np.asarray(tc, dtype=float)
    gap = float(deadline_s) - tc
    w_min = np.where(gap > 0.0, c / np.maximum(gap, 1e-300), np.inf)
    return c, np.where((c <= 0.0) & (gap > 0.0), 0.0, w_min)


def feasible_packing(w_min: np.ndarray, tc: np.ndarray,
                     budget: float) -> np.ndarray:
    """Greedy ascending-W_min packing into the budget (ties broken by
    compute time) as a vectorized prefix-sum: sorted ascending, every
    accepted client is a prefix of the finite part, so the sequential
    ``used + w_min <= budget`` test is exactly the running cumsum."""
    w_min = np.asarray(w_min, dtype=float)
    order = np.lexsort((np.asarray(tc, dtype=float), w_min))
    used = np.cumsum(w_min[order])
    feas = np.zeros(len(w_min), dtype=bool)
    feas[order] = np.isfinite(w_min[order]) & (
        used <= float(budget) * (1 + BISECT_EPS))
    return feas


def energy_opt_widths(c: np.ndarray, w_min: np.ndarray, feas: np.ndarray,
                      budget: float, iters: int = BISECT_ITERS
                      ) -> np.ndarray:
    """Energy-minimizing KKT widths W_k = max(floor_k, √c_k / λ) with λ
    pinned by the budget — the energy_opt allocate stage, vectorized.
    ``feas`` marks clients whose W_min fits (floor = W_min); the rest
    (force-keeps) floor at the equal split.  The scalar reference the
    jitted fleet kernel mirrors op-for-op."""
    c = np.asarray(c, dtype=float)
    w_min = np.asarray(w_min, dtype=float)
    budget = float(budget)
    n = len(c)
    w_floor = np.where(feas, w_min, budget / n)
    total_floor = float(w_floor.sum())
    if total_floor > budget:
        w_floor = w_floor * (budget / total_floor)
    sq = np.sqrt(np.maximum(c, 0.0))
    if sq.sum() <= 0.0:                    # nothing to upload
        w = np.maximum(w_floor, budget / n)
    else:
        def floored(lam: float) -> float:
            return float(np.maximum(w_floor, lam * sq).sum())

        lam = bisect_budget(floored, 0.0, budget / sq.sum(), budget, iters,
                            increasing=True)
        w = np.maximum(w_floor, lam * sq)
    tot = float(w.sum())
    if tot <= 0.0:
        return np.full(n, budget / n)
    return w * (budget / tot)              # hand back the bracket slack


# ---------------------------------------------------------------------------
# Estimates (moved from the retired edge/scheduler.py surface)
# ---------------------------------------------------------------------------
@dataclass
class ClientEstimate:
    """Predicted per-client round cost under current channel/fleet state."""
    clients: np.ndarray      # (n,) eligible ids
    time_s: np.ndarray       # (n,) predicted compute + uplink time
    energy_j: np.ndarray     # (n,) predicted compute + uplink energy
    battery_j: np.ndarray    # (n,) remaining budget

    def for_ids(self, ids) -> "ClientEstimate":
        pos = {int(c): i for i, c in enumerate(self.clients)}
        sel = []
        for i in ids:
            if int(i) not in pos:
                raise ValueError(
                    f"client id {int(i)} is not in this estimate's eligible "
                    f"set of {len(self.clients)} clients "
                    f"({np.sort(self.clients).tolist()})")
            sel.append(pos[int(i)])
        sel = np.asarray(sel, dtype=int)
        return ClientEstimate(self.clients[sel], self.time_s[sel],
                              self.energy_j[sel], self.battery_j[sel])


# ---------------------------------------------------------------------------
# The decision types
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Allocation:
    """One selected client's share of the round: an uplink subchannel
    width drawn from the shared budget, an optional per-client upload
    codec (None = the plan's / run's codec), and the finish deadline the
    policy holds it to — a *runtime contract*: a client whose realized
    finish (compute + uplink at this granted width) exceeds it is cut
    off at the barrier, its upload discarded and only the bytes on the
    air before the cutoff billed (inf = no deadline)."""
    bandwidth_hz: float
    codec: Any = None              # Optional[repro.fed.codecs.PayloadCodec]
    deadline_s: float = float("inf")


@dataclass
class RoundState:
    """Everything a policy may consult to decide one round.

    ``est`` covers the *eligible* (alive) clients, predicted under the
    nominal equal split ``budget_hz / k`` — so a pure selection policy
    reads it exactly as the old scheduler did.  ``wire_fn(codec|None)``
    answers "what does one client's upload cost on the wire under this
    codec override?" as ``(aggregatable_bytes, nonagg_bytes)``; policies
    never recompute plan bytes themselves."""
    k: int                          # target cohort size
    est: ClientEstimate             # eligible clients, nominal-split costs
    t_comp_s: np.ndarray            # (n,) compute-only share of est.time_s
    spectral_eff: np.ndarray        # (n,) bits/s/Hz under this round's fade
    budget_hz: float                # shared round uplink bandwidth budget
    rng: np.random.Generator
    codec: Any = None               # the run's base upload codec
    summable: bool = True           # plan.summable (gates codec overrides)
    wire_fn: Optional[Callable[[Any], tuple[float, float]]] = None
    payload_mult: Optional[np.ndarray] = None  # (n,) payloads per client
                                               # (duplicate cohort slots on
                                               # one device; None = 1 each)

    def mult(self) -> np.ndarray:
        if self.payload_mult is None:
            return np.ones(len(self.est.clients))
        return np.asarray(self.payload_mult, dtype=float)

    def wire_bytes(self, codec=None) -> tuple[float, float]:
        """Per-client (aggregatable, non-aggregatable) upload wire bytes
        under ``codec`` (None = the base codec)."""
        if self.wire_fn is not None:
            return self.wire_fn(codec)
        return (0.0, 0.0)

    def up_bits(self, codec=None) -> float:
        agg, nonagg = self.wire_bytes(codec)
        return 8.0 * (agg + nonagg)


@dataclass
class RoundDecision:
    """A policy's answer: who transmits with how much of the budget (and
    in which wire format), and who was excluded, with the reason.

    ``dropped`` is filled by the RUNTIME, not the policy: per allocated
    client that busted its granted deadline at the barrier, the reason it
    was cut off (``excluded`` is the a-priori exclusion, ``dropped`` the
    a-posteriori enforcement)."""
    allocations: dict[int, Allocation] = field(default_factory=dict)
    excluded: dict[int, str] = field(default_factory=dict)
    budget_hz: float = float("inf")
    dropped: dict[int, str] = field(default_factory=dict)

    @property
    def selected(self) -> list[int]:
        return list(self.allocations)

    @property
    def survivors(self) -> list[int]:
        """Allocated clients whose uploads actually landed (selected
        minus the runtime's deadline drops)."""
        return [i for i in self.allocations if i not in self.dropped]

    # count views shared with FleetDecision, so driver code stays
    # O(1)-per-decision and type-agnostic
    @property
    def n_selected(self) -> int:
        return len(self.allocations)

    @property
    def n_excluded(self) -> int:
        return len(self.excluded)

    @property
    def n_dropped(self) -> int:
        return len(self.dropped)

    @property
    def heterogeneous_codecs(self) -> bool:
        return any(a.codec is not None for a in self.allocations.values())

    def bandwidth(self, ids=None) -> np.ndarray:
        ids = self.selected if ids is None else ids
        return np.asarray([self.allocations[int(i)].bandwidth_hz
                           for i in ids], dtype=float)

    def codec_for(self, cid: int):
        """The client's upload codec override (None = plan/run codec)."""
        return self.allocations[int(cid)].codec

    def total_bandwidth_hz(self) -> float:
        return float(sum(a.bandwidth_hz for a in self.allocations.values()))

    def validate(self) -> "RoundDecision":
        """The allocation invariants every policy must satisfy: each
        transmitting client holds a strictly positive subchannel, and the
        round never hands out more than the shared budget."""
        for cid, a in self.allocations.items():
            if not a.bandwidth_hz > 0.0:
                raise ValueError(
                    f"allocation for client {cid} has non-positive bandwidth "
                    f"{a.bandwidth_hz!r}; exclude the client instead")
        total = self.total_bandwidth_hz()
        if total > self.budget_hz * (1.0 + 1e-9):
            raise ValueError(
                f"allocated bandwidth {total:.6g} Hz exceeds the round "
                f"budget {self.budget_hz:.6g} Hz")
        return self


# ---------------------------------------------------------------------------
# Fleet (struct-of-arrays) twins of RoundState / RoundDecision
# ---------------------------------------------------------------------------
@dataclass
class FleetRoundState:
    """The struct-of-arrays twin of :class:`RoundState` for the fleet
    fast path (`repro.edge.fleet`): the same per-round facts, but kept as
    arrays over the eligible population instead of per-client dicts.

    ``backend`` picks the width solver: ``"exact"`` runs the shared
    vectorized-numpy cores above (bit-identical to the scalar dict path
    by construction), ``"jit"`` the x64 lax kernels in
    :mod:`repro.edge.fleet.kernel` (equal up to float-op reassociation —
    XLA reductions are not bitwise numpy)."""
    k: int                          # target cohort size
    ids: np.ndarray                 # (n,) eligible (alive) client ids
    t_comp_s: np.ndarray            # (n,) compute-only times
    spectral_eff: np.ndarray        # (n,) bits/s/Hz under this round's fade
    budget_hz: float                # shared round uplink bandwidth budget
    rng: np.random.Generator
    up_bits: float = 0.0            # 8 · (agg + nonagg) wire bytes / payload
    payload_mult: Optional[np.ndarray] = None  # (n,) payloads per client
    est: Optional[ClientEstimate] = None       # nominal-split estimates
    backend: str = "exact"          # "exact" | "jit"

    def mult(self) -> np.ndarray:
        if self.payload_mult is None:
            return np.ones(len(self.ids))
        return np.asarray(self.payload_mult, dtype=float)


class FleetDecision:
    """An array-backed :class:`RoundDecision` twin: the same contract
    (selected ids in draw order, per-client width + deadline grant, the
    runtime's a-posteriori drops) without any per-client dict on the hot
    path.  The dict views (``allocations`` / ``excluded`` / ``dropped``)
    materialize lazily with the exact prose of the scalar path, so
    fingerprints and renderers see no difference."""

    def __init__(self, ids: np.ndarray, bandwidth_hz: np.ndarray,
                 deadline_s: np.ndarray, budget_hz: float, positions=None):
        self.ids = np.asarray(ids, dtype=int)
        self.bandwidth_hz_arr = np.asarray(bandwidth_hz, dtype=float)
        self.deadline_s_arr = np.asarray(deadline_s, dtype=float)
        self.budget_hz = float(budget_hz)
        # positions of ids within the FleetRoundState's eligible arrays
        # (None = the identity: a fixed full-cohort decision)
        self._positions = (None if positions is None
                           else np.asarray(positions, dtype=int))
        self._excluded_ids = np.asarray([], dtype=int)
        self._excluded_reason_fn = None
        self.excluded_bucket: Optional[str] = None
        self._verdict = None
        self._allocations = None
        self._excluded = None
        self._dropped = None

    def set_excluded(self, ids, reason_fn=None, bucket=None):
        """A-priori exclusions: ids plus a lazy ``reason_fn(position) ->
        prose`` (materialized only if someone reads ``excluded``) and the
        single ``reason_key`` bucket they all fall into (for O(1) drop
        accounting at fleet scale)."""
        self._excluded_ids = np.asarray(ids, dtype=int)
        self._excluded_reason_fn = reason_fn
        self.excluded_bucket = bucket
        self._excluded = None
        return self

    def set_verdict(self, verdict):
        """Attach the runtime's deadline verdict (fills ``dropped``)."""
        self._verdict = verdict
        self._dropped = None
        return self

    # --- array-facing surface (the fleet hot path) ---------------------
    @property
    def positions(self) -> np.ndarray:
        if self._positions is None:
            return np.arange(len(self.ids))
        return self._positions

    @property
    def n_selected(self) -> int:
        return len(self.ids)

    @property
    def n_excluded(self) -> int:
        return len(self._excluded_ids)

    @property
    def n_dropped(self) -> int:
        return 0 if self._verdict is None else int(self._verdict.dropped.sum())

    @property
    def drop_mask(self) -> np.ndarray:
        """(n_selected,) True where the runtime cut the upload off."""
        if self._verdict is None:
            return np.zeros(len(self.ids), dtype=bool)
        return self._verdict.dropped

    # --- RoundDecision-compatible surface ------------------------------
    @property
    def selected(self) -> list[int]:
        return self.ids.tolist()

    @property
    def survivors(self) -> list[int]:
        if self._verdict is None:
            return self.ids.tolist()
        return self.ids[~self._verdict.dropped].tolist()

    @property
    def heterogeneous_codecs(self) -> bool:
        return False     # the fleet path schedules widths, never codecs

    @property
    def allocations(self) -> dict[int, Allocation]:
        if self._allocations is None:
            self._allocations = {
                int(i): Allocation(bandwidth_hz=float(w), deadline_s=float(d))
                for i, w, d in zip(self.ids, self.bandwidth_hz_arr,
                                   self.deadline_s_arr, strict=True)}
        return self._allocations

    @property
    def excluded(self) -> dict[int, str]:
        if self._excluded is None:
            fn = self._excluded_reason_fn or (lambda j: "excluded")
            self._excluded = {int(c): fn(j)
                              for j, c in enumerate(self._excluded_ids)}
        return self._excluded

    @property
    def dropped(self) -> dict[int, str]:
        if self._dropped is None:
            self._dropped = ({} if self._verdict is None
                             else self._verdict.reasons())
        return self._dropped

    def bandwidth(self, ids=None) -> np.ndarray:
        if ids is None:
            return self.bandwidth_hz_arr
        pos = {int(c): i for i, c in enumerate(self.ids)}
        return self.bandwidth_hz_arr[[pos[int(i)] for i in ids]]

    def codec_for(self, cid: int):
        return None

    def total_bandwidth_hz(self) -> float:
        return float(self.bandwidth_hz_arr.sum())

    def validate(self) -> "FleetDecision":
        if len(self.ids) and not (self.bandwidth_hz_arr > 0.0).all():
            bad = int(self.ids[np.argmin(self.bandwidth_hz_arr)])
            raise ValueError(
                f"allocation for client {bad} has non-positive bandwidth; "
                f"exclude the client instead")
        total = self.total_bandwidth_hz()
        if total > self.budget_hz * (1.0 + 1e-9):
            raise ValueError(
                f"allocated bandwidth {total:.6g} Hz exceeds the round "
                f"budget {self.budget_hz:.6g} Hz")
        return self


# ---------------------------------------------------------------------------
# The policy protocol
# ---------------------------------------------------------------------------
class AllocationPolicy:
    """decide(RoundState) -> RoundDecision.

    ``decide`` composes two overridable stages: ``select`` (who, and who
    is excluded why) and ``allocate`` (how much of the budget each
    selected client gets).  The default ``allocate`` is the uniform
    split, so a pure selection policy only implements ``select`` — the
    four ``make_scheduler``-era policies are exactly that."""

    name = "base"
    needs_summable = False   # True: the policy emits per-client sparsifying
                             # codecs, meaningful only for additive payloads
    vectorized = False       # True: decide_vectorized is a real fast path

    def decide(self, state: RoundState) -> RoundDecision:
        ids, excluded = self.select(state)
        return RoundDecision(allocations=self.allocate(ids, state),
                             excluded=excluded,
                             budget_hz=state.budget_hz).validate()

    def decide_vectorized(self, fstate: FleetRoundState
                          ) -> Optional[FleetDecision]:
        """The fleet fast path: the same decision as :meth:`decide` but
        computed with array ops over a :class:`FleetRoundState` — on the
        ``"exact"`` backend, bit-identical to the scalar path because
        both run the shared vectorized cores above.  Returns None when
        the policy has no vectorized form (``vectorized`` False); the
        runtime then falls back to the scalar dict path."""
        if not self.vectorized:
            return None
        pick = self._uniform_pick(fstate)
        n = len(pick)
        if n == 0:
            w = d = np.asarray([], dtype=float)
        else:
            w, d = self.allocate_vectorized(fstate, pick)
        return FleetDecision(fstate.ids[pick], w, d, fstate.budget_hz,
                             positions=pick)

    def allocate_vectorized(self, fstate: FleetRoundState, sel: np.ndarray
                            ) -> tuple[np.ndarray, np.ndarray]:
        """-> (widths, deadline grants) over ``fstate`` positions
        ``sel``.  Default: the uniform split, no deadline."""
        n = len(sel)
        return np.full(n, fstate.budget_hz / n), np.full(n, np.inf)

    def select(self, state: RoundState) -> tuple[list[int], dict[int, str]]:
        """-> (selected ids, {excluded id: reason})."""
        raise NotImplementedError

    def allocate(self, ids: Sequence[int],
                 state: RoundState) -> dict[int, Allocation]:
        """Split the round budget over the selected ids (default: equal)."""
        ids = [int(i) for i in ids]
        if not ids:
            return {}
        w = state.budget_hz / len(ids)
        return {i: Allocation(bandwidth_hz=w) for i in ids}

    # shared proposal: sample k uniformly (the paper's protocol)
    @staticmethod
    def _uniform_positions(state: RoundState) -> np.ndarray:
        """The uniform draw as positions into the eligible arrays (the
        same rng call as :meth:`_uniform_pick`, so the dict and fleet
        cohorts match bitwise)."""
        n = len(state.est.clients)
        return state.rng.choice(n, size=min(state.k, n), replace=False)

    @staticmethod
    def _uniform_ids(state: RoundState) -> list[int]:
        pick = AllocationPolicy._uniform_positions(state)
        return [int(state.est.clients[i]) for i in pick]

    @staticmethod
    def _uniform_pick(fstate: FleetRoundState) -> np.ndarray:
        """The same uniform draw as :meth:`_uniform_ids` (identical rng
        call, so the cohorts match bitwise), returned as positions."""
        n = len(fstate.ids)
        return fstate.rng.choice(n, size=min(fstate.k, n), replace=False)


class UniformPolicy(AllocationPolicy):
    """Uniform cohort, equal bandwidth split — the paper's protocol."""
    name = "uniform"
    vectorized = True

    def select(self, state):
        return self._uniform_ids(state), {}


class DeadlinePolicy(AllocationPolicy):
    """Uniform proposal, then exclude predicted stragglers past
    ``deadline_s``.  Keeps at least ``min_clients`` (the fastest) so a
    tight deadline can never stall training entirely.  Survivors share
    the full budget equally, so dropping stragglers also widens everyone
    else's subchannel.

    Deadline grants (what the runtime enforces): an admitted client is
    granted ``deadline_s``; since admission predicts under the *nominal*
    equal split and the granted width is at least nominal, an admitted
    client's realized finish never exceeds its prediction — under zero
    channel noise it is never dropped at the barrier.  A client kept
    only by the ``min_clients`` floor (predicted past the deadline) is
    granted *no* deadline (inf): the policy insists on its progress, so
    the runtime must not cut it off.

    Both the scalar dict path and ``decide_vectorized`` run the same
    shared cores (:func:`deadline_min_widths` for the per-client
    admission floor, :func:`feasible_packing` as the budget-feasibility
    authority), so the fleet fast path is bit-identical to the dict path
    by construction rather than by parallel reimplementation."""
    name = "deadline"
    vectorized = True

    def __init__(self, deadline_s: float, min_clients: int = 1):
        self.deadline_s = float(deadline_s)
        self.min_clients = int(min_clients)

    # --- the shared admission core (both paths, bitwise) ---------------
    def _admit(self, t_nom: np.ndarray, bits: np.ndarray, s: np.ndarray,
               tc: np.ndarray, budget: float, k: int
               ) -> tuple[np.ndarray, np.ndarray, float]:
        """-> (admitted mask, W_min, W_nom).  Admission is in *time*
        space — a client is admitted iff its predicted nominal finish
        ``t_nom`` (``est.time_s``: equal split, this round's draw) meets
        the deadline — AND its narrowest deadline-meeting subchannel
        (``deadline_min_widths``) greedily packs into the budget
        (``feasible_packing``; for the equal split Σ W_min over admitted
        clients <= k·W_nom <= budget, so the packing rule only bites at
        float borderline — it is kept as the shared feasibility
        authority so admission can never outgrow the budget)."""
        w_nom = budget / max(k, 1)
        _c, w_min = deadline_min_widths(bits, s, tc, self.deadline_s)
        return ((t_nom <= self.deadline_s)
                & feasible_packing(w_min, tc, budget)), w_min, w_nom

    def _keep(self, admit: np.ndarray, t_nom: np.ndarray) -> np.ndarray:
        """Admission plus the ``min_clients`` floor: when too few admit,
        force-keep the predicted-fastest ``min_clients`` instead."""
        if int(admit.sum()) >= self.min_clients:
            return admit
        order = np.argsort(t_nom)
        keep = np.zeros(len(admit), dtype=bool)
        keep[order[:self.min_clients]] = True
        return keep

    def _reason(self, t_nom: float) -> str:
        if t_nom <= self.deadline_s:   # packed out at float borderline
            return ("meets the deadline but the admitted floors fill "
                    "the budget")
        return (f"predicted finish {t_nom:.3g}s > deadline "
                f"{self.deadline_s:g}s")

    def _grants(self, t_nom: np.ndarray) -> np.ndarray:
        """Deadline grants over the selected set: clients predicted to
        meet ``deadline_s`` are held to it, floor force-keeps (predicted
        past it) are granted none (inf)."""
        return np.where(t_nom <= self.deadline_s, self.deadline_s, np.inf)

    # --- scalar dict path ----------------------------------------------
    def select(self, state):
        pick = self._uniform_positions(state)
        clients = state.est.clients[pick]
        t_nom = state.est.time_s[pick]
        bits = state.up_bits() * state.mult()[pick]
        admit, _w_min, _w_nom = self._admit(
            t_nom, bits, state.spectral_eff[pick], state.t_comp_s[pick],
            float(state.budget_hz), state.k)
        keep = self._keep(admit, t_nom)
        selected = [int(c) for c in clients[keep]]
        excluded = {int(c): self._reason(float(t))
                    for c, t in zip(clients[~keep], t_nom[~keep],
                                    strict=True)}
        return selected, excluded

    def allocate(self, ids, state):
        base = super().allocate(ids, state)
        if not base:
            return base
        pred = state.est.for_ids(list(base)).time_s
        grants = self._grants(pred)
        return {i: Allocation(bandwidth_hz=a.bandwidth_hz,
                              deadline_s=float(d))
                for (i, a), d in zip(base.items(), grants, strict=True)}

    # --- fleet fast path -----------------------------------------------
    def _t_nom(self, fstate, idx) -> np.ndarray:
        """The scalar path's ``est.time_s`` op-for-op
        (``Channel.set_bandwidth`` then ``uplink_time_s`` at the nominal
        equal split), so admission, the floor ordering, grants, and the
        exclusion prose match the dict path bitwise."""
        bits = fstate.up_bits * fstate.mult()[idx]
        w_nom = float(fstate.budget_hz) / max(fstate.k, 1)
        return (fstate.t_comp_s[idx]
                + bits / np.maximum(w_nom * fstate.spectral_eff[idx], 1e-6))

    def allocate_vectorized(self, fstate, sel):
        n = len(sel)
        budget = float(fstate.budget_hz)
        return (np.full(n, budget / max(n, 1)),
                self._grants(self._t_nom(fstate, sel)))

    def decide_vectorized(self, fstate):
        pick = self._uniform_pick(fstate)
        budget = float(fstate.budget_hz)
        if len(pick) == 0:
            e = np.asarray([], dtype=float)
            return FleetDecision(fstate.ids[pick], e, e.copy(), budget,
                                 positions=pick)
        bits = fstate.up_bits * fstate.mult()[pick]
        s = fstate.spectral_eff[pick]
        tc = fstate.t_comp_s[pick]
        t_nom = self._t_nom(fstate, pick)
        admit, _w_min, _w_nom = self._admit(t_nom, bits, s, tc, budget,
                                            fstate.k)
        keep = self._keep(admit, t_nom)
        sel = pick[keep]
        w, grants = self.allocate_vectorized(fstate, sel)
        dec = FleetDecision(fstate.ids[sel], w, grants, budget,
                            positions=sel)
        if bool((~keep).any()):
            t_e = t_nom[~keep]
            dec.set_excluded(
                fstate.ids[pick[~keep]],
                reason_fn=lambda j: self._reason(float(t_e[j])),
                bucket="deadline")
        return dec


class EnergyThresholdPolicy(AllocationPolicy):
    """Exclude depleted clients (battery below ``battery_floor_j``) and
    clients whose predicted round energy exceeds ``round_budget_j`` —
    arXiv:2104.05509's threshold exclusion, expressed as an allocation
    of zero."""
    name = "energy_threshold"

    def __init__(self, battery_floor_j: float = 0.0,
                 round_budget_j: float = math.inf):
        self.battery_floor_j = float(battery_floor_j)
        self.round_budget_j = float(round_budget_j)

    def select(self, state):
        est = state.est
        ok = ((est.battery_j > self.battery_floor_j)
              & (est.energy_j <= self.round_budget_j)
              & (est.energy_j <= est.battery_j))
        excluded = {}
        for c, e, b in zip(est.clients[~ok], est.energy_j[~ok],
                           est.battery_j[~ok], strict=True):
            excluded[int(c)] = (
                f"battery {b:.3g}J under floor {self.battery_floor_j:g}J"
                if b <= self.battery_floor_j else
                f"round energy {e:.3g}J over budget "
                f"{min(self.round_budget_j, b):.3g}J")
        eligible = est.clients[ok]
        if len(eligible) == 0:
            return [], excluded
        pick = state.rng.choice(len(eligible),
                                size=min(state.k, len(eligible)),
                                replace=False)
        return [int(eligible[i]) for i in pick], excluded


class CapacityProportionalPolicy(AllocationPolicy):
    """Sample the cohort with P(k) ∝ 1 / t_k (predicted capacity), the
    selection reading of arXiv:1910.13067; equal bandwidth split.

    Approximation note: ``rng.choice(..., replace=False, p=p)`` draws
    sequentially with renormalization after each pick, which is NOT the
    exact "probability-proportional-to-size without replacement" design
    (inclusion probabilities differ from k·p_k, most visibly for heavy
    p's near 1/k).  It preserves the intended ordering — faster clients
    are strictly more likely — which is all the policy relies on."""
    name = "capacity_proportional"

    def select(self, state):
        est = state.est
        n = len(est.clients)
        cap = 1.0 / np.maximum(est.time_s, 1e-9)
        cap = np.where(np.isfinite(cap), cap, 0.0)
        p = cap / cap.sum()
        assert math.isclose(float(p.sum()), 1.0, rel_tol=1e-9), \
            f"selection probabilities must renormalize to 1, got {p.sum()}"
        pick = state.rng.choice(n, size=min(state.k, n), replace=False, p=p)
        return [int(est.clients[i]) for i in pick], {}


class BandwidthOptPolicy(AllocationPolicy):
    """Minimize the sync-round barrier max_k t_k under Σ_k W_k ≤ budget.

    The arXiv:1910.13067 capacity form: client k finishing by time T
    needs W_k(T) = bits / (s_k · (T − t_comp,k)) with s_k = log2(1+γ_k)
    its spectral efficiency this round.  Each W_k(T) is decreasing in T,
    so the minimal feasible barrier T* solves Σ_k W_k(T) = budget —
    found by bisection; the slack from the final bracket is handed back
    pro rata so the full budget is always in the air.  The cohort itself
    is the paper's uniform sample, which keeps bytes (and, under a fixed
    seed, the cohort) identical to ``uniform`` — only the per-client
    subchannel widths, and therefore the barrier, change."""
    name = "bandwidth_opt"
    vectorized = True

    def __init__(self, iters: int = BISECT_ITERS):
        self.iters = int(iters)

    def select(self, state):
        return self._uniform_ids(state), {}

    def allocate(self, ids, state):
        ids = [int(i) for i in ids]
        if not ids:
            return {}
        bits = state.up_bits()
        if bits <= 0.0:          # nothing to upload: any split is optimal
            return super().allocate(ids, state)
        pos = {int(c): i for i, c in enumerate(state.est.clients)}
        sel = np.asarray([pos[i] for i in ids], dtype=int)
        s = np.maximum(state.spectral_eff[sel], 1e-9)   # bits/s/Hz
        tc = np.asarray(state.t_comp_s[sel], dtype=float)
        w = bandwidth_opt_widths(bits * state.mult()[sel], s, tc,
                                 state.budget_hz, self.iters)
        return {i: Allocation(bandwidth_hz=float(wk))
                for i, wk in zip(ids, w, strict=True)}

    def allocate_vectorized(self, fstate, sel):
        bits = fstate.up_bits
        n = len(sel)
        if bits <= 0.0:
            w = np.full(n, fstate.budget_hz / n)
        else:
            s = np.maximum(fstate.spectral_eff[sel], 1e-9)
            tc = np.asarray(fstate.t_comp_s[sel], dtype=float)
            b = bits * fstate.mult()[sel]
            if fstate.backend == "jit":
                from repro.edge.fleet import kernel  # late: optional backend
                w = kernel.bandwidth_opt_widths_jit(b, s, tc,
                                                    fstate.budget_hz,
                                                    self.iters)
            else:
                w = bandwidth_opt_widths(b, s, tc, fstate.budget_hz,
                                         self.iters)
        return w, np.full(n, np.inf)


class EnergyOptPolicy(AllocationPolicy):
    """Minimize the cohort's total energy Σ_k E_k subject to every
    selected client finishing within ``deadline_s`` — the dual of
    ``bandwidth_opt`` (which minimizes the barrier subject to the
    budget; here the deadline is the constraint and energy the
    objective), following the resource-allocation formulation of
    arXiv:1910.13067.

    With E_k = e_comp,k + P_tx · t_up,k and t_up,k = c_k / W_k on the
    capacity form (c_k = bits_k / s_k, s_k = log2(1+γ_k) this round's
    spectral efficiency), compute energy is width-independent, so the
    problem is  min Σ_k c_k / W_k  s.t.  Σ_k W_k ≤ budget  and
    W_k ≥ W_min,k = c_k / (deadline − t_comp,k)  (the narrowest
    subchannel that still meets the deadline).  The KKT point is
    W_k = max(W_min,k, √c_k / λ) with λ pinned by the budget — found by
    per-client bisection on λ; the final bracket's slack is scaled back
    pro rata (scaling up never violates a W_min), so the full budget is
    in the air and Σ energy is the constrained minimum — strictly below
    the uniform split whenever the c_k are heterogeneous (Cauchy–
    Schwarz).

    Feasibility-aware selection: a uniform proposal, then clients whose
    compute alone busts the deadline (no width can save them) and, in
    ascending-W_min order, clients whose minimal widths no longer fit
    the remaining budget are excluded with reasons.  If fewer than
    ``min_clients`` are feasible, the cheapest remaining clients are
    force-kept at (at least) the equal-split width; the deadline grant
    is re-derived from the widths actually handed out — a kept client
    whose width cannot guarantee the deadline is granted none (inf): the
    policy insists on its progress, so the runtime must not cut it
    off."""
    name = "energy_opt"
    vectorized = True

    def __init__(self, deadline_s: float, min_clients: int = 1,
                 iters: int = BISECT_ITERS):
        self.deadline_s = float(deadline_s)
        self.min_clients = int(min_clients)
        self.iters = int(iters)

    def _capacity(self, ids, state):
        """Per-client (c_k, t_comp,k, W_min,k) on the capacity form;
        W_min is inf where no width meets the deadline."""
        pos = {int(c): i for i, c in enumerate(state.est.clients)}
        sel = np.asarray([pos[int(i)] for i in ids], dtype=int)
        s = np.maximum(state.spectral_eff[sel], 1e-9)
        tc = np.asarray(state.t_comp_s[sel], dtype=float)
        c, w_min = deadline_min_widths(state.up_bits() * state.mult()[sel],
                                       s, tc, self.deadline_s)
        return c, tc, w_min

    def _feasible(self, w_min, tc, budget):
        """Greedy ascending-W_min packing into the budget (deterministic:
        ties broken by compute time) — the shared feasibility rule select
        and allocate both apply, so they can never disagree."""
        return feasible_packing(w_min, tc, budget)

    def _reason(self, w_min_j, tc_j, free, budget):
        if not np.isfinite(w_min_j):
            return (f"compute alone takes {tc_j:.3g}s ≥ deadline "
                    f"{self.deadline_s:g}s — infeasible at any bandwidth")
        return (f"needs ≥ {w_min_j:.3g} Hz to finish by "
                f"{self.deadline_s:g}s but only {max(free, 0.0):.3g} Hz "
                f"of the {budget:.3g} Hz budget remains")

    def _kept_positions(self, w_min, tc, feas, budget):
        """Positions kept by select: every feasible client plus, in
        ascending-(W_min, t_comp) order, enough infeasible force-keeps to
        reach ``min_clients``.  Returns (sorted kept positions, free Hz)."""
        order = np.lexsort((tc, w_min))
        kept = feas.copy()
        short = self.min_clients - int(feas.sum())
        if short > 0:
            infeasible = order[~feas[order]]
            kept[infeasible[:short]] = True
        free = float(budget) - float(w_min[feas].sum())
        return np.flatnonzero(kept), free

    def select(self, state):
        ids = self._uniform_ids(state)
        if not ids:
            return ids, {}
        c, tc, w_min = self._capacity(ids, state)
        budget = float(state.budget_hz)
        feas = self._feasible(w_min, tc, budget)
        kept_pos, free = self._kept_positions(w_min, tc, feas, budget)
        kept = set(kept_pos.tolist())
        excluded = {int(ids[j]): self._reason(w_min[j], tc[j], free, budget)
                    for j in range(len(ids)) if j not in kept}
        return [int(ids[j]) for j in sorted(kept)], excluded

    def allocate(self, ids, state):
        ids = [int(i) for i in ids]
        if not ids:
            return {}
        c, tc, w_min = self._capacity(ids, state)
        budget = float(state.budget_hz)
        feas = self._feasible(w_min, tc, budget)
        # floors: a feasible client holds its minimal deadline-meeting
        # width; a force-kept (infeasible) client holds the equal-split
        # share, like DeadlinePolicy's keeps — never a vanishing sliver
        # of bisection slack (an inf-deadline client on a ~0 Hz channel
        # would blow the barrier and Σ energy unboundedly).  If the
        # combined floors overflow the budget the guarantees are jointly
        # unsatisfiable — everyone shrinks pro rata and the deadline
        # grant below re-derives from the widths actually handed out.
        w = energy_opt_widths(c, w_min, feas, budget, self.iters)
        # grant the deadline iff the width actually handed out still
        # guarantees it (W ≥ W_min) — a force-kept client whose equal
        # share happens to meet the deadline earns the grant, one whose
        # floor was shrunk below W_min loses it (inf: runtime must not
        # cut off a client the policy could not provision)
        ok = w >= w_min * (1.0 - 1e-9)
        return {i: Allocation(
                    bandwidth_hz=float(wk),
                    deadline_s=(self.deadline_s if k else float("inf")))
                for i, wk, k in zip(ids, w, ok, strict=True)}

    def _capacity_vec(self, fstate, sel):
        s = np.maximum(fstate.spectral_eff[sel], 1e-9)
        tc = np.asarray(fstate.t_comp_s[sel], dtype=float)
        c, w_min = deadline_min_widths(fstate.up_bits * fstate.mult()[sel],
                                       s, tc, self.deadline_s)
        return c, tc, w_min

    def allocate_vectorized(self, fstate, sel):
        n = len(sel)
        c, tc, w_min = self._capacity_vec(fstate, sel)
        budget = float(fstate.budget_hz)
        feas = self._feasible(w_min, tc, budget)
        if fstate.backend == "jit":
            from repro.edge.fleet import kernel  # late: optional backend
            w = kernel.energy_opt_widths_jit(c, w_min, feas, budget,
                                             self.iters)
        else:
            w = energy_opt_widths(c, w_min, feas, budget, self.iters)
        ok = w >= w_min * (1.0 - 1e-9)
        return w, np.where(ok, self.deadline_s, np.inf)

    def decide_vectorized(self, fstate):
        pick = self._uniform_pick(fstate)
        if len(pick) == 0:
            return FleetDecision(np.asarray([], dtype=int),
                                 np.asarray([], dtype=float),
                                 np.asarray([], dtype=float),
                                 fstate.budget_hz,
                                 positions=np.asarray([], dtype=int))
        c, tc, w_min = self._capacity_vec(fstate, pick)
        budget = float(fstate.budget_hz)
        feas = self._feasible(w_min, tc, budget)
        kept_pos, free = self._kept_positions(w_min, tc, feas, budget)
        kept = np.zeros(len(pick), dtype=bool)
        kept[kept_pos] = True
        sel = pick[kept_pos]                 # sorted draw positions, as select
        w, grants = self.allocate_vectorized(fstate, sel)
        dec = FleetDecision(fstate.ids[sel], w, grants, budget,
                            positions=sel)
        excl = ~kept
        if excl.any():
            w_min_e, tc_e = w_min[excl], tc[excl]
            dec.set_excluded(
                fstate.ids[pick[excl]],
                # reasons materialize lazily (dec.excluded) — same prose as
                # the scalar path; both exclusion kinds bucket under
                # reason_key as "bandwidth_infeasible"
                reason_fn=lambda j: self._reason(w_min_e[j], tc_e[j],
                                                 free, budget),
                bucket="bandwidth_infeasible")
        return dec


class AdaptiveCodecPolicy(AllocationPolicy):
    """Uniform cohort + equal split, but each client's top-k upload ratio
    is scheduled from its sampled channel rate: a client whose allocated
    subchannel is r× the cohort median runs top-k at ``ratio`` · r
    (clipped to [ratio_floor, 1]), so slow links send sparser payloads
    and the uplink barrier flattens.  A client whose scheduled format
    would cost at least as many wire bytes as the base codec (top-k
    ships value + index, 8 B per kept element, so ratio ≥ 0.5 dominates
    a dense 4 B/element payload) keeps the base codec instead —
    sparsifying is only ever a discount.  Sparsification zeroes
    coordinates, which only additive payloads survive — the policy
    refuses non-summable plans (``needs_summable``)."""
    name = "adaptive_codec"
    needs_summable = True

    def __init__(self, ratio: float = 0.25, ratio_floor: float = 0.02):
        if not 0.0 < ratio <= 1.0:
            raise ValueError(f"adaptive_codec ratio must be in (0, 1], "
                             f"got {ratio}")
        self.ratio = float(ratio)
        self.ratio_floor = float(ratio_floor)

    def select(self, state):
        return self._uniform_ids(state), {}

    def allocate(self, ids, state):
        if not state.summable:
            raise ValueError(
                "adaptive_codec schedules per-client top-k sparsification, "
                "which is only meaningful for additive (summable) payloads; "
                "this plan uploads distinct models/components")
        from repro.fed.codecs import TopKCodec  # late: avoid edge<->fed cycle

        base = super().allocate(ids, state)
        if not base:
            return base
        pos = {int(c): i for i, c in enumerate(state.est.clients)}
        sel = np.asarray([pos[int(i)] for i in ids], dtype=int)
        rate = (np.asarray([base[int(i)].bandwidth_hz for i in ids])
                * np.maximum(state.spectral_eff[sel], 1e-9))
        ref = float(np.median(rate))
        ratios = np.clip(self.ratio * rate / max(ref, 1e-12),
                         self.ratio_floor, 1.0)
        base_bytes = sum(state.wire_bytes(None))
        out = {}
        for i, r in zip(ids, ratios, strict=True):
            codec = TopKCodec(float(r))
            if sum(state.wire_bytes(codec)) >= base_bytes:
                codec = None    # dominated format: keep the base codec
            out[int(i)] = Allocation(
                bandwidth_hz=base[int(i)].bandwidth_hz, codec=codec)
        return out


# ---------------------------------------------------------------------------
# Registry (mirrors repro.fed.strategies / repro.fed.codecs)
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[..., AllocationPolicy]] = {}


def register(name: str,
             factory: Optional[Callable[..., AllocationPolicy]] = None):
    """Register ``factory(**knobs) -> AllocationPolicy`` under ``name``.
    Usable as a decorator on a policy class or called directly."""

    def _do(f):
        try:
            f.name = name
        except (AttributeError, TypeError):
            pass
        _REGISTRY[name] = f
        return f

    return _do if factory is None else _do(factory)


def get(name: str) -> Callable[..., AllocationPolicy]:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown allocation policy {name!r}; known: {names()}")
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)


def make_policy(name: str, **kw) -> AllocationPolicy:
    """Build a policy by name.  ``kw`` may be a superset of the policy's
    knobs (EdgeConfig passes every policy knob it carries); anything the
    factory does not accept is dropped."""
    factory = get(name)
    try:
        params = inspect.signature(factory).parameters
    except (TypeError, ValueError):
        return factory(**kw)
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return factory(**kw)
    return factory(**{k: v for k, v in kw.items() if k in params})


register("uniform", UniformPolicy)
register("deadline", DeadlinePolicy)
register("energy_threshold", EnergyThresholdPolicy)
register("capacity_proportional", CapacityProportionalPolicy)
register("bandwidth_opt", BandwidthOptPolicy)
register("energy_opt", EnergyOptPolicy)
register("adaptive_codec", AdaptiveCodecPolicy)
