"""Per-client resource allocation for the edge runtime.

The paper's resource-constrained FEEL formulation is about *how much* of
the wireless budget each client gets, not just *who* transmits.  An
``AllocationPolicy`` therefore returns a :class:`RoundDecision` — per
selected client an :class:`Allocation` (uplink ``bandwidth_hz`` drawn
from a shared round budget, an optional per-client upload codec, and a
deadline) plus the ids it deliberately excluded, with reasons.  Client
*selection* (the old ``Scheduler.select`` API) is the degenerate case
where every selected client gets an equal split of the budget.

Policies (register your own with :func:`register`):
  * uniform               — sample k uniformly (the paper's protocol),
                            equal bandwidth split.
  * deadline              — uniform proposal, then exclude clients whose
                            predicted finish exceeds the round deadline
                            (straggler dropping; the quantile-barrier
                            view of synchronous FEEL); equal split.
  * energy_threshold      — exclude clients whose battery is below a
                            floor or whose round energy exceeds a budget,
                            à la the threshold-based exclusion design of
                            arXiv:2104.05509 (exclusion == an allocation
                            of zero); equal split.
  * capacity_proportional — sample with probability ∝ predicted capacity
                            1/t_k, the resource-allocation reading of
                            arXiv:1910.13067; equal split.
  * bandwidth_opt         — uniform cohort, then minimize the sync-round
                            barrier max_k t_k subject to Σ_k W_k ≤ budget
                            by bisection on the arXiv:1910.13067 capacity
                            form t_k = t_comp,k + bits / (W_k·log2(1+γ_k)).
  * energy_opt            — the dual: minimize Σ_k E_k subject to every
                            selected client finishing within the round
                            deadline (and Σ_k W_k ≤ budget), by bisection
                            on the same capacity form; feasibility-aware
                            (clients that cannot meet the deadline at any
                            width within budget are excluded, with
                            reasons).
  * adaptive_codec        — uniform cohort + equal split, but each
                            client's top-k upload ratio is scheduled from
                            its sampled channel rate (fast links send
                            denser payloads); summable plans only.

Every policy sees the same :class:`RoundState`: the eligible ids with a
per-client :class:`ClientEstimate` under a *nominal* equal split, the
compute-only times, this round's spectral efficiencies, the shared
bandwidth budget, and the upload wire format.  Bandwidth-only policies
never change WHAT is transmitted — CommLedger bytes are allocation-
independent; per-client codecs change bytes only through the codec's
``wire_bytes``, and the ledger still equals the plan per client.
"""
from __future__ import annotations

import inspect
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np


# ---------------------------------------------------------------------------
# Estimates (moved from the retired edge/scheduler.py surface)
# ---------------------------------------------------------------------------
@dataclass
class ClientEstimate:
    """Predicted per-client round cost under current channel/fleet state."""
    clients: np.ndarray      # (n,) eligible ids
    time_s: np.ndarray       # (n,) predicted compute + uplink time
    energy_j: np.ndarray     # (n,) predicted compute + uplink energy
    battery_j: np.ndarray    # (n,) remaining budget

    def for_ids(self, ids) -> "ClientEstimate":
        pos = {int(c): i for i, c in enumerate(self.clients)}
        sel = []
        for i in ids:
            if int(i) not in pos:
                raise ValueError(
                    f"client id {int(i)} is not in this estimate's eligible "
                    f"set of {len(self.clients)} clients "
                    f"({np.sort(self.clients).tolist()})")
            sel.append(pos[int(i)])
        sel = np.asarray(sel, dtype=int)
        return ClientEstimate(self.clients[sel], self.time_s[sel],
                              self.energy_j[sel], self.battery_j[sel])


# ---------------------------------------------------------------------------
# The decision types
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Allocation:
    """One selected client's share of the round: an uplink subchannel
    width drawn from the shared budget, an optional per-client upload
    codec (None = the plan's / run's codec), and the finish deadline the
    policy holds it to — a *runtime contract*: a client whose realized
    finish (compute + uplink at this granted width) exceeds it is cut
    off at the barrier, its upload discarded and only the bytes on the
    air before the cutoff billed (inf = no deadline)."""
    bandwidth_hz: float
    codec: Any = None              # Optional[repro.fed.codecs.PayloadCodec]
    deadline_s: float = float("inf")


@dataclass
class RoundState:
    """Everything a policy may consult to decide one round.

    ``est`` covers the *eligible* (alive) clients, predicted under the
    nominal equal split ``budget_hz / k`` — so a pure selection policy
    reads it exactly as the old scheduler did.  ``wire_fn(codec|None)``
    answers "what does one client's upload cost on the wire under this
    codec override?" as ``(aggregatable_bytes, nonagg_bytes)``; policies
    never recompute plan bytes themselves."""
    k: int                          # target cohort size
    est: ClientEstimate             # eligible clients, nominal-split costs
    t_comp_s: np.ndarray            # (n,) compute-only share of est.time_s
    spectral_eff: np.ndarray        # (n,) bits/s/Hz under this round's fade
    budget_hz: float                # shared round uplink bandwidth budget
    rng: np.random.Generator
    codec: Any = None               # the run's base upload codec
    summable: bool = True           # plan.summable (gates codec overrides)
    wire_fn: Optional[Callable[[Any], tuple[float, float]]] = None
    payload_mult: Optional[np.ndarray] = None  # (n,) payloads per client
                                               # (duplicate cohort slots on
                                               # one device; None = 1 each)

    def mult(self) -> np.ndarray:
        if self.payload_mult is None:
            return np.ones(len(self.est.clients))
        return np.asarray(self.payload_mult, dtype=float)

    def wire_bytes(self, codec=None) -> tuple[float, float]:
        """Per-client (aggregatable, non-aggregatable) upload wire bytes
        under ``codec`` (None = the base codec)."""
        if self.wire_fn is not None:
            return self.wire_fn(codec)
        return (0.0, 0.0)

    def up_bits(self, codec=None) -> float:
        agg, nonagg = self.wire_bytes(codec)
        return 8.0 * (agg + nonagg)


@dataclass
class RoundDecision:
    """A policy's answer: who transmits with how much of the budget (and
    in which wire format), and who was excluded, with the reason.

    ``dropped`` is filled by the RUNTIME, not the policy: per allocated
    client that busted its granted deadline at the barrier, the reason it
    was cut off (``excluded`` is the a-priori exclusion, ``dropped`` the
    a-posteriori enforcement)."""
    allocations: dict[int, Allocation] = field(default_factory=dict)
    excluded: dict[int, str] = field(default_factory=dict)
    budget_hz: float = float("inf")
    dropped: dict[int, str] = field(default_factory=dict)

    @property
    def selected(self) -> list[int]:
        return list(self.allocations)

    @property
    def survivors(self) -> list[int]:
        """Allocated clients whose uploads actually landed (selected
        minus the runtime's deadline drops)."""
        return [i for i in self.allocations if i not in self.dropped]

    @property
    def heterogeneous_codecs(self) -> bool:
        return any(a.codec is not None for a in self.allocations.values())

    def bandwidth(self, ids=None) -> np.ndarray:
        ids = self.selected if ids is None else ids
        return np.asarray([self.allocations[int(i)].bandwidth_hz
                           for i in ids], dtype=float)

    def codec_for(self, cid: int):
        """The client's upload codec override (None = plan/run codec)."""
        return self.allocations[int(cid)].codec

    def total_bandwidth_hz(self) -> float:
        return float(sum(a.bandwidth_hz for a in self.allocations.values()))

    def validate(self) -> "RoundDecision":
        """The allocation invariants every policy must satisfy: each
        transmitting client holds a strictly positive subchannel, and the
        round never hands out more than the shared budget."""
        for cid, a in self.allocations.items():
            if not a.bandwidth_hz > 0.0:
                raise ValueError(
                    f"allocation for client {cid} has non-positive bandwidth "
                    f"{a.bandwidth_hz!r}; exclude the client instead")
        total = self.total_bandwidth_hz()
        if total > self.budget_hz * (1.0 + 1e-9):
            raise ValueError(
                f"allocated bandwidth {total:.6g} Hz exceeds the round "
                f"budget {self.budget_hz:.6g} Hz")
        return self


# ---------------------------------------------------------------------------
# The policy protocol
# ---------------------------------------------------------------------------
class AllocationPolicy:
    """decide(RoundState) -> RoundDecision.

    ``decide`` composes two overridable stages: ``select`` (who, and who
    is excluded why) and ``allocate`` (how much of the budget each
    selected client gets).  The default ``allocate`` is the uniform
    split, so a pure selection policy only implements ``select`` — the
    four ``make_scheduler``-era policies are exactly that."""

    name = "base"
    needs_summable = False   # True: the policy emits per-client sparsifying
                             # codecs, meaningful only for additive payloads

    def decide(self, state: RoundState) -> RoundDecision:
        ids, excluded = self.select(state)
        return RoundDecision(allocations=self.allocate(ids, state),
                             excluded=excluded,
                             budget_hz=state.budget_hz).validate()

    def select(self, state: RoundState) -> tuple[list[int], dict[int, str]]:
        """-> (selected ids, {excluded id: reason})."""
        raise NotImplementedError

    def allocate(self, ids, state: RoundState) -> dict[int, Allocation]:
        """Split the round budget over the selected ids (default: equal)."""
        ids = [int(i) for i in ids]
        if not ids:
            return {}
        w = state.budget_hz / len(ids)
        return {i: Allocation(bandwidth_hz=w) for i in ids}

    # shared proposal: sample k uniformly (the paper's protocol)
    @staticmethod
    def _uniform_ids(state: RoundState) -> list[int]:
        n = len(state.est.clients)
        pick = state.rng.choice(n, size=min(state.k, n), replace=False)
        return [int(state.est.clients[i]) for i in pick]


class UniformPolicy(AllocationPolicy):
    """Uniform cohort, equal bandwidth split — the paper's protocol."""
    name = "uniform"

    def select(self, state):
        return self._uniform_ids(state), {}


class DeadlinePolicy(AllocationPolicy):
    """Uniform proposal, then exclude predicted stragglers past
    ``deadline_s``.  Keeps at least ``min_clients`` (the fastest) so a
    tight deadline can never stall training entirely.  Survivors share
    the full budget equally, so dropping stragglers also widens everyone
    else's subchannel.

    Deadline grants (what the runtime enforces): an admitted client is
    granted ``deadline_s``; since admission predicts under the *nominal*
    equal split and the granted width is at least nominal, an admitted
    client's realized finish never exceeds its prediction — under zero
    channel noise it is never dropped at the barrier.  A client kept
    only by the ``min_clients`` floor (predicted past the deadline) is
    granted *no* deadline (inf): the policy insists on its progress, so
    the runtime must not cut it off."""
    name = "deadline"

    def __init__(self, deadline_s: float, min_clients: int = 1):
        self.deadline_s = float(deadline_s)
        self.min_clients = int(min_clients)

    def select(self, state):
        sub = state.est.for_ids(self._uniform_ids(state))
        keep = sub.time_s <= self.deadline_s
        if keep.sum() < self.min_clients:
            order = np.argsort(sub.time_s)
            keep = np.zeros(len(sub.clients), dtype=bool)
            keep[order[:self.min_clients]] = True
        selected = [int(c) for c in sub.clients[keep]]
        excluded = {int(c): f"predicted finish {t:.3g}s > deadline "
                            f"{self.deadline_s:g}s"
                    for c, t in zip(sub.clients[~keep], sub.time_s[~keep])}
        return selected, excluded

    def allocate(self, ids, state):
        base = super().allocate(ids, state)
        if not base:
            return base
        pred = state.est.for_ids(list(base)).time_s
        return {i: Allocation(
                    bandwidth_hz=a.bandwidth_hz,
                    deadline_s=(self.deadline_s if t <= self.deadline_s
                                else float("inf")))
                for (i, a), t in zip(base.items(), pred)}


class EnergyThresholdPolicy(AllocationPolicy):
    """Exclude depleted clients (battery below ``battery_floor_j``) and
    clients whose predicted round energy exceeds ``round_budget_j`` —
    arXiv:2104.05509's threshold exclusion, expressed as an allocation
    of zero."""
    name = "energy_threshold"

    def __init__(self, battery_floor_j: float = 0.0,
                 round_budget_j: float = float("inf")):
        self.battery_floor_j = float(battery_floor_j)
        self.round_budget_j = float(round_budget_j)

    def select(self, state):
        est = state.est
        ok = ((est.battery_j > self.battery_floor_j)
              & (est.energy_j <= self.round_budget_j)
              & (est.energy_j <= est.battery_j))
        excluded = {}
        for c, e, b in zip(est.clients[~ok], est.energy_j[~ok],
                           est.battery_j[~ok]):
            excluded[int(c)] = (
                f"battery {b:.3g}J under floor {self.battery_floor_j:g}J"
                if b <= self.battery_floor_j else
                f"round energy {e:.3g}J over budget "
                f"{min(self.round_budget_j, b):.3g}J")
        eligible = est.clients[ok]
        if len(eligible) == 0:
            return [], excluded
        pick = state.rng.choice(len(eligible),
                                size=min(state.k, len(eligible)),
                                replace=False)
        return [int(eligible[i]) for i in pick], excluded


class CapacityProportionalPolicy(AllocationPolicy):
    """Sample the cohort with P(k) ∝ 1 / t_k (predicted capacity), the
    selection reading of arXiv:1910.13067; equal bandwidth split.

    Approximation note: ``rng.choice(..., replace=False, p=p)`` draws
    sequentially with renormalization after each pick, which is NOT the
    exact "probability-proportional-to-size without replacement" design
    (inclusion probabilities differ from k·p_k, most visibly for heavy
    p's near 1/k).  It preserves the intended ordering — faster clients
    are strictly more likely — which is all the policy relies on."""
    name = "capacity_proportional"

    def select(self, state):
        est = state.est
        n = len(est.clients)
        cap = 1.0 / np.maximum(est.time_s, 1e-9)
        cap = np.where(np.isfinite(cap), cap, 0.0)
        p = cap / cap.sum()
        assert math.isclose(float(p.sum()), 1.0, rel_tol=1e-9), \
            f"selection probabilities must renormalize to 1, got {p.sum()}"
        pick = state.rng.choice(n, size=min(state.k, n), replace=False, p=p)
        return [int(est.clients[i]) for i in pick], {}


class BandwidthOptPolicy(AllocationPolicy):
    """Minimize the sync-round barrier max_k t_k under Σ_k W_k ≤ budget.

    The arXiv:1910.13067 capacity form: client k finishing by time T
    needs W_k(T) = bits / (s_k · (T − t_comp,k)) with s_k = log2(1+γ_k)
    its spectral efficiency this round.  Each W_k(T) is decreasing in T,
    so the minimal feasible barrier T* solves Σ_k W_k(T) = budget —
    found by bisection; the slack from the final bracket is handed back
    pro rata so the full budget is always in the air.  The cohort itself
    is the paper's uniform sample, which keeps bytes (and, under a fixed
    seed, the cohort) identical to ``uniform`` — only the per-client
    subchannel widths, and therefore the barrier, change."""
    name = "bandwidth_opt"

    def __init__(self, iters: int = 64):
        self.iters = int(iters)

    def select(self, state):
        return self._uniform_ids(state), {}

    def allocate(self, ids, state):
        ids = [int(i) for i in ids]
        if not ids:
            return {}
        bits = state.up_bits()
        if bits <= 0.0:          # nothing to upload: any split is optimal
            return super().allocate(ids, state)
        pos = {int(c): i for i, c in enumerate(state.est.clients)}
        sel = np.asarray([pos[i] for i in ids], dtype=int)
        s = np.maximum(state.spectral_eff[sel], 1e-9)   # bits/s/Hz
        tc = np.asarray(state.t_comp_s[sel], dtype=float)
        bits = bits * state.mult()[sel]   # m slots on one device = m payloads
        budget = float(state.budget_hz)

        def need(T: float) -> float:
            gap = T - tc
            if np.any(gap <= 0.0):
                return float("inf")
            return float((bits / (s * gap)).sum())

        lo = float(tc.max())                  # infeasible: zero air time
        hi = max(2.0 * lo, lo + 1e-6)
        for _ in range(200):
            if need(hi) <= budget:
                break
            hi *= 2.0
        for _ in range(self.iters):
            mid = 0.5 * (lo + hi)
            if need(mid) <= budget:
                hi = mid
            else:
                lo = mid
        w = bits / (s * np.maximum(hi - tc, 1e-12))
        w *= budget / w.sum()                 # hand back the bracket slack
        return {i: Allocation(bandwidth_hz=float(wk))
                for i, wk in zip(ids, w)}


class EnergyOptPolicy(AllocationPolicy):
    """Minimize the cohort's total energy Σ_k E_k subject to every
    selected client finishing within ``deadline_s`` — the dual of
    ``bandwidth_opt`` (which minimizes the barrier subject to the
    budget; here the deadline is the constraint and energy the
    objective), following the resource-allocation formulation of
    arXiv:1910.13067.

    With E_k = e_comp,k + P_tx · t_up,k and t_up,k = c_k / W_k on the
    capacity form (c_k = bits_k / s_k, s_k = log2(1+γ_k) this round's
    spectral efficiency), compute energy is width-independent, so the
    problem is  min Σ_k c_k / W_k  s.t.  Σ_k W_k ≤ budget  and
    W_k ≥ W_min,k = c_k / (deadline − t_comp,k)  (the narrowest
    subchannel that still meets the deadline).  The KKT point is
    W_k = max(W_min,k, √c_k / λ) with λ pinned by the budget — found by
    per-client bisection on λ; the final bracket's slack is scaled back
    pro rata (scaling up never violates a W_min), so the full budget is
    in the air and Σ energy is the constrained minimum — strictly below
    the uniform split whenever the c_k are heterogeneous (Cauchy–
    Schwarz).

    Feasibility-aware selection: a uniform proposal, then clients whose
    compute alone busts the deadline (no width can save them) and, in
    ascending-W_min order, clients whose minimal widths no longer fit
    the remaining budget are excluded with reasons.  If fewer than
    ``min_clients`` are feasible, the cheapest remaining clients are
    force-kept at (at least) the equal-split width; the deadline grant
    is re-derived from the widths actually handed out — a kept client
    whose width cannot guarantee the deadline is granted none (inf): the
    policy insists on its progress, so the runtime must not cut it
    off."""
    name = "energy_opt"

    def __init__(self, deadline_s: float, min_clients: int = 1,
                 iters: int = 64):
        self.deadline_s = float(deadline_s)
        self.min_clients = int(min_clients)
        self.iters = int(iters)

    def _capacity(self, ids, state):
        """Per-client (c_k, t_comp,k, W_min,k) on the capacity form;
        W_min is inf where no width meets the deadline."""
        pos = {int(c): i for i, c in enumerate(state.est.clients)}
        sel = np.asarray([pos[int(i)] for i in ids], dtype=int)
        s = np.maximum(state.spectral_eff[sel], 1e-9)
        tc = np.asarray(state.t_comp_s[sel], dtype=float)
        c = state.up_bits() * state.mult()[sel] / s   # needed W·t_up (Hz·s)
        gap = self.deadline_s - tc
        w_min = np.where(gap > 0.0, c / np.maximum(gap, 1e-300), np.inf)
        w_min = np.where((c <= 0.0) & (gap > 0.0), 0.0, w_min)
        return c, tc, w_min

    def _feasible(self, w_min, tc, budget):
        """Greedy ascending-W_min packing into the budget (deterministic:
        ties broken by compute time) — the shared feasibility rule select
        and allocate both apply, so they can never disagree."""
        feas = np.zeros(len(w_min), dtype=bool)
        used = 0.0
        for j in np.lexsort((tc, w_min)):
            if np.isfinite(w_min[j]) and used + w_min[j] <= budget * (1 + 1e-12):
                feas[j] = True
                used += w_min[j]
        return feas

    def select(self, state):
        ids = self._uniform_ids(state)
        if not ids:
            return ids, {}
        c, tc, w_min = self._capacity(ids, state)
        budget = float(state.budget_hz)
        feas = self._feasible(w_min, tc, budget)
        order = np.lexsort((tc, w_min))
        keep = [j for j in order if feas[j]]
        forced = [j for j in order if not feas[j]][:max(
            0, self.min_clients - len(keep))]
        kept = set(keep) | set(forced)
        free = budget - float(w_min[feas].sum())
        excluded = {}
        for j in range(len(ids)):
            if j in kept:
                continue
            if not np.isfinite(w_min[j]):
                excluded[int(ids[j])] = (
                    f"compute alone takes {tc[j]:.3g}s ≥ deadline "
                    f"{self.deadline_s:g}s — infeasible at any bandwidth")
            else:
                excluded[int(ids[j])] = (
                    f"needs ≥ {w_min[j]:.3g} Hz to finish by "
                    f"{self.deadline_s:g}s but only {max(free, 0.0):.3g} Hz "
                    f"of the {budget:.3g} Hz budget remains")
        return [int(ids[j]) for j in sorted(kept)], excluded

    def allocate(self, ids, state):
        ids = [int(i) for i in ids]
        if not ids:
            return {}
        c, tc, w_min = self._capacity(ids, state)
        budget = float(state.budget_hz)
        feas = self._feasible(w_min, tc, budget)
        # floors: a feasible client holds its minimal deadline-meeting
        # width; a force-kept (infeasible) client holds the equal-split
        # share, like DeadlinePolicy's keeps — never a vanishing sliver
        # of bisection slack (an inf-deadline client on a ~0 Hz channel
        # would blow the barrier and Σ energy unboundedly).  If the
        # combined floors overflow the budget the guarantees are jointly
        # unsatisfiable — everyone shrinks pro rata and the deadline
        # grant below re-derives from the widths actually handed out.
        w_floor = np.where(feas, w_min, budget / len(ids))
        total_floor = float(w_floor.sum())
        if total_floor > budget:
            w_floor = w_floor * (budget / total_floor)
        sq = np.sqrt(np.maximum(c, 0.0))
        if sq.sum() <= 0.0:                    # nothing to upload
            w = np.maximum(w_floor, budget / len(ids))
        else:
            lo, hi = 0.0, budget / sq.sum()
            for _ in range(self.iters):
                mid = 0.5 * (lo + hi)
                if float(np.maximum(w_floor, mid * sq).sum()) <= budget:
                    lo = mid
                else:
                    hi = mid
            w = np.maximum(w_floor, lo * sq)
        tot = float(w.sum())
        if tot <= 0.0:
            w = np.full(len(ids), budget / len(ids))
        else:
            w = w * (budget / tot)             # hand back the bracket slack
        # grant the deadline iff the width actually handed out still
        # guarantees it (W ≥ W_min) — a force-kept client whose equal
        # share happens to meet the deadline earns the grant, one whose
        # floor was shrunk below W_min loses it (inf: runtime must not
        # cut off a client the policy could not provision)
        ok = w >= w_min * (1.0 - 1e-9)
        return {i: Allocation(
                    bandwidth_hz=float(wk),
                    deadline_s=(self.deadline_s if k else float("inf")))
                for i, wk, k in zip(ids, w, ok)}


class AdaptiveCodecPolicy(AllocationPolicy):
    """Uniform cohort + equal split, but each client's top-k upload ratio
    is scheduled from its sampled channel rate: a client whose allocated
    subchannel is r× the cohort median runs top-k at ``ratio`` · r
    (clipped to [ratio_floor, 1]), so slow links send sparser payloads
    and the uplink barrier flattens.  A client whose scheduled format
    would cost at least as many wire bytes as the base codec (top-k
    ships value + index, 8 B per kept element, so ratio ≥ 0.5 dominates
    a dense 4 B/element payload) keeps the base codec instead —
    sparsifying is only ever a discount.  Sparsification zeroes
    coordinates, which only additive payloads survive — the policy
    refuses non-summable plans (``needs_summable``)."""
    name = "adaptive_codec"
    needs_summable = True

    def __init__(self, ratio: float = 0.25, ratio_floor: float = 0.02):
        if not 0.0 < ratio <= 1.0:
            raise ValueError(f"adaptive_codec ratio must be in (0, 1], "
                             f"got {ratio}")
        self.ratio = float(ratio)
        self.ratio_floor = float(ratio_floor)

    def select(self, state):
        return self._uniform_ids(state), {}

    def allocate(self, ids, state):
        if not state.summable:
            raise ValueError(
                "adaptive_codec schedules per-client top-k sparsification, "
                "which is only meaningful for additive (summable) payloads; "
                "this plan uploads distinct models/components")
        from repro.fed.codecs import TopKCodec  # late: avoid edge<->fed cycle

        base = super().allocate(ids, state)
        if not base:
            return base
        pos = {int(c): i for i, c in enumerate(state.est.clients)}
        sel = np.asarray([pos[int(i)] for i in ids], dtype=int)
        rate = (np.asarray([base[int(i)].bandwidth_hz for i in ids])
                * np.maximum(state.spectral_eff[sel], 1e-9))
        ref = float(np.median(rate))
        ratios = np.clip(self.ratio * rate / max(ref, 1e-12),
                         self.ratio_floor, 1.0)
        base_bytes = sum(state.wire_bytes(None))
        out = {}
        for i, r in zip(ids, ratios):
            codec = TopKCodec(float(r))
            if sum(state.wire_bytes(codec)) >= base_bytes:
                codec = None    # dominated format: keep the base codec
            out[int(i)] = Allocation(
                bandwidth_hz=base[int(i)].bandwidth_hz, codec=codec)
        return out


# ---------------------------------------------------------------------------
# Registry (mirrors repro.fed.strategies / repro.fed.codecs)
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[..., AllocationPolicy]] = {}


def register(name: str,
             factory: Optional[Callable[..., AllocationPolicy]] = None):
    """Register ``factory(**knobs) -> AllocationPolicy`` under ``name``.
    Usable as a decorator on a policy class or called directly."""

    def _do(f):
        try:
            f.name = name
        except (AttributeError, TypeError):
            pass
        _REGISTRY[name] = f
        return f

    return _do if factory is None else _do(factory)


def get(name: str) -> Callable[..., AllocationPolicy]:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown allocation policy {name!r}; known: {names()}")
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)


def make_policy(name: str, **kw) -> AllocationPolicy:
    """Build a policy by name.  ``kw`` may be a superset of the policy's
    knobs (EdgeConfig passes every policy knob it carries); anything the
    factory does not accept is dropped."""
    factory = get(name)
    try:
        params = inspect.signature(factory).parameters
    except (TypeError, ValueError):
        return factory(**kw)
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params.values()):
        return factory(**kw)
    return factory(**{k: v for k, v in kw.items() if k in params})


register("uniform", UniformPolicy)
register("deadline", DeadlinePolicy)
register("energy_threshold", EnergyThresholdPolicy)
register("capacity_proportional", CapacityProportionalPolicy)
register("bandwidth_opt", BandwidthOptPolicy)
register("energy_opt", EnergyOptPolicy)
register("adaptive_codec", AdaptiveCodecPolicy)
