"""Back-compat shim: the client-selection ``Scheduler`` surface became
the per-client resource-allocation API in :mod:`repro.edge.allocation`.

The old ``Scheduler.select(k, est, rng) -> (ids, dropped)`` could only
say *who* transmits; the paper's formulation allocates *how much* of the
wireless budget each client gets.  ``AllocationPolicy.decide(RoundState)
-> RoundDecision`` returns, per selected client, an ``Allocation``
(bandwidth from a shared round budget, optional per-client codec,
deadline) plus the excluded ids with reasons.  The four legacy policies
live on as uniform-split allocation policies under their
``make_scheduler``-era names (``uniform`` / ``deadline`` /
``energy_threshold`` / ``capacity_proportional``), constructible through
the same ``EdgeConfig`` knobs.
"""
from repro.edge.allocation import (  # noqa: F401
    Allocation, AllocationPolicy, CapacityProportionalPolicy, ClientEstimate,
    DeadlinePolicy, EnergyThresholdPolicy, RoundDecision, RoundState,
    UniformPolicy, make_policy,
)

# legacy aliases (PR-1 names); new code should import from edge.allocation
Scheduler = AllocationPolicy
UniformScheduler = UniformPolicy
DeadlineScheduler = DeadlinePolicy
EnergyThresholdScheduler = EnergyThresholdPolicy
CapacityProportionalScheduler = CapacityProportionalPolicy
make_scheduler = make_policy
