"""Client-selection policies for the edge runtime.

Every scheduler sees the same picture — the eligible client ids and a
per-client ``ClientEstimate`` (predicted round time and energy under the
current channel/fleet state) — and returns the cohort to dispatch plus
the ids it deliberately excluded.  Bytes are policy-independent; only
who transmits (and therefore the round's wall time and energy) changes.

Policies:
  * uniform              — sample k uniformly (the paper's protocol).
  * deadline             — uniform proposal, then drop clients whose
                           predicted finish exceeds the round deadline
                           (straggler dropping; the quantile-barrier view
                           of synchronous FEEL).
  * energy_threshold     — exclude clients whose battery is below a floor
                           or whose round energy exceeds a per-round
                           budget, à la the threshold-based data-exclusion
                           design of arXiv:2104.05509.
  * capacity_proportional— sample with probability proportional to
                           predicted capacity 1/t_k (fast links + fast
                           devices more likely), the resource-allocation
                           reading of arXiv:1910.13067.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class ClientEstimate:
    """Predicted per-client round cost under current channel/fleet state."""
    clients: np.ndarray      # (n,) eligible ids
    time_s: np.ndarray       # (n,) predicted compute + uplink time
    energy_j: np.ndarray     # (n,) predicted compute + uplink energy
    battery_j: np.ndarray    # (n,) remaining budget

    def for_ids(self, ids) -> "ClientEstimate":
        pos = {int(c): i for i, c in enumerate(self.clients)}
        sel = np.asarray([pos[int(i)] for i in ids], dtype=int)
        return ClientEstimate(self.clients[sel], self.time_s[sel],
                              self.energy_j[sel], self.battery_j[sel])


class Scheduler:
    name = "base"

    def select(self, k: int, est: ClientEstimate, rng: np.random.Generator
               ) -> tuple[list[int], list[int]]:
        """-> (selected ids, excluded ids).  k is the target cohort size."""
        raise NotImplementedError


class UniformScheduler(Scheduler):
    name = "uniform"

    def select(self, k, est, rng):
        n = len(est.clients)
        pick = rng.choice(n, size=min(k, n), replace=False)
        return [int(est.clients[i]) for i in pick], []


class DeadlineScheduler(Scheduler):
    """Uniform proposal, then drop predicted stragglers past ``deadline_s``.

    Keeps at least ``min_clients`` (the fastest) so a tight deadline can
    never stall training entirely."""
    name = "deadline"

    def __init__(self, deadline_s: float, min_clients: int = 1):
        self.deadline_s = float(deadline_s)
        self.min_clients = int(min_clients)

    def select(self, k, est, rng):
        n = len(est.clients)
        pick = rng.choice(n, size=min(k, n), replace=False)
        sub = est.for_ids(est.clients[pick])
        keep = sub.time_s <= self.deadline_s
        if keep.sum() < self.min_clients:
            order = np.argsort(sub.time_s)
            keep = np.zeros(len(sub.clients), dtype=bool)
            keep[order[:self.min_clients]] = True
        selected = [int(c) for c in sub.clients[keep]]
        dropped = [int(c) for c in sub.clients[~keep]]
        return selected, dropped


class EnergyThresholdScheduler(Scheduler):
    """Exclude depleted clients (battery below ``battery_floor_j``) and
    clients whose predicted round energy exceeds ``round_budget_j``."""
    name = "energy_threshold"

    def __init__(self, battery_floor_j: float = 0.0,
                 round_budget_j: float = float("inf")):
        self.battery_floor_j = float(battery_floor_j)
        self.round_budget_j = float(round_budget_j)

    def select(self, k, est, rng):
        ok = ((est.battery_j > self.battery_floor_j)
              & (est.energy_j <= self.round_budget_j)
              & (est.energy_j <= est.battery_j))
        eligible = est.clients[ok]
        excluded = [int(c) for c in est.clients[~ok]]
        if len(eligible) == 0:
            return [], excluded
        pick = rng.choice(len(eligible), size=min(k, len(eligible)),
                          replace=False)
        return [int(eligible[i]) for i in pick], excluded


class CapacityProportionalScheduler(Scheduler):
    """Sample without replacement with P(k) ∝ 1 / t_k (predicted)."""
    name = "capacity_proportional"

    def select(self, k, est, rng):
        n = len(est.clients)
        cap = 1.0 / np.maximum(est.time_s, 1e-9)
        p = cap / cap.sum()
        pick = rng.choice(n, size=min(k, n), replace=False, p=p)
        return [int(est.clients[i]) for i in pick], []


def make_scheduler(name: str, **kw) -> Scheduler:
    if name == "uniform":
        return UniformScheduler()
    if name == "deadline":
        return DeadlineScheduler(deadline_s=kw.get("deadline_s", 1.0),
                                 min_clients=kw.get("min_clients", 1))
    if name == "energy_threshold":
        return EnergyThresholdScheduler(
            battery_floor_j=kw.get("battery_floor_j", 0.0),
            round_budget_j=kw.get("round_budget_j", float("inf")))
    if name == "capacity_proportional":
        return CapacityProportionalScheduler()
    raise ValueError(f"unknown scheduler {name!r}; known: uniform, deadline, "
                     "energy_threshold, capacity_proportional")
