"""The lint engine: source loading, the ``Rule`` protocol + registry,
pragma suppression, baselines, and the runner.

Everything here is **pure stdlib + pure AST**: the analyzer must never
import the modules it lints (no jax, no numpy), so the CI job runs in
seconds on a bare Python install.  Rules register themselves mirroring
the strategy / codec / policy registries::

    @register
    class MyRule(Rule):
        id = "RPL099"
        title = "my-contract"
        description = "one line for --list-rules / reports"

        def check(self, mod):
            return [self.finding(mod, node, "message") for node in ...]

Suppression layers, innermost first:

  * pragma — ``# repro: allow[RPL001]`` on the finding's line (or on a
    comment-only line directly above it) suppresses the named rules;
    ``allow[*]`` suppresses every rule.  Pragmas are the documented
    opt-in for sites that *intend* to break a contract (CAT_WALL
    tracing, seeded-RNG shims).
  * baseline — a committed JSON file of grandfathered finding
    fingerprints (rule + path + line-content hash, count-aware so
    moved lines don't churn).  New findings never match old
    fingerprints; fixing a finding leaves a stale entry that
    ``--write-baseline`` garbage-collects.
"""
from __future__ import annotations

import abc
import ast
import hashlib
import io
import json
import os
import tokenize
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

PRAGMA_PREFIX = "repro:"
PRAGMA_ALLOW = "allow["
BASELINE_DEFAULT = "analysis-baseline.json"
_SKIP_DIRS = {".git", "__pycache__", ".ruff_cache", ".mypy_cache",
              ".pytest_cache", "node_modules", ".venv", "venv"}


# ---------------------------------------------------------------------------
# Findings
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""
    rule: str
    path: str          # posix-style path as given on the command line
    line: int          # 1-based
    col: int           # 0-based, ast convention
    message: str
    snippet: str = ""  # the stripped source line, for fingerprints/reports

    def fingerprint(self) -> str:
        """Line-number-free identity: rule + path + content hash, so a
        baselined finding survives unrelated edits above it."""
        h = hashlib.sha1(self.snippet.strip().encode()).hexdigest()[:12]
        return f"{self.rule}:{self.path}:{h}"

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1} {self.rule} {self.message}"

    def as_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "snippet": self.snippet,
                "fingerprint": self.fingerprint()}


# ---------------------------------------------------------------------------
# Parsed module + shared AST helpers
# ---------------------------------------------------------------------------
class ModuleSource:
    """One parsed file plus the derived indexes every rule wants:
    parent links, dotted-name resolution, import origins, and the
    pragma table."""

    def __init__(self, path: str, text: str):
        self.path = path.replace(os.sep, "/")
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        self.parents: dict[ast.AST, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        self.imports = self._import_map()
        self.pragmas = self._pragma_map()

    @classmethod
    def load(cls, path: str) -> "ModuleSource":
        with open(path, encoding="utf-8") as f:
            return cls(path, f.read())

    # -- structure -------------------------------------------------------
    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        while node in self.parents:
            node = self.parents[node]
            yield node

    def enclosing_function(self, node: ast.AST):
        for anc in self.ancestors(node):
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return anc
        return None

    def at_module_level(self, node: ast.AST) -> bool:
        """No enclosing function or class body (plain module statements,
        possibly nested in module-level if/try blocks)."""
        return not any(isinstance(a, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.ClassDef, ast.Lambda))
                       for a in self.ancestors(node))

    def dotted(self, node: ast.AST) -> Optional[str]:
        """``a.b.c`` for a Name/Attribute chain rooted at a Name, else
        None (calls/subscripts in the chain break resolution)."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
            return ".".join(reversed(parts))
        return None

    def resolve(self, node: ast.AST) -> Optional[str]:
        """Like :meth:`dotted`, with the first segment expanded through
        the module's imports — ``config.update`` under ``from jax import
        config`` resolves to ``jax.config.update``."""
        d = self.dotted(node)
        if d is None:
            return None
        head, _, rest = d.partition(".")
        origin = self.imports.get(head)
        if origin is None:
            return d
        return f"{origin}.{rest}" if rest else origin

    def _import_map(self) -> dict[str, str]:
        out: dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    out[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    if a.name == "*":
                        continue
                    out[a.asname or a.name] = f"{node.module}.{a.name}"
        return out

    # -- pragmas ---------------------------------------------------------
    def _pragma_map(self) -> dict[int, frozenset]:
        """{line: rules allowed there}; a pragma on a comment-only line
        also covers the next line (for calls too long to share a line)."""
        out: dict[int, set] = {}
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(self.text).readline))
        except tokenize.TokenError:
            return {}
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            rules = parse_pragma(tok.string)
            if rules is None:
                continue
            line = tok.start[0]
            out.setdefault(line, set()).update(rules)
            code = self.lines[line - 1][:tok.start[1]].strip()
            if not code:  # comment-only line: cover the line below too
                out.setdefault(line + 1, set()).update(rules)
        return {k: frozenset(v) for k, v in out.items()}

    def suppressed(self, finding: Finding) -> bool:
        rules = self.pragmas.get(finding.line)
        return bool(rules) and ("*" in rules or finding.rule in rules)


def parse_pragma(comment: str) -> Optional[set]:
    """``# repro: allow[RPL001,RPL005]`` -> {"RPL001", "RPL005"};
    ``allow[*]`` -> {"*"}; non-pragma comments -> None."""
    body = comment.lstrip("#").strip()
    if not body.startswith(PRAGMA_PREFIX):
        return None
    body = body[len(PRAGMA_PREFIX):].strip()
    if not body.startswith(PRAGMA_ALLOW) or "]" not in body:
        return None
    inner = body[len(PRAGMA_ALLOW):body.index("]")]
    return {r.strip() for r in inner.split(",") if r.strip()}


def contains_name(node: ast.AST, names: set) -> bool:
    """True if any Name in ``node``'s subtree is in ``names``."""
    return any(isinstance(n, ast.Name) and n.id in names
               for n in ast.walk(node))


# ---------------------------------------------------------------------------
# Rule protocol + registry (mirrors strategies / codecs / policies)
# ---------------------------------------------------------------------------
class Rule(abc.ABC):
    """One static contract.  Subclass, set ``id``/``title``/
    ``description``, implement ``check``, and decorate with
    :func:`register`."""

    id: str = ""
    title: str = ""
    description: str = ""

    def applies_to(self, path: str) -> bool:
        """Path filter (posix-style path); default: every file."""
        return True

    @abc.abstractmethod
    def check(self, mod: ModuleSource) -> list:
        """-> [Finding] for one parsed module."""

    def finding(self, mod: ModuleSource, node: ast.AST,
                message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        snippet = mod.lines[line - 1].strip() if line <= len(mod.lines) else ""
        return Finding(self.id, mod.path, line,
                       getattr(node, "col_offset", 0), message, snippet)


_REGISTRY: dict[str, type] = {}


def register(cls: type) -> type:
    """Class decorator: add a :class:`Rule` subclass to the registry."""
    if not cls.id:
        raise ValueError(f"rule {cls.__name__} must set a non-empty id")
    _REGISTRY[cls.id] = cls
    return cls


def get(rule_id: str) -> type:
    if rule_id not in _REGISTRY:
        raise ValueError(f"unknown rule {rule_id!r}; known: {names()}")
    return _REGISTRY[rule_id]


def names() -> list:
    return sorted(_REGISTRY)


def all_rules() -> list:
    """Fresh instances of every registered rule, id-sorted."""
    return [_REGISTRY[i]() for i in names()]


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------
@dataclass
class Baseline:
    """Grandfathered finding fingerprints with per-fingerprint counts
    (two identical lines in one file share a fingerprint)."""
    counts: dict = field(default_factory=dict)
    path: Optional[str] = None

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls(path=path)
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
        return cls(counts=dict(data.get("findings", {})), path=path)

    @classmethod
    def from_findings(cls, findings: Iterable[Finding],
                      path: Optional[str] = None) -> "Baseline":
        counts: dict = {}
        for f in findings:
            fp = f.fingerprint()
            counts[fp] = counts.get(fp, 0) + 1
        return cls(counts=counts, path=path)

    def write(self, path: Optional[str] = None) -> str:
        path = path or self.path or BASELINE_DEFAULT
        payload = {"version": 1,
                   "comment": "grandfathered repro.analysis findings; "
                              "regenerate with --write-baseline",
                   "findings": dict(sorted(self.counts.items()))}
        with open(path, "w", encoding="utf-8") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    def filter(self, findings: list) -> tuple:
        """-> (new findings, baselined count).  Consumes up to
        ``counts[fp]`` occurrences of each fingerprint."""
        budget = dict(self.counts)
        fresh, eaten = [], 0
        for f in findings:
            fp = f.fingerprint()
            if budget.get(fp, 0) > 0:
                budget[fp] -= 1
                eaten += 1
            else:
                fresh.append(f)
        return fresh, eaten


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------
def iter_py_files(paths: Iterable[str]) -> list:
    out = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
            out.extend(os.path.join(dirpath, f) for f in sorted(filenames)
                       if f.endswith(".py"))
    return out


def check_module(mod: ModuleSource,
                 rules: Optional[list] = None) -> list:
    """All (pragma-filtered) findings for one parsed module."""
    findings = []
    for rule in (rules if rules is not None else all_rules()):
        if not rule.applies_to(mod.path):
            continue
        findings.extend(f for f in rule.check(mod)
                        if not mod.suppressed(f))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings


def run_paths(paths: Iterable[str], rules: Optional[list] = None,
              on_error: Optional[Callable] = None) -> list:
    """Lint every .py under ``paths``.  Unparseable files become
    synthetic ``PARSE`` findings (a lint gate must not skip code it
    cannot read)."""
    findings = []
    for path in iter_py_files(paths):
        try:
            mod = ModuleSource.load(path)
        except (SyntaxError, UnicodeDecodeError) as e:
            line = getattr(e, "lineno", 1) or 1
            findings.append(Finding("PARSE", path.replace(os.sep, "/"),
                                    line, 0, f"could not parse: {e}"))
            if on_error is not None:
                on_error(path, e)
            continue
        findings.extend(check_module(mod, rules=rules))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings
