"""RPL002 — x64-hygiene: keep float64 a *scoped* choice.

PR 7 established the convention: the fleet's jitted kernels run under
``with jax.experimental.enable_x64():`` at their call sites, so x64 is
an explicitly scoped property of the fleet fast path — never a
process-global flip that silently changes every other kernel's dtypes
(the Pallas kernels and the fed training loop are f32).

Two checks:

  * a module-level ``jax.config.update(...)`` anywhere in the linted
    tree (the global flip: importing the module changes numerics for
    the whole process);
  * in ``edge/fleet/`` files, any call to a function the same module
    decorated with ``jax.jit`` must sit lexically inside a
    ``with enable_x64():`` block.
"""
from __future__ import annotations

import ast

from repro.analysis.core import ModuleSource, Rule, register

JIT_NAMES = {"jax.jit", "jit"}
PARTIAL_NAMES = {"partial", "functools.partial"}
ENABLE_X64 = {"enable_x64", "jax.experimental.enable_x64"}


def jit_decorated_functions(mod: ModuleSource) -> dict:
    """{name: FunctionDef} for every function the module decorates with
    ``@jax.jit`` or ``@partial(jax.jit, ...)``."""
    out = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and jit_static_argnames(mod, node) is not None:
            out[node.name] = node
    return out


def jit_static_argnames(mod: ModuleSource, fn: ast.FunctionDef):
    """None if ``fn`` is not jit-decorated, else the set of
    ``static_argnames`` its decorator declares (possibly empty)."""
    for dec in fn.decorator_list:
        if mod.resolve(dec) in JIT_NAMES:
            return set()
        if isinstance(dec, ast.Call):
            if mod.resolve(dec.func) in JIT_NAMES:
                return _static_names(dec)
            if mod.resolve(dec.func) in PARTIAL_NAMES and dec.args \
                    and mod.resolve(dec.args[0]) in JIT_NAMES:
                return _static_names(dec)
    return None


def _static_names(call: ast.Call) -> set:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            names = set()
            for el in ast.walk(kw.value):
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    names.add(el.value)
            return names
    return set()


def under_enable_x64(mod: ModuleSource, node: ast.AST) -> bool:
    for anc in mod.ancestors(node):
        if isinstance(anc, ast.With):
            for item in anc.items:
                expr = item.context_expr
                target = expr.func if isinstance(expr, ast.Call) else expr
                if mod.resolve(target) in ENABLE_X64:
                    return True
    return False


@register
class X64HygieneRule(Rule):
    id = "RPL002"
    title = "x64-hygiene"
    description = ("no module-level jax.config.update; calls to "
                   "jit-decorated fleet kernels must sit under "
                   "`with enable_x64():` (the PR-7 scoping)")

    def check(self, mod: ModuleSource) -> list:
        out = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            if mod.resolve(node.func) == "jax.config.update" \
                    and mod.at_module_level(node):
                out.append(self.finding(
                    mod, node,
                    "module-level jax.config.update flips numerics for "
                    "the whole process on import — scope x64 with `with "
                    "enable_x64():` at the call site instead"))
        if "edge/fleet/" in mod.path:
            out.extend(self._check_fleet_scoping(mod))
        return out

    def _check_fleet_scoping(self, mod: ModuleSource) -> list:
        jitted = jit_decorated_functions(mod)
        if not jitted:
            return []
        out = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = node.func.id if isinstance(node.func, ast.Name) else None
            if name not in jitted:
                continue
            # the decorated def itself references jax.jit, not the kernel
            if under_enable_x64(mod, node):
                continue
            out.append(self.finding(
                mod, node,
                f"call to jitted kernel {name}() outside `with "
                "enable_x64():` — fleet kernels must match the float64 "
                "numpy references (PR-7 scoping)"))
        return out
