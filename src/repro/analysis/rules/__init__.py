"""The built-in rule set.  Importing this package registers every rule
(mirroring how importing ``repro.fed.strategies`` registers the
built-in strategies); third-party rules register the same way::

    from repro.analysis import Rule, register

    @register
    class MyRule(Rule):
        id = "XYZ001"
        ...
"""
from repro.analysis.rules import (determinism, jit_purity, ledger,  # noqa: F401
                                  registry_contract, tracer_noop, x64)
