"""RPL004 — registry-contract: registered plugins declare what the
generic drivers consume.

The three registries (strategies / codecs / allocation policies) let
anyone drop in a new entry without driver edits — which also means a
structurally incomplete entry only fails deep inside a round.  This
rule front-loads the three declaration contracts:

  * a class registered with ``repro.fed.strategies`` must carry a
    ``_make_plan`` that constructs a complete ``RoundPlan`` (both
    ``phases`` and ``flops`` — the inputs CommLedger metering, edge
    estimation, and scheduling all consume);
  * a class registered with ``repro.fed.codecs`` must define
    ``wire_bytes`` (the single number that keeps plan == ledger);
  * any class defining ``decide_vectorized`` must match the
    ``FleetRoundState -> Optional[FleetDecision]`` shape: exactly
    ``(self, fstate)``, no varargs — the fleet fast path calls it
    positionally with one state.

Resolution is per-module: base classes imported from elsewhere are
assumed compliant (their defining module is linted on its own).
"""
from __future__ import annotations

import ast

from repro.analysis.core import ModuleSource, Rule, register

STRATEGY_REGISTERS = {"repro.fed.strategies.base.register",
                      "repro.fed.strategies.register",
                      "strategies.register"}
CODEC_REGISTERS = {"repro.fed.codecs.register", "codecs.register"}
POLICY_REGISTERS = {"repro.edge.allocation.register", "allocation.register"}

# a bare `register` defined in the file itself: classify by the file
_SELF_KINDS = (("fed/strategies/", "strategy"), ("fed/codecs", "codec"),
               ("edge/allocation", "policy"))

# the protocol roots only *declare* the contract (abstract methods):
# inheriting from one of these is not evidence the method exists
ABSTRACT_ROOTS = {
    "repro.fed.strategies.base.FedStrategy",
    "repro.fed.strategies.FedStrategy",
    "repro.fed.codecs.PayloadCodec",
    "repro.edge.allocation.AllocationPolicy",
    "abc.ABC", "ABC", "object",
}


def _local_classes(mod: ModuleSource) -> dict:
    return {n.name: n for n in ast.walk(mod.tree)
            if isinstance(n, ast.ClassDef)}


def _mro_chain(mod: ModuleSource, cls: ast.ClassDef):
    """(same-module class chain, saw_imported_base) — depth-first over
    bases resolvable in this module."""
    classes = _local_classes(mod)
    chain, imported, stack, seen = [], False, [cls], set()
    while stack:
        c = stack.pop(0)
        if c.name in seen:
            continue
        seen.add(c.name)
        chain.append(c)
        for base in c.bases:
            name = base.id if isinstance(base, ast.Name) else None
            if name in classes:
                stack.append(classes[name])
                continue
            resolved = mod.resolve(base)
            if resolved in ABSTRACT_ROOTS or name == "object":
                continue  # the protocol root declares, never implements
            imported = True  # an unknown concrete base: trust it
    return chain, imported


def _find_method(chain, name: str):
    for c in chain:
        for item in c.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and item.name == name:
                return item
    return None


@register
class RegistryContractRule(Rule):
    id = "RPL004"
    title = "registry-contract"
    description = ("registered strategies declare a complete RoundPlan, "
                   "registered codecs define wire_bytes, and "
                   "decide_vectorized matches the fleet signature")

    def check(self, mod: ModuleSource) -> list:
        out = []
        for cls, kind, site in self._registrations(mod):
            if kind == "strategy":
                out.extend(self._check_strategy(mod, cls, site))
            elif kind == "codec":
                out.extend(self._check_codec(mod, cls, site))
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                m = _find_method([node], "decide_vectorized")
                if m is not None:
                    out.extend(self._check_vectorized_sig(mod, node, m))
        return out

    # -- find register call sites ---------------------------------------
    def _register_kind(self, mod: ModuleSource, func: ast.AST):
        d = mod.resolve(func)
        if d in STRATEGY_REGISTERS:
            return "strategy"
        if d in CODEC_REGISTERS:
            return "codec"
        if d in POLICY_REGISTERS:
            return "policy"
        if d == "register":  # defined in this very module
            for frag, kind in _SELF_KINDS:
                if frag in mod.path:
                    return kind
        return None

    def _registrations(self, mod: ModuleSource):
        classes = _local_classes(mod)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call):
                        kind = self._register_kind(mod, dec.func)
                        if kind:
                            yield node, kind, node
            elif isinstance(node, ast.Call) and len(node.args) >= 2:
                kind = self._register_kind(mod, node.func)
                cls = node.args[1]
                if kind and isinstance(cls, ast.Name) \
                        and cls.id in classes:
                    yield classes[cls.id], kind, node

    # -- contracts -------------------------------------------------------
    def _check_strategy(self, mod, cls, site) -> list:
        chain, imported = _mro_chain(mod, cls)
        make_plan = _find_method(chain, "_make_plan")
        if make_plan is None:
            if imported:  # plan may live on the imported base — its
                return []  # module is linted separately
            return [self.finding(
                mod, site,
                f"registered strategy {cls.name} declares no _make_plan "
                "— the driver cannot meter/estimate/schedule it")]
        plan_calls = [n for n in ast.walk(make_plan)
                      if isinstance(n, ast.Call)
                      and (mod.resolve(n.func) or "").split(".")[-1]
                      == "RoundPlan"]
        if not plan_calls:
            return [self.finding(
                mod, make_plan,
                f"{cls.name}._make_plan never constructs a RoundPlan")]
        out = []
        for call in plan_calls:
            given = {kw.arg for kw in call.keywords if kw.arg}
            # positional slots are (phases, flops, ...)
            if len(call.args) >= 1:
                given.add("phases")
            if len(call.args) >= 2:
                given.add("flops")
            if any(kw.arg is None for kw in call.keywords):
                continue  # **kwargs splat: cannot prove incompleteness
            missing = [f for f in ("phases", "flops") if f not in given]
            if missing:
                out.append(self.finding(
                    mod, call,
                    f"{cls.name}._make_plan builds an incomplete "
                    f"RoundPlan: missing {', '.join(missing)} — metering "
                    "and edge scheduling consume both"))
        return out

    def _check_codec(self, mod, cls, site) -> list:
        chain, imported = _mro_chain(mod, cls)
        if _find_method(chain, "wire_bytes") is not None or imported:
            return []
        return [self.finding(
            mod, site,
            f"registered codec {cls.name} defines no wire_bytes — "
            "CommLedger metering, uplink time/energy, and scheduler "
            "estimates all consume it (plan == ledger breaks)")]

    def _check_vectorized_sig(self, mod, cls, m) -> list:
        a = m.args
        params = [p.arg for p in (a.posonlyargs + a.args)]
        problems = []
        if a.vararg or a.kwarg or a.kwonlyargs:
            problems.append("varargs/kw-only params")
        if len(params) != 2:
            problems.append(f"{len(params)} positional params (need 2)")
        if not problems:
            return []
        return [self.finding(
            mod, m,
            f"{cls.name}.decide_vectorized({', '.join(params)}) does not "
            "match the fleet contract decide_vectorized(self, fstate: "
            "FleetRoundState) -> Optional[FleetDecision] — the runtime "
            f"calls it positionally with one state ({'; '.join(problems)})")]
