"""RPL003 — jit-purity: no host syncs or Python branching on tracers
inside jit-decorated kernels.

Scope: ``edge/fleet/kernel.py`` and ``src/repro/kernels/`` — the files
whose jitted functions are the repo's hot compute path.  Inside a
function decorated with ``@jax.jit`` / ``@partial(jax.jit, ...)``:

  * ``.item()`` anywhere is a device->host sync that breaks tracing;
  * ``float()`` / ``bool()`` / ``int()`` / ``np.*`` applied to a traced
    value concretizes a tracer (TracerConversionError at best, a silent
    recompile-per-value at worst);
  * Python ``if`` / ``while`` / ``assert`` / ternary tests on a traced
    value branch at trace time — use ``lax.cond`` / ``lax.select`` /
    ``jnp.where``.

"Traced" is approximated lexically: the function's parameters minus the
decorator's ``static_argnames``, plus the parameters of functions
nested inside (loop bodies, ``lax`` callees).  Values derived through
assignments are not chased — shape-derived Python ints (``B, D =
x.shape``) stay legal, as they are at trace time.
"""
from __future__ import annotations

import ast

from repro.analysis.core import ModuleSource, Rule, contains_name, register
from repro.analysis.rules.x64 import jit_static_argnames

HOST_CASTS = {"float", "bool", "int", "complex"}


def _param_names(fn) -> list:
    a = fn.args
    return [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]


@register
class JitPurityRule(Rule):
    id = "RPL003"
    title = "jit-purity"
    description = ("no .item()/float()/bool() host syncs or Python "
                   "branching on traced values inside jax.jit functions "
                   "(fleet kernel + repro.kernels)")

    def applies_to(self, path: str) -> bool:
        return "edge/fleet/kernel" in path or "repro/kernels/" in path

    def check(self, mod: ModuleSource) -> list:
        out = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            statics = jit_static_argnames(mod, node)
            if statics is None:
                continue
            traced = {p for p in _param_names(node) if p not in statics}
            # params of nested defs/lambdas are traced when their caller
            # hands them traced values (lax callees, BlockSpec lambdas)
            for sub in ast.walk(node):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.Lambda)):
                    traced.update(p for p in _param_names(sub)
                                  if p not in statics)
            out.extend(self._check_jit_body(mod, node, traced))
        return out

    def _check_jit_body(self, mod: ModuleSource, fn, traced: set) -> list:
        out = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While, ast.IfExp, ast.Assert)):
                test = node.test
                if contains_name(test, traced):
                    kind = {ast.If: "if", ast.While: "while",
                            ast.IfExp: "ternary", ast.Assert: "assert"}[
                                type(node)]
                    out.append(self.finding(
                        mod, node,
                        f"Python {kind} on a traced value inside "
                        f"jax.jit function {fn.name}() branches at trace "
                        "time — use lax.cond/lax.select/jnp.where"))
            elif isinstance(node, ast.Call):
                out.extend(self._check_call(mod, fn, node, traced))
        return out

    def _check_call(self, mod: ModuleSource, fn, node: ast.Call,
                    traced: set) -> list:
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
                and not node.args and not node.keywords:
            return [self.finding(
                mod, node,
                f".item() inside jax.jit function {fn.name}() is a "
                "device->host sync — return the array and read it "
                "outside the jit boundary")]
        d = mod.resolve(node.func)
        if d is None:
            return []
        hit = (d in HOST_CASTS
               or d.startswith(("np.", "numpy.")))
        if hit and any(contains_name(a, traced) for a in node.args):
            return [self.finding(
                mod, node,
                f"{d}() on a traced value inside jax.jit function "
                f"{fn.name}() concretizes the tracer — keep it a jnp "
                "array (cast with .astype / jnp.asarray)")]
        return []
