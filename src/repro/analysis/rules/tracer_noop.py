"""RPL005 — tracer-noop: tracing must cost ~nothing when it is off.

The default tracer is the shared no-op ``NULL_TRACER``; the runtime
contract (tests/test_observability.py) is that untraced runs are
byte-identical *and* pay only an attribute check per instrumented site.
That breaks silently whenever a call site eagerly builds its telemetry
— an f-string, a ``%``/``.format`` render, a dict/comprehension — as an
argument, because Python evaluates arguments before the no-op method
discards them.

This rule flags tracer/metrics/audit recording calls in ``repro/edge``
and ``repro/fed`` whose arguments contain eager formatting or container
building, unless the call is guarded:

  * lexically inside ``if <...>.enabled:`` (or the else-branch of
    ``if not <...>.enabled:``), or
  * after an early-out ``if not <...>.enabled: return/continue`` at the
    top level of the enclosing function.

Helpers that are *only called* under a guard (e.g. a ``_trace_*``
method) document that contract with ``# repro: allow[RPL005]``.
"""
from __future__ import annotations

import ast

from repro.analysis.core import ModuleSource, Rule, register

HOT_PATHS = ("repro/edge/", "repro/fed/")

# unambiguous Tracer recording methods
TRACER_METHODS = {"span", "event", "record_round", "log_round", "wall_span"}
# metrics/audit methods — only tracer-ish when the receiver chain says so
METRIC_METHODS = {"counter", "gauge", "histogram", "inc", "observe", "set",
                  "add"}
RECEIVER_HINTS = {"tracer", "metrics", "audit"}


def _chain_parts(node: ast.AST) -> list:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    elif isinstance(node, ast.Call):
        parts.extend(_chain_parts(node.func))
    return parts


def _is_eager(node: ast.AST) -> bool:
    """Does this argument expression do formatting / container-building
    work that a no-op receiver would throw away?"""
    for sub in ast.walk(node):
        if isinstance(sub, (ast.JoinedStr, ast.Dict, ast.DictComp,
                            ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return True
        if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod) \
                and isinstance(sub.left, (ast.Constant, ast.JoinedStr)) \
                and isinstance(getattr(sub.left, "value", None), str):
            return True
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Attribute) \
                and sub.func.attr == "format":
            return True
    return False


def _test_mentions_enabled(test: ast.AST) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr == "enabled"
               for n in ast.walk(test))


def _branch_of(mod: ModuleSource, if_node: ast.If, node: ast.AST) -> str:
    """'body' | 'orelse' | '' — which arm of ``if_node`` contains
    ``node``."""
    child = node
    for anc in mod.ancestors(node):
        if anc is if_node:
            break
        child = anc
    if child in if_node.body:
        return "body"
    if child in if_node.orelse:
        return "orelse"
    return ""


class _EnabledGuard:
    """Shared guard analysis: is this call site reachable only when
    tracing is enabled?"""

    def __init__(self, mod: ModuleSource):
        self.mod = mod

    def guarded(self, node: ast.AST) -> bool:
        for anc in self.mod.ancestors(node):
            if isinstance(anc, ast.If) and _test_mentions_enabled(anc.test):
                negated = isinstance(anc.test, ast.UnaryOp) \
                    and isinstance(anc.test.op, ast.Not)
                branch = _branch_of(self.mod, anc, node)
                if branch == ("orelse" if negated else "body"):
                    return True
        return self._after_early_out(node)

    def _after_early_out(self, node: ast.AST) -> bool:
        fn = self.mod.enclosing_function(node)
        if fn is None:
            return False
        # the top-level statement of fn.body that (transitively) holds node
        holder = node
        for anc in self.mod.ancestors(node):
            if anc is fn:
                break
            holder = anc
        for stmt in fn.body:
            if stmt is holder:
                return False
            if isinstance(stmt, ast.If) and _test_mentions_enabled(stmt.test) \
                    and isinstance(stmt.test, ast.UnaryOp) \
                    and isinstance(stmt.test.op, ast.Not) \
                    and stmt.body \
                    and all(isinstance(s, (ast.Return, ast.Continue,
                                           ast.Raise)) for s in stmt.body):
                return True
        return False


@register
class TracerNoopRule(Rule):
    id = "RPL005"
    title = "tracer-noop"
    description = ("no eager f-string/%-format/dict building passed into "
                   "Tracer/metrics calls outside an `.enabled` guard — "
                   "NULL_TRACER must skip the work, not discard it")

    def applies_to(self, path: str) -> bool:
        return any(seg in path for seg in HOT_PATHS)

    def check(self, mod: ModuleSource) -> list:
        guard = _EnabledGuard(mod)
        out = []
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            attr = node.func.attr
            if attr in TRACER_METHODS:
                pass
            elif attr in METRIC_METHODS:
                parts = set(_chain_parts(node.func.value))
                if not (parts & RECEIVER_HINTS
                        or parts & self._aliases(mod, node)):
                    continue
            else:
                continue
            eager = [a for a in list(node.args)
                     + [kw.value for kw in node.keywords]
                     if _is_eager(a)]
            if not eager or guard.guarded(node):
                continue
            out.append(self.finding(
                mod, node,
                f"eager formatting/container building passed into "
                f".{attr}() without an `.enabled` guard — under "
                "NULL_TRACER this work runs and is thrown away; wrap the "
                "site in `if tracer.enabled:` (helpers called only under "
                "a guard take `# repro: allow[RPL005]`)"))
        return out

    def _aliases(self, mod: ModuleSource, node: ast.AST) -> set:
        """Local names assigned from tracer-ish chains in the enclosing
        function (``m = self.tracer.metrics``; ``c = tr.metrics.counter(
        ...)``) — resolved flow-insensitively, which is fine for a hint."""
        fn = mod.enclosing_function(node)
        if fn is None:
            return set()
        names = set()
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Assign) \
                    and set(_chain_parts(sub.value)) & RECEIVER_HINTS:
                for tgt in sub.targets:
                    if isinstance(tgt, ast.Name):
                        names.add(tgt.id)
        return names
