"""RPL006 — ledger-discipline: every upload is billed at its declared
wire size.

``plan == ledger`` (PR 3, audited at runtime by ``PlanAudit`` since
PR 6) holds because every ``CommLedger.upload`` call site passes the
codec's ``wire_bytes`` explicitly instead of letting the ledger fall
back to ``n_floats * 4``: a new call site that omits it silently bills
uncompressed bytes and the Theorem-3 byte accounting drifts from what
actually crossed the wire.

The receiver is matched by method name (``.upload`` /
``.upload_per_client``), which is deliberate: the repo has exactly one
``upload`` API, and a false positive on some future unrelated
``.upload`` is one pragma away.
"""
from __future__ import annotations

import ast

from repro.analysis.core import ModuleSource, Rule, register


@register
class LedgerDisciplineRule(Rule):
    id = "RPL006"
    title = "ledger-discipline"
    description = ("every CommLedger.upload/upload_per_client call passes "
                   "explicit wire_bytes — plan == ledger stays auditable "
                   "under every codec")

    def check(self, mod: ModuleSource) -> list:
        out = []
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            attr = node.func.attr
            kwargs = {kw.arg for kw in node.keywords}
            if None in kwargs:  # **kwargs splat: cannot prove the omission
                continue
            if attr == "upload" and "wire_bytes" not in kwargs:
                out.append(self.finding(
                    mod, node,
                    ".upload() without explicit wire_bytes= bills the "
                    "4-byte-float fallback — pass the phase codec's "
                    "wire_bytes(up_floats) so plan == ledger holds under "
                    "every codec"))
            elif attr == "upload_per_client" and not node.args \
                    and "wire_bytes" not in kwargs:
                out.append(self.finding(
                    mod, node,
                    ".upload_per_client() without per-client wire_bytes "
                    "— pass the billed byte array/list explicitly"))
        return out
