"""RPL001 — sim-determinism: no wall clocks or global RNG in sim paths.

The determinism suite (tests/test_determinism.py) proves same-seed
replays are bit-identical, but only on the paths it exercises.  This
rule makes the contract structural: inside ``src/repro/{edge,fed,obs}``
every random draw must come from an explicitly seeded generator
(``np.random.default_rng(seed)``, ``jax.random.PRNGKey``) and every
timestamp from the simulated ``EventClock`` — never from the host.

Opt-in wall-clock measurement (the tracer's ``CAT_WALL`` timeline, the
``BENCH_*.json`` timestamp) marks itself with ``# repro: allow[RPL001]``
so the exception is visible at the call site.
"""
from __future__ import annotations

import ast

from repro.analysis.core import ModuleSource, Rule, register

SIM_PATHS = ("repro/edge/", "repro/fed/", "repro/obs/")

WALL_CLOCKS = {
    "time.time", "time.time_ns", "time.perf_counter",
    "time.perf_counter_ns", "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
}
DATETIME_NOW = {
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "date.today", "datetime.date.today",
}
# random.Random(seed) is an explicitly seeded generator object — allowed
RANDOM_ALLOWED = {"Random"}
# the seeded Generator construction surface of numpy.random — allowed
NP_RANDOM_ALLOWED = {"default_rng", "Generator", "SeedSequence", "PCG64",
                     "PCG64DXSM", "Philox", "MT19937", "SFC64",
                     "BitGenerator"}


@register
class SimDeterminismRule(Rule):
    id = "RPL001"
    title = "sim-determinism"
    description = ("no wall clocks (time.time/datetime.now) or global RNG "
                   "(random.*, np.random.<fn>) in src/repro/{edge,fed,obs} "
                   "— sim paths must replay bit-identically")

    def applies_to(self, path: str) -> bool:
        return any(seg in path for seg in SIM_PATHS)

    def check(self, mod: ModuleSource) -> list:
        out = []
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            d = mod.resolve(node.func)
            if d is None:
                continue
            msg = self._classify(d)
            if msg is not None:
                out.append(self.finding(mod, node, msg))
        return out

    def _classify(self, d: str):
        if d in WALL_CLOCKS:
            return (f"wall-clock call {d}() in a sim path — simulated time "
                    "comes from EventClock; opt-in CAT_WALL measurement "
                    "sites take `# repro: allow[RPL001]`")
        if d in DATETIME_NOW:
            return (f"{d}() reads the host clock in a sim path — replays "
                    "must be bit-identical")
        head, _, fn = d.partition(".")
        if head == "random" and fn and "." not in fn \
                and fn not in RANDOM_ALLOWED:
            return (f"global random.{fn}() draws from the process-wide RNG "
                    "— use a seeded np.random.default_rng / "
                    "jax.random.PRNGKey stream")
        if d.startswith(("np.random.", "numpy.random.")):
            fn = d.split(".")[-1]
            if fn not in NP_RANDOM_ALLOWED:
                return (f"np.random.{fn}() uses the legacy global numpy "
                        "RNG — draw from a seeded Generator instead")
        return None
