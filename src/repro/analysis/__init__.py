"""repro.analysis — the repo's runtime contracts as static AST checks.

Six built-in rules turn invariants that the test matrices only catch at
runtime (and only on exercised paths) into structural properties that
fail in seconds on a bare Python install:

  ======== ================== ==============================================
  RPL001   sim-determinism    no wall clocks / global RNG in edge, fed, obs
  RPL002   x64-hygiene        no module-level jax.config.update; fleet
                              kernels called under ``with enable_x64():``
  RPL003   jit-purity         no host syncs / Python branching on tracers
                              inside jitted kernels
  RPL004   registry-contract  registered strategies/codecs/policies declare
                              what the generic drivers consume
  RPL005   tracer-noop        telemetry work is skipped, not discarded,
                              under NULL_TRACER
  RPL006   ledger-discipline  every upload billed at explicit wire_bytes
  ======== ================== ==============================================

CLI: ``python -m repro.analysis [--format text|json] [--baseline FILE]
[paths...]``.  Suppress one site with ``# repro: allow[RPL001]``;
grandfather existing findings into the committed baseline with
``--write-baseline``.  The package is pure stdlib and never imports the
modules it lints.
"""
from repro.analysis.core import (Baseline, Finding, ModuleSource,  # noqa: F401
                                 Rule, all_rules, check_module, get, names,
                                 register, run_paths)
