"""``python -m repro.analysis`` — the repo's contract linter.

Exit status: 0 when no un-suppressed, un-baselined findings remain;
1 otherwise.  Designed to run on a bare Python install in seconds —
nothing under :mod:`repro.analysis` imports the modules it lints (no
jax, no numpy), so the CI job needs no dependency install at all.
"""
from __future__ import annotations

import argparse
import json
import sys

import repro.analysis.rules  # noqa: F401  (registers the built-in rules)
from repro.analysis.core import (BASELINE_DEFAULT, Baseline, all_rules,
                                 run_paths)

JSON_SCHEMA_VERSION = 1


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST lint suite encoding the repo's runtime contracts "
                    "(determinism, x64 scoping, jit purity, registry "
                    "completeness, tracer no-op cost, ledger discipline) "
                    "as static checks.")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files/directories to lint (default: src)")
    p.add_argument("--format", choices=("text", "json"), default="text",
                   help="report format (default: text)")
    p.add_argument("--baseline", default=BASELINE_DEFAULT, metavar="FILE",
                   help="grandfathered-findings file (default: "
                        f"{BASELINE_DEFAULT}; silently ignored if absent)")
    p.add_argument("--no-baseline", action="store_true",
                   help="report every finding, baseline or not")
    p.add_argument("--write-baseline", action="store_true",
                   help="rewrite --baseline from the current findings "
                        "and exit 0")
    p.add_argument("--select", metavar="RULES",
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table and exit")
    return p


def _selected_rules(spec):
    rules = all_rules()
    if not spec:
        return rules
    wanted = {s.strip() for s in spec.split(",") if s.strip()}
    unknown = wanted - {r.id for r in rules}
    if unknown:
        raise SystemExit(f"unknown rule id(s): {', '.join(sorted(unknown))}; "
                         f"known: {', '.join(r.id for r in rules)}")
    return [r for r in rules if r.id in wanted]


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    rules = _selected_rules(args.select)

    if args.list_rules:
        for r in rules:
            print(f"{r.id}  {r.title:20s} {r.description}")
        return 0

    findings = run_paths(args.paths, rules=rules)

    if args.write_baseline:
        path = Baseline.from_findings(findings).write(args.baseline)
        print(f"wrote {len(findings)} finding(s) to {path}")
        return 0

    baselined = 0
    if not args.no_baseline:
        findings, baselined = Baseline.load(args.baseline).filter(findings)

    if args.format == "json":
        print(json.dumps({
            "version": JSON_SCHEMA_VERSION,
            "rules": {r.id: r.description for r in rules},
            "findings": [f.as_json() for f in findings],
            "baselined": baselined,
        }, indent=1, sort_keys=True))
    else:
        for f in findings:
            print(f.render())
            if f.snippet:
                print(f"    {f.snippet}")
        tail = f" ({baselined} baselined)" if baselined else ""
        print(f"repro.analysis: {len(findings)} finding(s){tail}")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
