"""Production mesh definitions (TPU v5e target).

A function, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py forces
512 host devices)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: "data" carries the federated client cohorts / global batch,
    "model" carries megatron+expert sharding, "pod" extends the cohort axis
    across pods (see DESIGN.md §3)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Tiny mesh for CPU-host sharding tests (requires >=data*model devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


# v5e hardware constants used by the roofline analysis (benchmarks/roofline).
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
