import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# Only the dry run sees 512 placeholder devices; tests/benches see 1 CPU.

import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ASSIGNED, INPUT_SHAPES, get
from repro.launch import mesh as meshlib
from repro.launch import hlo_cost
from repro.launch import train as trainlib
from repro.models import model as zoo
from repro.models.layers import use_mesh
from repro.utils import sharding as shd

"""Multi-pod dry run (deliverable e).

For every (architecture x input shape x mesh) combination, builds the real
step function (train_step = one federated FIM-L-BFGS round; serve_step = one
decode token; prefill = full-sequence forward), lowers it with
ShapeDtypeStruct inputs against the production mesh, compiles, and records
memory_analysis / cost_analysis / per-collective byte counts into a JSON
artifact that benchmarks/roofline.py turns into EXPERIMENTS.md §Roofline.
"""

ARRAY_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
COLL_RE = re.compile(
    r"=\s*([^=]*?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
               "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
               "u64": 8, "c64": 8, "c128": 16}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in ARRAY_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (result-shape proxy;
    '-done' ops are skipped so start/done pairs count once)."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for m in COLL_RE.finditer(hlo_text):
        kind = m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(m.group(1))
        count[kind] = count.get(kind, 0) + 1
    return {"bytes": out, "count": count,
            "total_bytes": float(sum(out.values()))}


def build_step(cfg, shape, optimizer: str, n_micro: int):
    """Returns (step_fn, arg_shapes (tuple), arg_shardings (tuple), donate)."""
    ocfg = trainlib.opt_config(cfg)
    specs = zoo.input_specs(cfg, shape)
    in_axes = zoo.input_axes(cfg, shape)

    if shape.kind == "train":
        params_s, axes, opt_s, opt_axes = trainlib.train_state_shapes(
            cfg, ocfg, optimizer)
        step = trainlib.make_train_step(cfg, ocfg, n_micro=n_micro,
                                        optimizer=optimizer)
        # donate params + optimizer state (aliased in-place update — the
        # production trainer does the same; halves the residency)
        return step, (params_s, opt_s, specs), (axes, opt_axes, in_axes), (0, 1)
    if shape.kind == "prefill":
        params_s, axes = trainlib.abstract_params(cfg)
        step = trainlib.make_prefill_step(cfg)
        return step, (params_s, specs), (axes, in_axes), ()
    # decode
    params_s, axes = trainlib.abstract_params(cfg)
    cache_s, cache_axes = trainlib.abstract_cache(
        cfg, shape.global_batch, shape.seq_len)
    step = trainlib.make_serve_step(cfg)
    return (step, (params_s, cache_s, specs["token"]),
            (axes, cache_axes, in_axes["token"]), (1,))


def run_one(arch: str, shape_name: str, multi_pod: bool,
            optimizer: str = "fim_lbfgs", n_micro: int = 16) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg = zoo.shape_variant(get(arch), shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "optimizer": optimizer, "family": cfg.family,
           "attn_variant": cfg.attn_variant}

    ok, reason = zoo.supports_shape(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    # §Perf finding (hillclimb a): a microbatch must shard evenly over the
    # cohort (pod x data) axes or GSPMD pads/replicates it — observed 4.5x
    # redundant per-chip FLOPs and 19x collective bytes on 2x16x16.  Pin the
    # microbatch to one sequence per data shard.
    data_shards = dict(mesh.shape).get("data", 1) * dict(mesh.shape).get("pod", 1)
    if shape.kind == "train":
        if cfg.train_n_micro:
            n_micro = cfg.train_n_micro  # per-arch override (FSDP archs)
        n_micro = max(1, min(n_micro, shape.global_batch // data_shards))
        rec["n_micro"] = n_micro
    t0 = time.time()
    step, arg_shapes, arg_axes, donate = build_step(cfg, shape, optimizer, n_micro)

    # arg 0 = params (TP sharding); arg 1 (train) = optimizer state, which
    # additionally ZeRO-shards over the data axes (see utils/sharding.py).
    in_shardings = []
    for i, (s, a) in enumerate(zip(arg_shapes, arg_axes, strict=True)):
        rules = None
        if shape.kind == "train" and i == 1:
            rules = shd.OPT_RULES
        elif i == 0 and cfg.fsdp:
            rules = shd.PARAM_RULES_FSDP
        in_shardings.append(shd.shardings_for_tree(s, a, mesh, rules))
    in_shardings = tuple(in_shardings)
    with use_mesh(mesh):
        jitted = jax.jit(step, in_shardings=in_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = hlo_cost.xla_cost_analysis(compiled)
    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
    if cost:
        rec["flops"] = float(cost.get("flops", -1))
        rec["bytes_accessed"] = float(cost.get("bytes accessed", -1))
    hlo_text = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo_text)
    # trip-count-aware costs (XLA's cost_analysis counts while bodies ONCE —
    # see repro/launch/hlo_cost.py; these are the roofline inputs)
    rec["hlo_cost"] = hlo_cost.analyze(hlo_text)
    rec["n_params"] = int(cfg.param_count())
    rec["n_active_params"] = int(cfg.active_param_count())
    rec["tokens"] = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    rec["kind"] = shape.kind
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
          f"flops {rec.get('flops', 0):.3g} "
          f"coll {rec['collectives']['total_bytes']:.3g}B")
    mem_str = str(mem) if mem is not None else "n/a"
    print(f"  memory_analysis: {mem_str[:300]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--mesh", default="16x16", choices=["16x16", "2x16x16", "both"])
    ap.add_argument("--optimizer", default="fim_lbfgs")
    ap.add_argument("--n-micro", type=int, default=16)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.mesh == "both" else [args.mesh == "2x16x16"]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
                if args.optimizer != "fim_lbfgs":
                    tag += f"_{args.optimizer}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: exists, skipping")
                    continue
                try:
                    rec = run_one(arch, shape, mp, args.optimizer, args.n_micro)
                except Exception as e:  # noqa: BLE001 — record & continue
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"[dryrun] done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
