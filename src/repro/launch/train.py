"""LLM-scale federated train/serve step factories + the runnable trainer.

The train step is one *federated round* with the paper's Algorithm 1
integrated as a first-class feature:

  microbatch cohorts (gradient accumulation) play the client role —
  each scan iteration computes a cohort gradient and its squared-gradient
  Fisher term (core/fim.py "microbatch" mode), the accumulated means are the
  server's ḡ and Γ̄ (the two O(d) all-reduces of Theorem 3, lowered from
  batch sharding over the ("pod","data") axes), and core/fim_lbfgs.update
  performs the VL-BFGS server step (the O(m²) scalar collectives).

`--optimizer fedavg_sgd|fedavg_adam` swaps the server step for the paper's
baselines, sharing the identical data path (that is the Table II comparison
at LLM scale).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import baselines, fim_lbfgs
from repro.models import model as zoo
from repro.utils.pytree import tree_scale


def opt_config(cfg: ArchConfig, learning_rate: float = 0.05) -> fim_lbfgs.FimLbfgsConfig:
    return fim_lbfgs.FimLbfgsConfig(
        learning_rate=learning_rate,
        m=cfg.lbfgs_m,
        damping=1e-2,
        max_step_norm=1.0,
        history_dtype=jnp.dtype(cfg.lbfgs_dtype),
        # LLM-scale configs keep the Fisher EMA / step temporaries in the
        # accumulation dtype (f32 full-param copies dominate collectives)
        state_dtype=jnp.dtype(cfg.grad_accum_dtype),
    )


def make_train_step(cfg: ArchConfig, ocfg: fim_lbfgs.FimLbfgsConfig,
                    n_micro: int = 4, optimizer: str = "fim_lbfgs"):
    """(params, opt_state, batch) -> (params, opt_state, stats)."""

    def train_step(params, opt_state, batch):
        B = jax.tree.leaves(batch)[0].shape[0]
        nm = min(n_micro, B)
        micro = jax.tree.map(
            lambda x: x.reshape((nm, B // nm) + x.shape[1:]), batch)

        def cohort(carry, mb):
            # Both accumulators share the GRADIENT's sharding — updating the
            # (differently-sharded) Fisher EMA state per microbatch instead
            # made GSPMD all-gather f32 diag slices per layer per microbatch
            # (§Perf hillclimb b, iter 3: 589 GB/chip of f32 all-gather).
            gsum, gsqsum, lsum = carry
            (loss, _metrics), grad = jax.value_and_grad(
                lambda p: zoo.loss_fn(p, cfg, mb), has_aux=True)(params)
            gsum = jax.tree.map(lambda a, g: a + g.astype(a.dtype), gsum, grad)
            gsqsum = jax.tree.map(
                lambda a, g: a + jnp.square(g.astype(a.dtype)), gsqsum, grad)
            return (gsum, gsqsum, lsum + loss), None

        accum_dtype = jnp.dtype(cfg.grad_accum_dtype)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        (gsum, gsqsum, lsum), _ = jax.lax.scan(
            cohort, (zeros, zeros, jnp.zeros(())), micro)
        grad = tree_scale(1.0 / nm, gsum)
        fim_diag = tree_scale(1.0 / nm, gsqsum)  # mean of cohort g² = Γ̄

        if optimizer == "fim_lbfgs":
            new_params, new_state, stats = fim_lbfgs.update(
                opt_state, params, grad, fim_diag, ocfg)
        elif optimizer == "fedavg_adam":
            new_params, new_state, stats = baselines.adam_update(
                opt_state, params, grad, ocfg.learning_rate)
        else:
            new_params, new_state, stats = baselines.sgd_update(
                opt_state, params, grad, ocfg.learning_rate)
        stats = dict(stats)
        stats["loss"] = lsum / nm
        return new_params, new_state, stats

    return train_step


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, batch):
        return zoo.prefill_fn(params, cfg, batch)

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, cache, token):
        return zoo.decode_fn(params, cfg, cache, token)

    return serve_step


def init_train_state(cfg: ArchConfig, ocfg, key, optimizer: str = "fim_lbfgs"):
    params, axes = zoo.init(cfg, key)
    if optimizer == "fim_lbfgs":
        opt_state = fim_lbfgs.init(params, ocfg)
        opt_axes = fim_lbfgs.state_axes(axes, ocfg)
    elif optimizer == "fedavg_adam":
        opt_state = baselines.adam_init(params)
        opt_axes = baselines.AdamState(mu=axes, nu=axes, step="")
    else:
        opt_state = baselines.sgd_init(params)
        opt_axes = baselines.SgdState(momentum=axes, step="")
    return params, axes, opt_state, opt_axes


def abstract_params(cfg: ArchConfig):
    """(ShapeDtypeStruct params tree, axes tree) without allocating anything:
    run init under eval_shape, capturing the static axes via a side channel."""
    captured = {}

    def f(key):
        p, a = zoo.init(cfg, key)
        captured["axes"] = a
        return p

    shapes = jax.eval_shape(f, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return shapes, captured["axes"]


def train_state_shapes(cfg: ArchConfig, ocfg, optimizer: str = "fim_lbfgs"):
    """Abstract (params, axes, opt_state, opt_axes) for the dry run."""
    params_s, axes = abstract_params(cfg)
    if optimizer == "fim_lbfgs":
        opt_s = jax.eval_shape(lambda p: fim_lbfgs.init(p, ocfg), params_s)
        opt_axes = fim_lbfgs.state_axes(axes, ocfg)
    elif optimizer == "fedavg_adam":
        opt_s = jax.eval_shape(baselines.adam_init, params_s)
        opt_axes = baselines.AdamState(mu=axes, nu=axes, step="")
    else:
        opt_s = jax.eval_shape(baselines.sgd_init, params_s)
        opt_axes = baselines.SgdState(momentum=axes, step="")
    return params_s, axes, opt_s, opt_axes


def abstract_cache(cfg: ArchConfig, batch: int, context: int):
    """(ShapeDtypeStruct cache, axes) for serve_step dry runs."""
    captured = {}

    def f():
        c, a = zoo.init_cache(cfg, batch, context)
        captured["axes"] = a
        return c

    shapes = jax.eval_shape(f)
    return shapes, captured["axes"]
