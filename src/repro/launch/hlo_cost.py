"""Trip-count-aware HLO cost analyzer.

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body ONCE
(verified empirically: a 10-step scanned matmul reports 1 matmul of FLOPs),
which silently undercounts every scanned-layer model by O(L x n_micro).
This analyzer re-derives the three roofline inputs from ``compiled.as_text()``
with each computation weighted by the product of the ``known_trip_count``s
of the while loops enclosing it:

  * flops            — 2 * |result| * contraction for every dot
                       (+ reduce/elementwise ignored: <1% for these models)
  * hbm bytes        — sum of (result + operand) bytes of *top-level* ops in
                       non-fusion computations: fusion boundaries are exactly
                       the buffers XLA materializes, i.e. HBM traffic
  * collective bytes — per-kind result bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute

All values are per-device (the module is the SPMD-partitioned per-device
program).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict

def xla_cost_analysis(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Newer JAX returns a flat dict; older versions return a one-element
    list of per-device dicts (and some builds return None)."""
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s16": 2,
               "u16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
               "u64": 8, "c64": 8, "c128": 16}
ARRAY_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")
# single-computation references (body=%x, calls=%x, ...)
CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
# braced lists (branch_computations={%a, %b})
CALL_LIST_RE = re.compile(r"(?:branch_computations|called_computations)=\{([^}]*)\}")
TRIP_RE = re.compile(r'known_trip_count[\\"{:\s]+n[\\"\s:]+(\d+)')
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
SKIP_BYTES_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast",
                  "constant", "iota", "while", "fusion", "call", "conditional",
                  "broadcast", "reshape", "copy-start", "copy-done"}


def _shape_elems_bytes(type_str):
    """(elems, bytes) summed over all arrays in a (possibly tuple) type."""
    elems = byts = 0
    for dt, dims in ARRAY_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * DTYPE_BYTES[dt]
    return elems, byts


def parse_module(text: str):
    """-> {comp_name: [instr dict]}, each instr: result_type, op, rest."""
    comps: dict[str, list] = {}
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = COMP_RE.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        m = INSTR_RE.match(line)
        if m:
            name, rtype, op, rest = m.groups()
            comps[cur].append({
                "name": name, "type": rtype, "op": op, "rest": rest,
                "line": stripped,
            })
    return comps


def _entry_name(text, comps):
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = COMP_RE.match(line.strip())
            if m:
                return m.group(1)
    # fallback: the computation nobody references
    referenced = set()
    for instrs in comps.values():
        for ins in instrs:
            for cm in CALL_RE.finditer(ins["line"]):
                referenced.add(cm.group(1))
            for cm in CALL_LIST_RE.finditer(ins["line"]):
                for nm in cm.group(1).split(","):
                    referenced.add(nm.strip().lstrip("%"))
    for name in comps:
        if name not in referenced:
            return name
    return next(iter(comps))


def _dot_flops(ins, symtab):
    """2 * |result| * contraction_size for a dot instruction."""
    res_elems, _ = _shape_elems_bytes(ins["type"])
    mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins["line"])
    operands = re.findall(r"%([\w.\-]+)", ins["rest"].split(")")[0])
    if not operands:
        return 0.0
    lhs_type = symtab.get(operands[0], "")
    arrays = ARRAY_RE.findall(lhs_type)
    if not arrays or mm is None:
        return 2.0 * res_elems  # unknown contraction: lower bound
    dims = [int(x) for x in arrays[0][1].split(",") if x]
    contract = 1
    for ci in (int(c) for c in mm.group(1).split(",") if c):
        if ci < len(dims):
            contract *= dims[ci]
    return 2.0 * res_elems * contract


def analyze(text: str) -> dict:
    comps = parse_module(text)
    entry = _entry_name(text, comps)

    # symbol table per computation: instr name -> result type (params incl.)
    symtabs = {}
    for cname, instrs in comps.items():
        symtabs[cname] = {i["name"]: i["type"] for i in instrs}

    # multipliers: BFS from entry; fusion comps flagged (bytes not counted)
    mult: dict[str, float] = defaultdict(float)
    fusion_comp: set[str] = set()
    mult[entry] = 1.0
    stack = [entry]
    seen_edges = set()
    while stack:
        cname = stack.pop()
        m = mult[cname]
        for ins in comps.get(cname, []):
            refs = [cm.group(1) for cm in CALL_RE.finditer(ins["line"])]
            for cm in CALL_LIST_RE.finditer(ins["line"]):
                refs.extend(s.strip().lstrip("%") for s in cm.group(1).split(","))
            if not refs:
                continue
            trip = 1.0
            if ins["op"] == "while":
                tm = TRIP_RE.search(ins["line"])
                trip = float(tm.group(1)) if tm else 1.0
            for sub in refs:
                if sub not in comps:
                    continue
                key = (cname, sub, ins["name"])
                if key in seen_edges:
                    continue
                seen_edges.add(key)
                mult[sub] += m * trip
                if ins["op"] == "fusion":
                    fusion_comp.add(sub)
                stack.append(sub)

    flops = 0.0
    hbm = 0.0
    coll: dict[str, float] = defaultdict(float)
    coll_n: dict[str, int] = defaultdict(int)
    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        st = symtabs[cname]
        in_fusion = cname in fusion_comp
        for ins in instrs:
            op = ins["op"]
            if op in ("dot", "convolution"):
                flops += m * _dot_flops(ins, st)
            for ck in COLLECTIVES:
                if op == ck or op == ck + "-start":
                    _, b = _shape_elems_bytes(ins["type"])
                    coll[ck] += m * b
                    coll_n[ck] += int(m)
            if not in_fusion and op not in SKIP_BYTES_OPS and not op.endswith("-done"):
                _, rb = _shape_elems_bytes(ins["type"])
                ob = 0
                for opr in re.findall(r"%([\w.\-]+)", ins["rest"]):
                    if opr in st:
                        _, b = _shape_elems_bytes(st[opr])
                        ob += b
                hbm += m * (rb + min(ob, 10 * rb if rb else ob))
    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collective_bytes": dict(coll),
        "collective_total": float(sum(coll.values())),
        "collective_count": dict(coll_n),
    }


if __name__ == "__main__":
    import sys
    print(json.dumps(analyze(open(sys.argv[1]).read()), indent=1))
