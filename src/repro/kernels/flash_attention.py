"""Pallas TPU kernel: blocked online-softmax (flash) attention with GQA,
causal masking and optional sliding window.

This is the TPU target for the backbone attention hot spot; the pure-jnp
q-chunked scan in models/attention.py is the oracle-equivalent fallback the
XLA:CPU dry run compiles.  Design points (TPU adaptation, DESIGN.md §3):

  * grid = (B*H, num_q_blocks, num_kv_blocks), kv minor so the f32
    accumulator / running-max / running-sum scratch stays in VMEM across the
    kv sweep of one q block;
  * GQA without materializing repeated K/V: the kv BlockSpec index map folds
    the query head to its kv head (b*KV + h//G) — K/V tiles are fetched once
    per kv head group;
  * fully-masked blocks (above the causal diagonal, or outside the sliding
    window) are skipped with pl.when — for long_500k's window=4096 this is
    what makes attention O(S·W) instead of O(S²);
  * block sizes 128 align the MXU's 128x128 systolic tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLK_Q = 128
BLK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, sq: int, sk: int, nk: int, causal: bool, window: int, scale: float):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * sq
    k_start = ik * sk
    # Block-level skip: causal => k block must start at/below q block end;
    # sliding window => k block must end after (q_start - window).
    live = True
    if causal:
        live = k_start <= q_start + sq - 1
    if window:
        live = jnp.logical_and(live, k_start + sk - 1 > q_start - window) if causal else (k_start + sk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale            # (sq, hd)
        k = k_ref[0].astype(jnp.float32)                    # (sk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (sq, sk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
        mask = jnp.ones((sq, sk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                 # (sq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        lsum = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / lsum).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "blk_q", "blk_k", "interpret")
)
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    blk_q: int = BLK_Q, blk_k: int = BLK_K,
                    interpret: bool = False):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    sq = min(blk_q, S)
    sk = min(blk_k, S)
    nq = pl.cdiv(S, sq)
    nk = pl.cdiv(S, sk)
    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * KV, S, hd)
    vf = v.reshape(B * KV, S, hd)

    def kv_row(bh):
        return (bh // H) * KV + (bh % H) // G

    out = pl.pallas_call(
        functools.partial(_kernel, sq=sq, sk=sk, nk=nk, causal=causal,
                          window=window, scale=hd ** -0.5),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, sq, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, sk, hd), lambda bh, iq, ik: (kv_row(bh), ik, 0)),
            pl.BlockSpec((1, sk, hd), lambda bh, iq, ik: (kv_row(bh), ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((sq, 1), jnp.float32),
            pltpu.VMEM((sq, 1), jnp.float32),
            pltpu.VMEM((sq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
