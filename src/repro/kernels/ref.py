"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

The codec oracles (``int8_roundtrip_ref``, ``topk_select_ref``) are also
the *default* encode path on non-TPU backends (ops.py mode "auto"), so
they are bit-exact re-statements of the historical codec semantics, not
approximations."""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Radix-bucket geometry shared by topk_select_ref and the Pallas kernel
# (codec_ops.py): nonnegative f32 magnitudes order exactly like their bit
# patterns, so the top 32 - TOPK_SHIFT = 10 bits (sign always 0, 8
# exponent bits, 1 mantissa bit) are an order-preserving radix with
# 2**10 / 2 = 512 reachable buckets and a tie band narrower than 1.5x.
TOPK_BUCKETS = 512
TOPK_SHIFT = 22


def fim_diag_ref(grads, old_diag, ema: float):
    """grads: (B, D) per-example (or per-microbatch) gradients;
    old_diag: (D,) f32 EMA state.  Returns ema*old + (1-ema)*mean(g²)."""
    meansq = jnp.mean(jnp.square(grads.astype(jnp.float32)), axis=0)
    return ema * old_diag.astype(jnp.float32) + (1.0 - ema) * meansq


def vlbfgs_gram_ref(basis):
    """basis: (n, D) rows [s_0..s_{m-1}, y_0..y_{m-1}, g].
    Returns (n, n) Gram matrix in f32."""
    b = basis.astype(jnp.float32)
    return b @ b.T


def int8_scale(x):
    """The per-tensor symmetric int8 scale, max|x|/127 (floored at
    1e-12/127 for all-zero tensors) — the exact expression of the
    historical ``codecs.quantize_tree``.  Computed once by the dispatch
    wrapper and shared by kernel and oracle: f32 max is order-exact and
    the single division is evaluated in one place, so both paths consume
    a bit-identical scale."""
    a = x.astype(jnp.float32)
    return jnp.maximum(jnp.max(jnp.abs(a)), 1e-12) / 127.0


def int8_roundtrip_ref(x, u, scale=None):
    """Per-tensor symmetric int8 with stochastic rounding, dequantized.

    x: payload tensor; u: uniforms of x's shape (the caller owns the PRNG
    stream so kernel and oracle consume identical draws).  Matches the
    historical ``codecs.quantize_tree``/``dequantize_tree`` pair bit-for-
    bit: the int8 cast is elided because the clipped rounded value is
    already integral in [-127, 127]."""
    a = x.astype(jnp.float32)
    s = int8_scale(x) if scale is None else scale
    q = a / s
    lo = jnp.floor(q)
    rnd = lo + (u.astype(jnp.float32) < (q - lo)).astype(jnp.float32)
    return jnp.clip(rnd, -127.0, 127.0) * s


def topk_select_ref(flat, k):
    """Bucketed threshold select: zero all but the k largest-|x| entries
    of a 1-D payload — same integer logic as codec_ops.topk_select (bit-
    identical keep masks), no global sort.  Threshold-bucket ties break
    by index order, so exactly k coordinates survive."""
    bits = jax.lax.bitcast_convert_type(
        jnp.abs(flat.astype(jnp.float32)), jnp.uint32)
    bucket = (bits >> TOPK_SHIFT).astype(jnp.int32)
    hist = jnp.zeros((TOPK_BUCKETS,), jnp.int32).at[bucket].add(1)
    k = jnp.asarray(k, jnp.int32)
    ge = jnp.cumsum(hist[::-1])[::-1]  # ge[t] = count(bucket >= t)
    t = jnp.max(jnp.where(
        ge >= k, jnp.arange(TOPK_BUCKETS, dtype=jnp.int32), 0))
    need = k - (ge[t] - hist[t])
    tie = (bucket == t).astype(jnp.int32)
    rank = jnp.cumsum(tie) - tie       # exclusive index-order rank
    keep = (bucket > t) | ((tie == 1) & (rank < need))
    return jnp.where(keep, flat, jnp.zeros_like(flat))


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd); GQA by head folding.
    f32 softmax; returns (B, H, S, hd) in q.dtype."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qf = q.reshape(B, KV, G, S, hd).astype(jnp.float32) * hd ** -0.5
    scores = jnp.einsum("bkgqh,bksh->bkgqs", qf, k.astype(jnp.float32))
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window:
        mask &= pos[None, :] > pos[:, None] - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, S, hd).astype(q.dtype)
