"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fim_diag_ref(grads, old_diag, ema: float):
    """grads: (B, D) per-example (or per-microbatch) gradients;
    old_diag: (D,) f32 EMA state.  Returns ema*old + (1-ema)*mean(g²)."""
    meansq = jnp.mean(jnp.square(grads.astype(jnp.float32)), axis=0)
    return ema * old_diag.astype(jnp.float32) + (1.0 - ema) * meansq


def vlbfgs_gram_ref(basis):
    """basis: (n, D) rows [s_0..s_{m-1}, y_0..y_{m-1}, g].
    Returns (n, n) Gram matrix in f32."""
    b = basis.astype(jnp.float32)
    return b @ b.T


def flash_attention_ref(q, k, v, causal: bool = True, window: int = 0):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd); GQA by head folding.
    f32 softmax; returns (B, H, S, hd) in q.dtype."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qf = q.reshape(B, KV, G, S, hd).astype(jnp.float32) * hd ** -0.5
    scores = jnp.einsum("bkgqh,bksh->bkgqs", qf, k.astype(jnp.float32))
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window:
        mask &= pos[None, :] > pos[:, None] - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, S, hd).astype(q.dtype)
