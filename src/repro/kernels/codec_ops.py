"""Pallas TPU kernels for the wire codecs (fed/codecs.py hot path).

Two encode primitives sit on every upload's critical path:

  * ``int8_roundtrip`` — fused per-tensor symmetric int8 with stochastic
    rounding: scale, floor, uniform-compare, clip and dequantize run in
    one ``pallas_call`` over the flattened payload, so the tensor is
    read+written once instead of the unfused oracle's per-op passes.
    The rounding uniforms are drawn *outside* the kernel with the same
    ``jax.random.uniform`` stream as the oracle, and the per-tensor
    scale is precomputed by the caller (an exact f32 max reduction plus
    one division) and passed in — constant-divisor divisions compiled
    *inside* a kernel may round 1 ulp away from the eager oracle, while
    every op the kernel performs on the shared scale (dynamic divide,
    floor, compare, clip, multiply) is exact or correctly rounded, so
    kernel and oracle are bit-identical — the codec tests assert exact
    equality.

  * ``topk_select`` — threshold-select top-k without a global sort.  The
    magnitude order of nonnegative f32 values equals the integer order of
    their bit patterns, so bucketing on the top ``32 - TOPK_SHIFT`` bits
    of ``bitcast(|x|)`` is an order-preserving radix: pass 1 histograms
    the payload into ``TOPK_BUCKETS`` buckets, a 512-entry reversed
    cumsum picks the threshold bucket ``t`` (the coarsest bucket whose
    suffix count still reaches ``k``), pass 2 keeps every element above
    ``t`` plus the first ``k - count(>t)`` tie-bucket elements in index
    order (a running SMEM counter across the sequential grid).  Exactly
    ``k`` coordinates survive — the ``wire_bytes`` billing invariant —
    and both passes are O(n) streaming, versus the O(n log n) global
    ``jax.lax.top_k`` it replaces.

Dispatch (TPU-native / interpret / jnp-oracle) lives in ops.py; the
pure-jnp oracles with identical integer select logic live in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import TOPK_BUCKETS, TOPK_SHIFT

BLK = 1024


def _bucket_of(x):
    """Order-preserving radix bucket of |x| (f32 -> int32 in [0, 512))."""
    bits = jax.lax.bitcast_convert_type(
        jnp.abs(x.astype(jnp.float32)), jnp.uint32)
    return (bits >> TOPK_SHIFT).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fused int8 stochastic-rounding round-trip
# ---------------------------------------------------------------------------
def _int8_kernel(x_ref, u_ref, s_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = s_ref[0]
    q = x / scale
    lo = jnp.floor(q)
    rnd = lo + (u_ref[...] < (q - lo)).astype(jnp.float32)
    out_ref[...] = jnp.clip(rnd, -127.0, 127.0) * scale


@functools.partial(jax.jit, static_argnames=("interpret",))
def int8_roundtrip(x, u, scale, interpret: bool = False):
    """x: any-shape payload tensor; u: uniforms of the same shape;
    scale: () or (1,) per-tensor scale (see ref.int8_scale — computed by
    the caller so kernel and oracle consume one bit-identical value).
    Returns dequantize(quantize(x)) in f32, shaped like x."""
    shape = x.shape
    size = x.size
    flat = x.reshape(-1)
    uf = u.reshape(-1).astype(jnp.float32)
    blk = min(BLK, size)
    nb = pl.cdiv(size, blk)
    if size % blk:
        flat = jnp.pad(flat, (0, nb * blk - size))
        uf = jnp.pad(uf, (0, nb * blk - size))
    out = pl.pallas_call(
        _int8_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, blk), lambda b: (b, 0)),
            pl.BlockSpec((1, blk), lambda b: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, blk), jnp.float32),
        interpret=interpret,
    )(flat.reshape(nb, blk), uf.reshape(nb, blk),
      jnp.asarray(scale, jnp.float32).reshape(1))
    return out.reshape(-1)[:size].reshape(shape)


# ---------------------------------------------------------------------------
# Bucketed top-k threshold select
# ---------------------------------------------------------------------------
def _hist_kernel(x_ref, out_ref):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bucket = _bucket_of(x_ref[...])  # (1, blk)
    ids = jax.lax.broadcasted_iota(
        jnp.int32, (TOPK_BUCKETS, bucket.shape[-1]), 0)
    out_ref[...] += jnp.sum((bucket == ids).astype(jnp.int32), axis=1)


def _select_kernel(x_ref, t_ref, need_ref, out_ref, seen_ref):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        seen_ref[0] = 0

    x = x_ref[...]
    bucket = _bucket_of(x)
    tie = (bucket == t_ref[0]).astype(jnp.int32)
    # exclusive global index-order rank among tie-bucket elements
    rank = seen_ref[0] + jnp.cumsum(tie, axis=-1) - tie
    keep = (bucket > t_ref[0]) | ((tie == 1) & (rank < need_ref[0]))
    out_ref[...] = jnp.where(keep, x, jnp.zeros_like(x))
    seen_ref[0] += jnp.sum(tie)


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_select(flat, k, interpret: bool = False):
    """Zero all but the ``k`` largest-|x| entries of a 1-D payload.

    Ties on the threshold bucket break by index order (lowest index
    wins), so exactly ``k`` coordinates survive for any 1 <= k <= n."""
    size = flat.size
    blk = min(BLK, size)
    nb = pl.cdiv(size, blk)
    x = flat
    if size % blk:
        # padded zeros land in bucket 0 *after* every real element in
        # index order, and need <= count(real bucket-0) whenever k <= n,
        # so padding can neither shift the threshold nor get selected
        x = jnp.pad(x, (0, nb * blk - size))
    x2 = x.reshape(nb, blk)

    hist = pl.pallas_call(
        _hist_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, blk), lambda b: (b, 0))],
        out_specs=pl.BlockSpec((TOPK_BUCKETS,), lambda b: (0,)),
        out_shape=jax.ShapeDtypeStruct((TOPK_BUCKETS,), jnp.int32),
        interpret=interpret,
    )(x2)

    # threshold bucket: coarsest t whose suffix count still reaches k
    k = jnp.asarray(k, jnp.int32)
    ge = jnp.cumsum(hist[::-1])[::-1]  # ge[t] = count(bucket >= t)
    t = jnp.max(jnp.where(
        ge >= k, jnp.arange(TOPK_BUCKETS, dtype=jnp.int32), 0))
    need = k - (ge[t] - hist[t])       # tie-bucket quota

    out = pl.pallas_call(
        _select_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, blk), lambda b: (b, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, blk), flat.dtype),
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(x2, t.reshape(1), need.reshape(1))
    return out.reshape(-1)[:size]
