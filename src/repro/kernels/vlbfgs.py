"""Pallas TPU kernel: VL-BFGS Gram matrix (paper Alg. 1 line 6 via [44]).

Computes the (2m+1)x(2m+1) dot-product matrix of the L-BFGS basis
[s_0..s_{m-1}, y_0..y_{m-1}, g] in ONE blocked pass over the d-dimensional
vectors: grid over D blocks, each step loads an (n, D_BLK) tile once and
rank-updates the accumulator with tile @ tile.T on the MXU.  A naive
two-loop needs 4m separate O(d) passes (each dot re-reads its vectors from
HBM); this kernel reads each basis element exactly once — an (4m : 1) HBM
traffic reduction for the optimizer's hot step, which is why it exists.

The n dimension (21 for m=10) is zero-padded to the 8-sublane boundary by
Pallas automatically; the matmul runs n x D_BLK @ D_BLK x n.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

D_BLK = 4096


def _kernel(basis_ref, out_ref):
    d = pl.program_id(0)

    @pl.when(d == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile = basis_ref[...].astype(jnp.float32)      # (n, D_BLK)
    out_ref[...] += jax.lax.dot_general(
        tile, tile, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def gram(basis, interpret: bool = False):
    """basis: (n, D) -> (n, n) f32 Gram matrix."""
    n, D = basis.shape
    db = min(D_BLK, D)
    nd = pl.cdiv(D, db)
    padded = D
    if D % db:
        padded = nd * db
        basis = jnp.pad(basis, ((0, 0), (0, padded - D)))
    return pl.pallas_call(
        _kernel,
        grid=(nd,),
        in_specs=[pl.BlockSpec((n, db), lambda d: (0, d))],
        out_specs=pl.BlockSpec((n, n), lambda d: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(basis)
