"""Pallas TPU kernel: fused diagonal-Fisher accumulation (paper Eq. 9 + Γ).

Computes  new = ema*old + (1-ema) * mean_b(g[b, :]**2)  in one pass over the
(B, D) per-example-gradient matrix, fusing square, batch-mean and EMA so the
gradient tile is read from HBM exactly once (the op is purely memory-bound:
2 flops/byte).  Tiled (B_BLK, D_BLK) over VMEM with the batch dimension as
the *minor* grid axis so the f32 accumulator tile stays resident while the
batch is reduced (TPU grids iterate minor-to-major sequentially).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

D_BLK = 2048
B_BLK = 256


def _kernel(g_ref, old_ref, ema_ref, out_ref, *, nb: int, batch: int):
    b = pl.program_id(1)  # minor axis: batch tiles reduce into out_ref

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.sum(g * g, axis=0)

    @pl.when(b == nb - 1)
    def _finish():
        ema = ema_ref[0]
        meansq = out_ref[...] / batch
        out_ref[...] = ema * old_ref[...] + (1.0 - ema) * meansq


@functools.partial(jax.jit, static_argnames=("interpret",))
def fim_diag(grads, old_diag, ema, interpret: bool = False):
    """grads: (B, D); old_diag: (D,) f32; ema: () f32 -> (D,) f32."""
    B, D = grads.shape
    db = min(D_BLK, D)
    bb = min(B_BLK, B)
    nd = pl.cdiv(D, db)
    nb = pl.cdiv(B, bb)
    # zero-pad tail tiles explicitly (as vlbfgs.gram does): padded rows
    # add 0 to the g² sum (the mean still divides by the true B) and the
    # padded diag tail is sliced off below
    if B % bb or D % db:
        grads = jnp.pad(grads, ((0, nb * bb - B), (0, nd * db - D)))
    old_diag = old_diag.astype(jnp.float32)
    if D % db:
        old_diag = jnp.pad(old_diag, (0, nd * db - D))
    ema = jnp.asarray(ema, jnp.float32).reshape(1)
    out = pl.pallas_call(
        functools.partial(_kernel, nb=nb, batch=B),
        grid=(nd, nb),
        in_specs=[
            pl.BlockSpec((bb, db), lambda d, b: (b, d)),
            pl.BlockSpec((db,), lambda d, b: (d,)),
            pl.BlockSpec((1,), lambda d, b: (0,)),
        ],
        out_specs=pl.BlockSpec((db,), lambda d, b: (d,)),
        out_shape=jax.ShapeDtypeStruct((nd * db,), jnp.float32),
        interpret=interpret,
    )(grads, old_diag, ema)
    return out[:D]
