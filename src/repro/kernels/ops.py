"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the Pallas kernels run natively; elsewhere (this CPU container, and
any platform without Mosaic) they execute in interpret mode when explicitly
requested, otherwise fall back to the pure-jnp oracle in ref.py — identical
semantics either way (tests sweep shapes/dtypes asserting allclose; the
codec ops assert bit-exact equality).

Every wrapper takes a ``mode`` knob (``FedConfig.kernels`` surfaces it to
federated runs):

  * ``"auto"`` — native Pallas on TPU, jnp oracle elsewhere (default:
    zero behavior change on CPU, fast path where Mosaic exists);
  * ``"on"``   — native on TPU, *interpret-mode kernel* elsewhere (the
    CI/testing setting: exercises the kernel code path everywhere);
  * ``"off"``  — always the jnp oracle, even on TPU.

``force_kernel=True`` (the pre-knob API) is kept as an alias for "on".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import codec_ops as _codec
from repro.kernels import fim_diag as _fim
from repro.kernels import flash_attention as _fa
from repro.kernels import ref
from repro.kernels import vlbfgs as _vl

MODES = ("auto", "on", "off")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve(mode: str, force_kernel: bool = False) -> str:
    """-> "native" | "interpret" | "oracle" for the current backend."""
    if mode not in MODES:
        raise ValueError(f"kernels mode must be one of {MODES}, got {mode!r}")
    if force_kernel:
        mode = "on"
    if mode == "off":
        return "oracle"
    if _on_tpu():
        return "native"
    return "interpret" if mode == "on" else "oracle"


def fim_diag_update(grads, old_diag, ema, force_kernel: bool = False,
                    mode: str = "auto"):
    """Fused Γ update: ema*old + (1-ema)*mean_b g².  grads: (B, D)."""
    path = resolve(mode, force_kernel)
    if path == "oracle":
        return ref.fim_diag_ref(grads, old_diag, ema)
    return _fim.fim_diag(grads, old_diag, ema,
                         interpret=(path == "interpret"))


def vlbfgs_gram(basis, force_kernel: bool = False, mode: str = "auto"):
    """(2m+1, D) basis -> (2m+1, 2m+1) Gram matrix."""
    path = resolve(mode, force_kernel)
    if path == "oracle":
        return ref.vlbfgs_gram_ref(basis)
    return _vl.gram(basis, interpret=(path == "interpret"))


def int8_roundtrip(x, key, force_kernel: bool = False, mode: str = "auto"):
    """Fused int8 stochastic-rounding quantize+dequantize of one payload
    tensor.  Draws the rounding uniforms from ``key`` with the same
    ``jax.random.uniform(key, x.shape)`` stream on every path, so kernel
    and oracle round identically (bit-for-bit)."""
    size = x.size
    if size == 0:
        return x.astype(jnp.float32)
    u = jax.random.uniform(key, x.shape)
    scale = ref.int8_scale(x)  # shared: both paths quantize identically
    path = resolve(mode, force_kernel)
    if path == "oracle":
        return ref.int8_roundtrip_ref(x, u, scale)
    return _codec.int8_roundtrip(x, u, scale,
                                 interpret=(path == "interpret"))


def topk_select(flat, k, force_kernel: bool = False, mode: str = "auto"):
    """Zero all but the ``k`` largest-|x| entries of a 1-D payload via
    bucketed threshold select (no global sort; exactly ``k`` survive —
    the codec ``wire_bytes`` billing invariant)."""
    path = resolve(mode, force_kernel)
    if path == "oracle":
        return ref.topk_select_ref(flat, k)
    return _codec.topk_select(flat, k, interpret=(path == "interpret"))


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    force_kernel: bool = False, mode: str = "auto"):
    """(B,H,S,hd) x (B,KV,S,hd) -> (B,H,S,hd)."""
    path = resolve(mode, force_kernel)
    if path == "oracle":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               interpret=(path == "interpret"))
