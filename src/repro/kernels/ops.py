"""Jit'd dispatch wrappers for the Pallas kernels.

On TPU the Pallas kernels run natively; elsewhere (this CPU container, and
any platform without Mosaic) they execute in interpret mode when explicitly
requested, otherwise fall back to the pure-jnp oracle in ref.py — identical
semantics either way (tests sweep shapes/dtypes asserting allclose)."""
from __future__ import annotations

import jax

from repro.kernels import fim_diag as _fim
from repro.kernels import flash_attention as _fa
from repro.kernels import ref
from repro.kernels import vlbfgs as _vl


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fim_diag_update(grads, old_diag, ema, force_kernel: bool = False):
    """Fused Γ update: ema*old + (1-ema)*mean_b g².  grads: (B, D)."""
    if _on_tpu():
        return _fim.fim_diag(grads, old_diag, ema)
    if force_kernel:
        return _fim.fim_diag(grads, old_diag, ema, interpret=True)
    return ref.fim_diag_ref(grads, old_diag, ema)


def vlbfgs_gram(basis, force_kernel: bool = False):
    """(2m+1, D) basis -> (2m+1, 2m+1) Gram matrix."""
    if _on_tpu():
        return _vl.gram(basis)
    if force_kernel:
        return _vl.gram(basis, interpret=True)
    return ref.vlbfgs_gram_ref(basis)


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    force_kernel: bool = False):
    """(B,H,S,hd) x (B,KV,S,hd) -> (B,H,S,hd)."""
    if _on_tpu():
        return _fa.flash_attention(q, k, v, causal=causal, window=window)
    if force_kernel:
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   interpret=True)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
