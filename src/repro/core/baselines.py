"""The paper's comparison optimizers (Table II): FedAvg-SGD, FedAvg-Adam,
FedDANE — implemented from scratch (no optax in this environment).

All three share the federated contract of core/fim_lbfgs.py: the server is
handed the client-aggregated gradient (FedAvg semantics — averaging one
local step's update equals applying the averaged gradient) and returns new
parameters.  FedDANE additionally prescribes the *client-side* corrected
inner objective; ``feddane_inner_grad`` is applied by fed/client.py during
local epochs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_axpy


# ---------------------------------------------------------------------------
# FedAvg-SGD
# ---------------------------------------------------------------------------
class SgdState(NamedTuple):
    momentum: object
    step: jax.Array


def sgd_init(params, momentum: float = 0.0) -> SgdState:
    return SgdState(
        momentum=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        step=jnp.zeros((), jnp.int32),
    )


def sgd_update(state: SgdState, params, grad, lr: float, momentum: float = 0.0):
    vel = jax.tree.map(
        lambda v, g: momentum * v + g.astype(jnp.float32), state.momentum, grad
    )
    new_params = tree_axpy(-lr, vel, params)
    return new_params, SgdState(vel, state.step + 1), {}


# ---------------------------------------------------------------------------
# FedAvg-Adam
# ---------------------------------------------------------------------------
class AdamState(NamedTuple):
    mu: object
    nu: object
    step: jax.Array


def adam_init(params) -> AdamState:
    def z():
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamState(mu=z(), nu=z(), step=jnp.zeros((), jnp.int32))


def adam_update(state: AdamState, params, grad, lr: float,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    t = state.step + 1
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grad)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state.nu, grad)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    upd = jax.tree.map(
        lambda m, v: (m / bc1) / (jnp.sqrt(v / bc2) + eps), mu, nu
    )
    new_params = tree_axpy(-lr, upd, params)
    return new_params, AdamState(mu, nu, t), {}


# ---------------------------------------------------------------------------
# FedDANE (Li et al., Asilomar 2019)
# ---------------------------------------------------------------------------
class DaneState(NamedTuple):
    step: jax.Array


def dane_init(params) -> DaneState:
    return DaneState(step=jnp.zeros((), jnp.int32))


def feddane_inner_grad(local_grad, local_grad_at_start, global_grad, params,
                       start_params, mu: float):
    """Gradient of the DANE local subproblem
        F_k(w) - (∇F_k(w_t) - ∇f(w_t))·w + (μ/2)‖w - w_t‖²
    i.e.  ∇F_k(w) - ∇F_k(w_t) + ∇f(w_t) + μ (w - w_t)."""
    return jax.tree.map(
        lambda g, g0, gg, w, w0: g - g0 + gg + mu * (w - w0).astype(g.dtype),
        local_grad, local_grad_at_start, global_grad, params, start_params,
    )


def dane_update(state: DaneState, params, avg_client_params):
    """Server step: average of clients' inner solutions."""
    return avg_client_params, DaneState(state.step + 1), {}


# ---------------------------------------------------------------------------
# Uniform optimizer façade used by fed/server.py and launch/train.py
# ---------------------------------------------------------------------------
def make(name: str, params, fed_cfg):
    """Returns (state, update_fn(state, params, grad, fim_diag) -> (params,
    state, stats)).  FIM diag is ignored by the first-order baselines."""
    from repro.core import fim_lbfgs

    if name == "fim_lbfgs":
        ocfg = fim_lbfgs.FimLbfgsConfig(
            learning_rate=fed_cfg.second_order_lr, m=fed_cfg.lbfgs_m,
            damping=fed_cfg.fim_damping, fim_ema=fed_cfg.fim_ema,
            max_step_norm=fed_cfg.max_step_norm,
        )
        state = fim_lbfgs.init(params, ocfg)

        def upd(state, params, grad, fim_diag):
            return fim_lbfgs.update(state, params, grad, fim_diag, ocfg)

        return state, upd
    if name == "fedavg_sgd":
        state = sgd_init(params)
        return state, lambda s, p, g, f: sgd_update(s, p, g, fed_cfg.learning_rate)
    if name == "fedavg_adam":
        state = adam_init(params)
        return state, lambda s, p, g, f: adam_update(s, p, g, fed_cfg.learning_rate)
    raise ValueError(f"unknown optimizer {name!r}")
