"""FedOVA (paper Sec. IV-B, Algorithm 2).

Decomposes an n-class federated classification task into n independent
binary one-vs-all component classifiers:

  * components are stored *stacked* (leading n_classes dim) so client-side
    training vmaps across a client's locally-present classes and server-side
    aggregation is one grouped reduction (Eq. 11);
  * each client trains only the components whose class appears in its local
    data (Step 2, "initializes some of the OVA component classifiers
    according to its own local data label distribution");
  * inference is arg-max over component confidences (Eq. 4).

The scheme is optimizer-agnostic: components can be trained with local SGD
(Alg. 2 as written) or with the FIM-L-BFGS server step (the paper's "can be
well integrated with our communication efficient algorithm").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import aggregation


class OvaModel(NamedTuple):
    components: object   # pytree, leaves (n_classes, ...) — binary classifiers
    n_classes: int


def init(component_init, n_classes: int, key) -> OvaModel:
    """component_init(key) -> params for ONE binary classifier."""
    keys = jax.random.split(key, n_classes)
    stacked = jax.vmap(component_init)(keys)
    return OvaModel(components=stacked, n_classes=n_classes)


def binary_labels(y, cls):
    """Ground-truth membership for component ``cls``: 1 if y == cls."""
    return (y == cls).astype(jnp.int32)


def client_class_mask(y, n_classes: int):
    """(n_classes,) float mask of classes present in a client's local data —
    drives which components the client trains (Alg. 2 Step 2)."""
    onehot = jax.nn.one_hot(y, n_classes, dtype=jnp.float32)
    return (jnp.sum(onehot, axis=0) > 0).astype(jnp.float32)


def predict(apply_fn, model: OvaModel, x):
    """Eq. (4): ŷ = argmax_i f_i(x).  apply_fn(params, x) -> (B, 1) logit."""
    logits = jax.vmap(lambda p: apply_fn(p, x))(model.components)  # (n, B, 1)
    conf = jax.nn.sigmoid(logits[..., 0])                          # (n, B)
    return jnp.argmax(conf, axis=0)


def accuracy(apply_fn, model: OvaModel, x, y):
    return jnp.mean(predict(apply_fn, model, x) == y)


def add_class(model: OvaModel, component_init, key) -> OvaModel:
    """Smooth adaptation to environment changes (paper Sec. IV-B Remark):
    "when new classes emerge, FedOVA just needs to create a new classifier".
    Appends a freshly-initialized component; existing experts untouched."""
    new = component_init(key)
    stacked = jax.tree.map(
        lambda buf, n: jnp.concatenate([buf, n[None]], axis=0),
        model.components, new,
    )
    return OvaModel(components=stacked, n_classes=model.n_classes + 1)


def aggregate(model: OvaModel, client_components, client_masks) -> OvaModel:
    """Eq. (11): per-component mean over contributing clients.

    client_components: pytree with leaves (K, n_classes, ...);
    client_masks: (K, n_classes) — which components each client trained."""
    def per_class(cls_params_prev, cls_idx):
        stacked = jax.tree.map(lambda leaf: leaf[:, cls_idx], client_components)
        return aggregation.grouped_mean(
            cls_params_prev, stacked, client_masks[:, cls_idx]
        )

    n = model.n_classes
    new = [
        per_class(jax.tree.map(lambda leaf: leaf[i], model.components), i)
        for i in range(n)
    ]
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *new)
    return OvaModel(components=stacked, n_classes=n)
