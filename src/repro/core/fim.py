"""Diagonal empirical Fisher Information Matrix (paper Sec. IV-A, Eq. 9).

The paper approximates the Hessian with the Fisher information
E[∇f ∇fᵀ], then keeps only the diagonal (Γ, the diagonalization step after
Eq. 9) so each client stores/communicates O(d) instead of O(d²).

Two estimation modes (cfg.fim_mode):
  * "per_example" — exact Eq. 9 diagonal: vmap per-example gradients, mean of
    squares.  Faithful to the paper; used for the paper-scale CNN models.
  * "microbatch"  — mean of squared *microbatch* gradients, produced for free
    by gradient accumulation.  Used for LLM-scale configs where per-example
    Jacobians are infeasible (documented deviation, DESIGN.md §3).

Both feed the same smoothing y_t = (Γ̄ + λI) s_t (Alg. 1 line 8), where Γ̄ is
the client-aggregated FIM.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops


class FimState(NamedTuple):
    diag: object      # pytree like params — EMA of the diagonal Fisher
    steps: jax.Array  # () int32


def init(params, dtype=jnp.float32) -> FimState:
    return FimState(
        diag=jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params),
        steps=jnp.zeros((), jnp.int32),
    )


def _leaf_diag(g2, kernels: str):
    """(B, D) per-example gradients -> (D,) mean of squares, via the
    fused Pallas op (repro.kernels.ops).  With old=0 and ema=0 the fused
    Γ update reduces to exactly mean_b g² — bit-identical to the inline
    jnp expression on the oracle path."""
    zeros = jnp.zeros((g2.shape[1],), jnp.float32)
    return kernel_ops.fim_diag_update(g2, zeros, 0.0, mode=kernels)


def per_example_diag(per_example_loss: Callable, params, xs, ys,
                     kernels: str = "off"):
    """Exact diagonal empirical Fisher: mean over the batch of squared
    per-example gradients.  ``per_example_loss(params, x, y) -> scalar``.

    ``kernels`` routes the square+mean through the fused Pallas op
    (repro.kernels.ops.fim_diag_update); "off"/non-TPU "auto" resolve to
    the bit-identical jnp oracle."""
    grads = jax.vmap(lambda x, y: jax.grad(per_example_loss)(params, x, y))(xs, ys)
    return jax.tree.map(
        lambda g: _leaf_diag(g.reshape(g.shape[0], -1),
                             kernels).reshape(g.shape[1:]), grads)


def microbatch_diag(grad, kernels: str = "off"):
    """Squared (micro)batch gradient — one term of the accumulation mean
    (a B=1 instance of the same fused Γ op)."""
    return jax.tree.map(
        lambda g: _leaf_diag(g.reshape(1, -1), kernels).reshape(g.shape),
        grad)


def update(state: FimState, new_diag, ema: float) -> FimState:
    """EMA accumulation of the Fisher diagonal with bias-corrected warmup."""
    def upd(old, new):
        mixed = ema * old + (1.0 - ema) * new.astype(old.dtype)
        return jnp.where(state.steps == 0, new.astype(old.dtype), mixed)

    return FimState(
        diag=jax.tree.map(upd, state.diag, new_diag),
        steps=state.steps + 1,
    )


def mean_diag(state: FimState) -> jax.Array:
    """Mean of the Fisher diagonal across all parameters (f32 scalar)."""
    sums = [jnp.sum(d) for d in jax.tree.leaves(state.diag)]
    cnt = sum(d.size for d in jax.tree.leaves(state.diag))
    return jnp.sum(jnp.stack(sums)) / jnp.float32(max(cnt, 1))


def smooth_y(state: FimState, s, damping: float, rel_damping: float = 0.1):
    """Paper Alg. 1 line 8: y_t = B̄_t s_t with B̄ = Γ̄ + λ_t I.

    λ_t = damping + rel_damping·mean(Γ̄) keeps B̄ ⪰ λ_t I (Assumption 1's
    lower bound — Lemma 1's θ₁ > 0) while also bounding the *relative*
    amplification of the implied preconditioner:  1/(Γ_ii + λ_t) ≤
    1/(rel_damping·mean Γ̄), i.e. Lemma 1's θ₂ made operational.  Without the
    relative term, near-zero Fisher entries (dead ReLUs at init) dominate
    the direction and the method stalls inside its trust region."""
    lam = damping + rel_damping * mean_diag(state)
    return jax.tree.map(
        lambda d, si: ((d + lam) * si.astype(jnp.float32)).astype(si.dtype),
        state.diag, s,
    )
