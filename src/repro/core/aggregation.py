"""Federated aggregation rules.

``weighted_mean`` is FedAvg's Eq. (1) (n_k/n weighting).  ``grouped_mean``
is FedOVA's Eq. (11): component classifiers are aggregated only over the
clients that actually trained them; groups with no contributors keep the
previous server model.  Both operate on *stacked* client pytrees (leading
client dim) so they jit and map directly onto mesh all-reduces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_mean(stacked_params, weights):
    """stacked_params: pytree with leading K dim; weights: (K,) ≥ 0."""
    w = weights.astype(jnp.float32)
    total = jnp.maximum(jnp.sum(w), 1e-12)

    def leaf(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return (jnp.sum(x.astype(jnp.float32) * wb, axis=0) / total).astype(x.dtype)

    return jax.tree.map(leaf, stacked_params)


def grouped_mean(prev_params, stacked_params, contributed):
    """FedOVA Eq. (11).

    prev_params: server pytree; stacked_params: (K, ...) client results;
    contributed: (K,) float mask (1 where the client trained this group).
    Returns the mean over contributors, or prev where no one contributed."""
    c = contributed.astype(jnp.float32)
    total = jnp.sum(c)

    def leaf(prev, x):
        cb = c.reshape((-1,) + (1,) * (x.ndim - 1))
        mean = jnp.sum(x.astype(jnp.float32) * cb, axis=0) / jnp.maximum(total, 1.0)
        return jnp.where(total > 0, mean.astype(prev.dtype), prev)

    return jax.tree.map(leaf, prev_params, stacked_params)


def delta_mean(global_params, stacked_client_params, weights):
    """FedAvg in delta form: w + mean_k n_k/n (w_k - w) — identical to
    weighted_mean when Σ n_k/n = 1 but numerically kinder in bf16."""
    mean = weighted_mean(stacked_client_params, weights)
    return jax.tree.map(
        lambda g, m: (g.astype(jnp.float32)
                      + (m.astype(jnp.float32) - g.astype(jnp.float32))).astype(g.dtype),
        global_params, mean,
    )
