"""Vector-free L-BFGS (paper Sec. IV-A; two-loop recursion of [44]).

The classical two-loop recursion interleaves O(d) dot products with O(d)
axpys m times.  The *vector-free* formulation (Chen et al., NeurIPS 2014 —
the algorithm the paper's Alg. 1 line 6 invokes) instead expresses the
direction in the basis  b = [s_0..s_{m-1}, y_0..y_{m-1}, g]  and runs the two
loops on the (2m+1)x(2m+1) Gram matrix of that basis.  In the federated
setting this is the whole point: with parameters (and hence s_i, y_i, g)
sharded across devices, the Gram matrix costs one fused pass over the shards
plus a (2m+1)² scalar all-reduce — the O(m²) communication term of
Theorem 3 — and the direction is a local linear combination (O(d), no
communication).

History is a functional circular buffer: pytrees with a leading ``m`` dim,
a write index and a live count, so the whole optimizer jits and shards.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops


class History(NamedTuple):
    s: object            # pytree, leaves (m, ...) — parameter deltas
    y: object            # pytree, leaves (m, ...) — FIM-smoothed grad deltas
    idx: jax.Array       # () int32 — next write slot
    count: jax.Array     # () int32 — number of live pairs (<= m)


def init(params, m: int, dtype=None) -> History:
    def alloc(p):
        return jnp.zeros((m,) + p.shape, dtype or p.dtype)

    return History(
        s=jax.tree.map(alloc, params),
        y=jax.tree.map(alloc, params),
        idx=jnp.zeros((), jnp.int32),
        count=jnp.zeros((), jnp.int32),
    )


def push(h: History, s, y) -> History:
    new_s = jax.tree.map(lambda b, v: b.at[h.idx].set(v.astype(b.dtype)), h.s, s)
    new_y = jax.tree.map(lambda b, v: b.at[h.idx].set(v.astype(b.dtype)), h.y, y)
    m = jax.tree.leaves(h.s)[0].shape[0]
    return History(
        s=new_s, y=new_y,
        idx=(h.idx + 1) % m,
        count=jnp.minimum(h.count + 1, m),
    )


# ---------------------------------------------------------------------------
# Gram matrix
# ---------------------------------------------------------------------------
def gram_matrix(h: History, g):
    """M[i,j] = <b_i, b_j> for b = [s_0.., y_0.., g]; f32 accumulation.

    Pure-jnp path; repro/kernels/vlbfgs.py is the blocked Pallas TPU kernel
    with identical semantics (tests assert allclose against this)."""
    m = jax.tree.leaves(h.s)[0].shape[0]
    n = 2 * m + 1

    def dots(a, b):
        # Contract over every trailing (parameter) dim in one dot_general,
        # f32-accumulated.  No reshape(m, -1): merging sharded dims would
        # force GSPMD to all-gather the whole history (hundreds of GB at
        # LLM scale); contracting the dims in place keeps each shard local
        # and reduces with a scalar-sized all-reduce.
        dims = tuple(range(1, a.ndim))
        return jax.lax.dot_general(
            a, b, ((dims, dims), ((), ())), preferred_element_type=jnp.float32)

    def leaf_gram(sb, yb, gl):
        s2 = sb
        y2 = yb
        g2 = gl[None]
        ss, sy, sg = dots(s2, s2), dots(s2, y2), dots(s2, g2)
        yy, yg = dots(y2, y2), dots(y2, g2)
        gg = dots(g2, g2)
        top = jnp.concatenate([ss, sy, sg], axis=1)
        mid = jnp.concatenate([sy.T, yy, yg], axis=1)
        bot = jnp.concatenate([sg.T, yg.T, gg], axis=1)
        return jnp.concatenate([top, mid, bot], axis=0)

    grams = jax.tree.map(leaf_gram, h.s, h.y, g)
    return sum(jax.tree.leaves(grams), jnp.zeros((n, n), jnp.float32))


# ---------------------------------------------------------------------------
# Two-loop recursion in Gram space
# ---------------------------------------------------------------------------
def direction_coeffs(M, idx, count, m: int):
    """Coefficients δ with  H·g = Σ_j δ_j b_j  (so the step is p = -Σ δ b).

    Slots are visited newest-to-oldest in the first loop and oldest-to-newest
    in the second, honouring the circular buffer.  Empty slots contribute
    nothing (ρ=0), so with count==0 this degrades to δ = e_g (steepest
    descent), matching L-BFGS-with-empty-memory."""
    n = 2 * m + 1
    delta = jnp.zeros((n,), jnp.float32).at[2 * m].set(1.0)

    def slot(age):  # age 0 = newest
        return (idx - 1 - age) % m

    def rho_of(i):
        return jnp.where(
            jnp.abs(M[i, m + i]) > 1e-20, 1.0 / M[i, m + i], 0.0
        )

    def loop1(age, carry):
        delta, alphas = carry
        i = slot(age)
        live = age < count
        rho = rho_of(i) * live
        alpha = rho * jnp.dot(M[i], delta)          # <s_i, q>
        delta = delta.at[m + i].add(-alpha)
        alphas = alphas.at[age].set(alpha)
        return delta, alphas

    delta, alphas = jax.lax.fori_loop(
        0, m, loop1, (delta, jnp.zeros((m,), jnp.float32))
    )

    newest = slot(0)
    sy = M[newest, m + newest]
    yy = M[m + newest, m + newest]
    gamma = jnp.where((count > 0) & (yy > 1e-20), sy / yy, 1.0)
    delta = delta * gamma

    def loop2(k, delta):
        age = count - 1 - k  # oldest first among live entries
        i = slot(age)
        live = (age >= 0) & (age < count)
        rho = rho_of(i) * live
        beta = rho * jnp.dot(M[m + i], delta)       # <y_i, r>
        alpha = jnp.where(live, alphas[age], 0.0)
        return delta.at[i].add(alpha - beta)

    delta = jax.lax.fori_loop(0, m, loop2, delta)
    return delta


def combine(h: History, g, delta):
    """p = -(Σ_i δ_i s_i + Σ_i δ_{m+i} y_i + δ_{2m} g): local O(d), no comm."""
    m = jax.tree.leaves(h.s)[0].shape[0]
    ds, dy, dg = delta[:m], delta[m:2 * m], delta[2 * m]

    def leaf(sb, yb, gl):
        # f32 accumulation without casting the (m, ...) history to f32
        acc = jnp.einsum("m,m...->...", ds, sb,
                         preferred_element_type=jnp.float32)
        acc = acc + jnp.einsum("m,m...->...", dy, yb,
                               preferred_element_type=jnp.float32)
        acc = acc + dg * gl.astype(jnp.float32)
        return (-acc).astype(gl.dtype)

    return jax.tree.map(leaf, h.s, h.y, g)


def _gram_via_kernel(h: History, g, kernels: str):
    """Gram matrix through the blocked Pallas kernel: materialize the
    (2m+1, D) basis [s_0.., y_0.., g] by raveling every history leaf.

    This is the single-host/paper-scale fast path — the reshape+concat
    that ``gram_matrix`` deliberately avoids is exactly what lets one
    pallas_call read each basis element once.  At LLM scale (sharded
    history) keep ``kernels="off"``: merging sharded dims would force an
    all-gather (see gram_matrix)."""
    def rows(tree):
        return jnp.concatenate(
            [leaf.reshape(leaf.shape[0], -1).astype(jnp.float32)
             for leaf in jax.tree.leaves(tree)], axis=1)

    gflat = jnp.concatenate(
        [leaf.ravel().astype(jnp.float32) for leaf in jax.tree.leaves(g)])
    basis = jnp.concatenate([rows(h.s), rows(h.y), gflat[None]], axis=0)
    return kernel_ops.vlbfgs_gram(basis, mode=kernels)


def direction(h: History, g, kernels: str = "off"):
    """Full VL-BFGS step: p = -H_t g (Alg. 1 line 6).

    ``kernels`` ("auto" | "on" | "off", FimLbfgsConfig.kernels) routes
    the Gram matrix through repro.kernels.ops.vlbfgs_gram; "off" (the
    default, and the right setting for sharded LLM-scale history) keeps
    the per-leaf all-gather-free ``gram_matrix`` path."""
    m = jax.tree.leaves(h.s)[0].shape[0]
    if kernel_ops.resolve(kernels) == "oracle":
        M = gram_matrix(h, g)
    else:
        M = _gram_via_kernel(h, g, kernels)
    delta = direction_coeffs(M, h.idx, h.count, m)
    return combine(h, g, delta)


def reference_two_loop(s_list, y_list, g):
    """Textbook O(d)-vector two-loop recursion (oracle for tests).

    s_list/y_list: python lists of flat f64 arrays, oldest first."""
    import numpy as np

    q = np.asarray(g, dtype=np.float64).copy()
    alphas = []
    rhos = [1.0 / float(np.dot(y, s))
            for s, y in zip(s_list, y_list, strict=True)]
    for s, y, rho in zip(reversed(s_list), reversed(y_list), reversed(rhos),
                         strict=True):
        a = rho * float(np.dot(s, q))
        q -= a * np.asarray(y, np.float64)
        alphas.append(a)
    if s_list:
        gamma = float(np.dot(s_list[-1], y_list[-1]) / np.dot(y_list[-1], y_list[-1]))
    else:
        gamma = 1.0
    r = gamma * q
    for (s, y, rho), a in zip(zip(s_list, y_list, rhos, strict=True),
                              reversed(alphas), strict=True):
        b = rho * float(np.dot(y, r))
        r += (a - b) * np.asarray(s, np.float64)
    return -r
