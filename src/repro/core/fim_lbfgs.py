"""FIM-based Approximate L-BFGS — the paper's Algorithm 1, as a composable
optimizer.

Round structure (server view):
  1. aggregate client gradients  ḡ = (1/K)Σ ∇F_k      (one all-reduce, O(d))
  2. aggregate client FIM diags  Γ̄ = (1/K)Σ Γ_k       (one all-reduce, O(d))
  3. direction p_t = -H_t ḡ via vector-free two-loop    (O(m²) scalar comm)
  4. ω_{t+1} = ω_t + η p_t;  s_t = η p_t
  5. y_t = (Γ̄ + λI) s_t      — the FIM smoothing of Alg. 1 line 8; replaces
     the unstable stochastic gradient difference of stochastic L-BFGS
  6. push (s_t, y_t) unless the curvature test <s,y> ≥ ε‖s‖‖y‖ fails
     (the guard that keeps Lemma 1's θ₁I ⪯ H_t ⪯ θ₂I in force)

In the TPU mapping, steps 1-2 are the data/pod-axis collectives produced by
batch sharding; steps 3-6 are elementwise/sharded and add only scalar
collectives (see DESIGN.md §3).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import fim, lbfgs
from repro.utils.pytree import tree_axpy, tree_dot, tree_norm


class FimLbfgsConfig(NamedTuple):
    learning_rate: float = 0.05
    m: int = 10
    damping: float = 1e-3
    rel_damping: float = 0.1
    fim_ema: float = 0.95
    curvature_eps: float = 1e-8
    max_step_norm: float = 0.0      # 0 disables step clipping
    history_dtype: jnp.dtype = jnp.float32
    state_dtype: jnp.dtype = jnp.float32  # Fisher EMA + s/y temporaries;
                                          # bf16 at LLM scale (f32 copies of
                                          # 132B params dominate collectives)
    kernels: str = "off"            # Pallas fast path for the Gram matrix
                                    # (repro.kernels.ops.vlbfgs_gram).
                                    # "off" by default: the kernel basis
                                    # ravels the history, which would
                                    # all-gather sharded LLM-scale state
                                    # (see lbfgs.gram_matrix); federated
                                    # strategies pass FedConfig.kernels


class FimLbfgsState(NamedTuple):
    history: lbfgs.History
    fim: fim.FimState
    step: jax.Array


def init(params, cfg: FimLbfgsConfig) -> FimLbfgsState:
    return FimLbfgsState(
        history=lbfgs.init(params, cfg.m, dtype=cfg.history_dtype),
        fim=fim.init(params, dtype=cfg.state_dtype),
        step=jnp.zeros((), jnp.int32),
    )


def state_axes(param_axes, cfg: FimLbfgsConfig) -> FimLbfgsState:
    """Logical sharding axes for the optimizer state (history gets a leading
    'history' axis; FIM diag shards exactly like the parameters)."""
    hist = jax.tree.map(lambda a: ("history," + a) if a else "history", param_axes)
    return FimLbfgsState(
        history=lbfgs.History(s=hist, y=hist, idx="", count=""),
        fim=fim.FimState(diag=param_axes, steps=""),
        step="",
    )


def update(
    state: FimLbfgsState,
    params,
    grad,
    fim_diag,
    cfg: FimLbfgsConfig,
    learning_rate: Optional[jax.Array] = None,
):
    """One server round given aggregated ḡ and Γ̄. Returns (params, state, stats)."""
    lr = cfg.learning_rate if learning_rate is None else learning_rate

    fim_state = fim.update(state.fim, fim_diag, cfg.fim_ema)

    # Alg. 1 line 6: p_t = -H_t ḡ  (vector-free two-loop; the Gram matrix
    # runs through the Pallas kernel when cfg.kernels enables it).
    p = lbfgs.direction(state.history, grad, kernels=cfg.kernels)

    if cfg.max_step_norm:
        # trust region on the actual step ||η p_t|| (not the raw direction)
        pn = tree_norm(p) * lr
        scale = jnp.minimum(1.0, cfg.max_step_norm / jnp.maximum(pn, 1e-12))
    else:
        scale = jnp.float32(1.0)

    # Alg. 1 line 7: ω_{t+1} = ω_t + η p_t.  The step stays in the
    # direction's dtype: a f32 copy of the full parameter vector would ride
    # every ZeRO reshard at 2x bytes (observed on dbrx-132b).
    s = jax.tree.map(
        lambda pi: (lr * scale * pi.astype(jnp.float32)).astype(pi.dtype), p)
    new_params = tree_axpy(1.0, s, params)

    # Alg. 1 line 8: y_t = B̄_t s_t  with B̄ = Γ̄ + λI.
    y = fim.smooth_y(fim_state, s, cfg.damping, cfg.rel_damping)

    # Curvature safeguard (Lemma 1 bounds): skip degenerate pairs.
    sy = tree_dot(s, y)
    sn, yn = tree_norm(s), tree_norm(y)
    ok = sy > cfg.curvature_eps * sn * yn

    pushed = lbfgs.push(state.history, s, y)
    history = jax.tree.map(
        lambda new, old: jnp.where(ok, new, old) if new.ndim == 0 else
        jnp.where(ok, new, old),
        pushed, state.history,
    )

    stats = {
        "dir_norm": tree_norm(p),
        "step_norm": sn,
        "sy": sy,
        "pair_accepted": ok.astype(jnp.float32),
        "grad_norm": tree_norm(grad),
    }
    return new_params, FimLbfgsState(history, fim_state, state.step + 1), stats
