"""The paper's contribution: FIM-based approximate L-BFGS (Algorithm 1) and
the FedOVA training scheme (Algorithm 2), plus the baselines it is compared
against (Table II)."""
from repro.core import aggregation, baselines, fedova, fim, fim_lbfgs, lbfgs  # noqa: F401
