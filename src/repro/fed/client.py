"""Client-side computation (paper Alg. 1 ClientUpdate / Alg. 2 Step 2).

All client functions are pure and jitted once per model; the Python-level
federated loop (server.py) feeds them per-client data.  The same functions
are vmapped by simulator.py for the mesh-parallel cohort path.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, fim


def make_grad_fim_fn(loss_fn: Callable, per_example_loss: Callable | None,
                     fim_mode: str = "per_example", kernels: str = "off"):
    """Client update for Algorithm 1: returns (grad, Γ_k, loss).

    loss_fn(params, batch) -> scalar; per_example_loss(params, x, y) ->
    scalar (needed for the exact Eq. 9 diagonal).  ``kernels``
    (FedConfig.kernels) routes the Fisher square+mean through the fused
    Pallas op (repro.kernels.ops.fim_diag_update)."""

    @jax.jit
    def client_grad_fim(params, batch):
        loss, grad = jax.value_and_grad(loss_fn)(params, batch)
        if fim_mode == "per_example" and per_example_loss is not None:
            diag = fim.per_example_diag(per_example_loss, params,
                                        batch["x"], batch["y"],
                                        kernels=kernels)
        else:
            diag = fim.microbatch_diag(grad, kernels=kernels)
        return grad, diag, loss

    return client_grad_fim


def make_local_sgd_fn(loss_fn: Callable):
    """FedAvg client: E epochs of minibatch SGD over stacked local batches.

    batches: pytree with leading (n_batches, ...) dim; scanned."""

    @functools.partial(jax.jit, static_argnames=("lr",))
    def local_sgd(params, batches, lr: float):
        def step(p, batch):
            loss, grad = jax.value_and_grad(loss_fn)(p, batch)
            p = jax.tree.map(lambda w, g: w - lr * g.astype(w.dtype), p, grad)
            return p, loss

        params, losses = jax.lax.scan(step, params, batches)
        return params, jnp.mean(losses)

    return local_sgd


def make_local_adam_fn(loss_fn: Callable):
    """FedAvg-based Adam client: E epochs of minibatch Adam locally
    (the paper's 'FedAvg-based Adam' baseline, Table II)."""

    @functools.partial(jax.jit, static_argnames=("lr",))
    def local_adam(params, batches, lr: float):
        state = baselines.adam_init(params)

        def step(carry, batch):
            p, st = carry
            loss, grad = jax.value_and_grad(loss_fn)(p, batch)
            p, st, _ = baselines.adam_update(st, p, grad, lr)
            return (p, st), loss

        (params, _), losses = jax.lax.scan(step, (params, state), batches)
        return params, jnp.mean(losses)

    return local_adam


def make_feddane_fn(loss_fn: Callable):
    """FedDANE client: inner SGD on the DANE-corrected local objective."""

    @functools.partial(jax.jit, static_argnames=("lr", "mu"))
    def local_dane(params, batches, global_grad, local_grad_at_start,
                   lr: float, mu: float):
        start = params

        def step(p, batch):
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            g = baselines.feddane_inner_grad(g, local_grad_at_start, global_grad,
                                             p, start, mu)
            p = jax.tree.map(lambda w, gi: w - lr * gi.astype(w.dtype), p, g)
            return p, loss

        params, losses = jax.lax.scan(step, params, batches)
        return params, jnp.mean(losses)

    return local_dane


def make_fedprox_fn(loss_fn: Callable):
    """FedProx client [Li et al., MLSys 2020]: inner SGD on the proximal
    objective  F_k(w) + (mu/2)||w - w_t||²  — bounds local drift under
    non-IID data."""

    @functools.partial(jax.jit, static_argnames=("lr", "mu"))
    def local_prox(params, batches, lr: float, mu: float):
        start = params

        def step(p, batch):
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            g = jax.tree.map(
                lambda gi, w, w0: gi + mu * (w - w0).astype(gi.dtype),
                g, p, start)
            p = jax.tree.map(lambda w, gi: w - lr * gi.astype(w.dtype), p, g)
            return p, loss

        params, losses = jax.lax.scan(step, params, batches)
        return params, jnp.mean(losses)

    return local_prox


def stack_batches(xs, ys, batch_size: int, epochs: int, rng):
    """Materialize E epochs of shuffled minibatches as stacked arrays for
    lax.scan (static shapes: drops ragged tails)."""
    n = len(xs)
    bs = min(batch_size, n)
    nb = max(1, n // bs)
    bx, by = [], []
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(nb):
            idx = order[i * bs:(i + 1) * bs]
            bx.append(xs[idx])
            by.append(ys[idx])
    return {"x": jnp.asarray(np.stack(bx)), "y": jnp.asarray(np.stack(by))}
