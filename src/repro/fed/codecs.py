"""Pluggable upload-payload codecs for the federated runtime.

The paper's premise is shrinking upload bytes on a resource-constrained
edge (Theorem 3); this registry makes the *wire format* of a client
upload a first-class, swappable object, mirroring the
:mod:`repro.fed.strategies` registry.  A codec answers two questions:

  * ``wire_bytes(n_floats)`` — how many bytes does an ``n_floats``-element
    payload cost on the uplink?  This single number feeds CommLedger
    metering, the edge channel's uplink time/energy, and the scheduler's
    ``ClientEstimate``s, so the PR-2 invariant "ledger actuals == plan by
    construction" stays true under every codec.
  * ``roundtrip(tree, key, residual)`` — what does the server *receive*
    (the simulation never serializes; it applies the lossy round-trip),
    and what residual should the client carry into its next round?

Built-in codecs:

  * ``none``   — float32 passthrough (4 bytes/element).
  * ``int8``   — per-tensor symmetric int8 with stochastic rounding
    (1 byte/element, unbiased per round; the related-work axis the paper
    cites as [27], [28]).
  * ``topk:r`` — magnitude top-k sparsification keeping the globally
    largest ``ceil(r·n)`` coordinates of the flattened payload — exactly
    what ``wire_bytes`` bills; 8 bytes per kept element (value +
    explicit index).
  * ``randk:r``— uniform random-k sparsification; 4 bytes per kept
    element (indices are derived from a PRNG seed the server shares, so
    only values cross the wire).

Both sparsifiers use client-side **error feedback**: the coordinates a
round drops are accumulated into a per-client residual (owned by the
federated driver, keyed by true client id — so even stale async deltas
keep their correction) and added back into the next round's payload.
Zeroing coordinates is only meaningful for *additive* payloads
(gradients, model deltas), i.e. plans declaring ``summable=True``;
``FedStrategy.round_plan`` rejects a sparsifying codec for any other
strategy rather than silently corrupting distinct-model uploads.

Registering a codec makes it constructible by name through
``FedConfig(compress="<spec>")``, where a spec is ``name`` or
``name:param``::

    @register("fp16")
    class Fp16Codec(PayloadCodec):
        ...
"""
from __future__ import annotations

import abc
import math
from typing import Callable, Optional

import jax
import jax.flatten_util
import jax.numpy as jnp

from repro.fed import comm
from repro.kernels import ops as kernel_ops


# ---------------------------------------------------------------------------
# The codec protocol
# ---------------------------------------------------------------------------
class PayloadCodec(abc.ABC):
    """One upload wire format: byte accounting + the lossy round-trip.

    Codecs are stateless and shareable; all per-client state (the error-
    feedback residual) lives with the caller, threaded through
    ``roundtrip``."""

    name: str = ""            # filled in by ``register``
    sparsifying: bool = False  # zeroes coordinates -> needs summable payloads
    error_feedback: bool = False  # returns a residual for the caller to keep
    # Pallas fast-path knob for the encode hot loop ("auto" | "on" |
    # "off", see repro.kernels.ops.resolve); ``make(spec, kernels=...)``
    # overrides per instance from FedConfig.kernels.  Every mode computes
    # bit-identical keep sets / quantized values, so plan==ledger billing
    # and the error-feedback algebra cannot depend on the knob.
    kernels: str = "auto"

    @property
    def identity(self) -> bool:
        """True if the round-trip is lossless passthrough (skip the work)."""
        return False

    @abc.abstractmethod
    def wire_bytes(self, n_floats: float) -> float:
        """Uplink bytes for an ``n_floats``-element payload."""

    @abc.abstractmethod
    def roundtrip(self, tree, key, residual=None):
        """-> (received_tree, new_residual).

        ``received_tree`` is what the server sees after encode+decode;
        ``new_residual`` is the error-feedback state the client must hand
        back next round (None for residual-free codecs)."""

    def spec(self) -> str:
        """The ``FedConfig.compress`` string that reconstructs this codec."""
        return self.name


class NoneCodec(PayloadCodec):
    """Uncompressed float32 uploads."""

    @property
    def identity(self) -> bool:
        return True

    def wire_bytes(self, n_floats: float) -> float:
        return float(n_floats) * comm.BYTES_F32

    def roundtrip(self, tree, key, residual=None):
        return tree, None


# ---------------------------------------------------------------------------
# int8 stochastic-rounding quantization (moved here from fed/comm.py).
# quantize/dequantize_tree remain the explicit two-step wire form (int8
# payload + scales); Int8Codec's simulation round-trip uses the fused
# kernel path in repro.kernels, which reproduces this pair bit-for-bit.
# ---------------------------------------------------------------------------
def quantize_tree(tree, key):
    """-> (int8 tree, scales tree). Unbiased: stochastic rounding."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))
    q_leaves, scales = [], []
    for leaf, k in zip(leaves, keys, strict=True):
        a = leaf.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(a)), 1e-12) / 127.0
        x = a / scale
        lo = jnp.floor(x)
        p = x - lo
        rnd = lo + (jax.random.uniform(k, x.shape) < p).astype(jnp.float32)
        q_leaves.append(jnp.clip(rnd, -127, 127).astype(jnp.int8))
        scales.append(scale)
    return (jax.tree_util.tree_unflatten(treedef, q_leaves),
            jax.tree_util.tree_unflatten(treedef, scales))


def dequantize_tree(q_tree, scales):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scales)


class Int8Codec(PayloadCodec):
    """Per-tensor symmetric int8 with stochastic rounding: 4x fewer
    upload bytes, unbiased per round (E[dequant(quant(x))] = x), so no
    error-feedback residual is needed.

    The round-trip runs the fused Pallas kernel where the ``kernels``
    knob resolves to one (repro.kernels.ops.int8_roundtrip); the key
    split and uniform draws match ``quantize_tree`` exactly, so every
    dispatch path reproduces the historical codec bit-for-bit."""

    def wire_bytes(self, n_floats: float) -> float:
        return float(n_floats) * comm.BYTES_INT8

    def roundtrip(self, tree, key, residual=None):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        out = [kernel_ops.int8_roundtrip(leaf, k, mode=self.kernels)
               for leaf, k in zip(leaves, keys, strict=True)]
        return jax.tree_util.tree_unflatten(treedef, out), None


# ---------------------------------------------------------------------------
# Sparsifiers with client-side error feedback
# ---------------------------------------------------------------------------
class _SparsifyingCodec(PayloadCodec):
    """Shared scaffolding: ratio validation, error-feedback round-trip.
    Subclasses pick which coordinates survive (``_keep``).

    Selection is GLOBAL over the flattened payload, not per tensor, so
    the number of transmitted coordinates is exactly the
    ``ceil(ratio * n_floats)`` that ``wire_bytes`` bills — the metered
    wire size and the semantic round-trip cannot drift apart.  (Global
    top-k mixes magnitude scales across payload parts — e.g. gradients
    vs Fisher diagonals — but error feedback retries whatever a round
    starves, so no coordinate is lost, only delayed.)"""

    sparsifying = True
    error_feedback = True
    default_ratio = 0.1

    def __init__(self, ratio: Optional[float] = None):
        ratio = self.default_ratio if ratio is None else float(ratio)
        if not 0.0 < ratio <= 1.0:
            raise ValueError(
                f"codec {self.name or type(self).__name__!r} ratio must be "
                f"in (0, 1], got {ratio}")
        self.ratio = ratio

    def spec(self) -> str:
        return f"{self.name}:{self.ratio:g}"

    def _k(self, size: int) -> int:
        # an empty payload keeps 0 coordinates — matching wire_bytes(0)
        # == 0; the old max(1, ...) floor claimed one kept element that
        # does not exist (and jax.lax.top_k crashes on zero-size input)
        if size <= 0:
            return 0
        return max(1, min(int(size), math.ceil(self.ratio * size)))

    def _keep(self, flat, k: int, key):
        raise NotImplementedError

    def roundtrip(self, tree, key, residual=None):
        if residual is not None:
            tree = jax.tree.map(jnp.add, tree, residual)
        flat, unravel = jax.flatten_util.ravel_pytree(tree)
        if self._k(flat.size) == 0:
            # zero-element no-op round-trip: nothing crosses the wire,
            # nothing is dropped, so the residual is (empty) zeros
            return tree, jax.tree.map(jnp.zeros_like, tree)
        sent = unravel(self._keep(flat, self._k(flat.size), key))
        new_residual = jax.tree.map(jnp.subtract, tree, sent)
        return sent, new_residual


class TopKCodec(_SparsifyingCodec):
    """Keep the largest-magnitude ``ceil(ratio * n)`` coordinates of the
    payload.  Wire format: 4-byte value + 4-byte explicit index per kept
    element."""

    def wire_bytes(self, n_floats: float) -> float:
        return math.ceil(self.ratio * float(n_floats)) * 8.0

    def _keep(self, flat, k: int, key):
        # bucketed threshold select (repro.kernels): O(n) streaming, no
        # global sort; exactly k coordinates survive, threshold-bucket
        # ties breaking by index order on every dispatch path
        return kernel_ops.topk_select(flat, k, mode=self.kernels)


class RandKCodec(_SparsifyingCodec):
    """Keep ``ceil(ratio * n)`` uniformly random coordinates of the
    payload.  The index set is derived from a PRNG seed the server
    shares, so only the 4-byte values cross the wire (half top-k's
    per-element cost)."""

    def wire_bytes(self, n_floats: float) -> float:
        return math.ceil(self.ratio * float(n_floats)) * 4.0

    def _keep(self, flat, k: int, key):
        idx = jax.random.choice(key, flat.size, (k,), replace=False)
        return jnp.zeros_like(flat).at[idx].set(flat[idx])


# ---------------------------------------------------------------------------
# Registry (mirrors repro.fed.strategies)
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[..., PayloadCodec]] = {}


def register(name: str, factory: Optional[Callable[..., PayloadCodec]] = None):
    """Register ``factory([param]) -> PayloadCodec`` under ``name``.
    Usable as a decorator on a codec class or called directly."""

    def _do(f):
        try:
            f.name = name
        except (AttributeError, TypeError):
            pass
        _REGISTRY[name] = f
        return f

    return _do if factory is None else _do(factory)


def get(name: str) -> Callable[..., PayloadCodec]:
    if name not in _REGISTRY:
        raise ValueError(f"unknown payload codec {name!r}; known: {names()}")
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)


def make(spec: str | PayloadCodec,
         kernels: Optional[str] = None) -> PayloadCodec:
    """Build a codec from a ``FedConfig.compress`` spec: a PayloadCodec
    instance (returned as-is) or a ``"name"`` / ``"name:param"`` string,
    e.g. ``"int8"``, ``"topk:0.05"``.

    ``kernels`` (FedConfig.kernels: "auto" | "on" | "off") selects the
    Pallas fast path for the encode hot loop; None keeps the codec's
    class default ("auto")."""
    if isinstance(spec, PayloadCodec):
        codec = spec
    else:
        if not isinstance(spec, str):
            raise ValueError(
                f"codec spec must be a string or PayloadCodec, got {spec!r}")
        name, _, arg = spec.partition(":")
        factory = get(name)
        try:
            codec = factory(float(arg)) if arg else factory()
        except (TypeError, ValueError) as e:
            raise ValueError(f"bad codec spec {spec!r}: {e}") from None
    if kernels is not None:
        if kernels not in kernel_ops.MODES:
            raise ValueError(
                f"codec kernels mode must be one of {kernel_ops.MODES}, "
                f"got {kernels!r}")
        codec.kernels = kernels
    return codec


def achieved_ratio(codec: PayloadCodec, n_floats: float) -> float:
    """Achieved wire compression: ``wire_bytes / raw float32 bytes`` for
    an ``n_floats``-element payload (1.0 = uncompressed; the obs
    ``codec_ratio`` gauge).  An empty payload compresses to nothing —
    ratio 1.0 by convention."""
    raw = float(n_floats) * comm.BYTES_F32
    if raw <= 0:
        return 1.0
    return float(codec.wire_bytes(n_floats)) / raw


register("none", NoneCodec)
register("int8", Int8Codec)
register("topk", TopKCodec)
register("randk", RandKCodec)

# the shared passthrough instance: the default wire format of a PhasePlan
NONE = NoneCodec()
