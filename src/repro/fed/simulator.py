"""Mesh-parallel federated cohort simulation.

server.py loops clients in Python (faithful to the paper's sequential
simulation).  This module is the *production* path: the selected cohort's
batches are stacked on a leading client dim, client gradients + FIM
diagonals are computed with vmap, and the aggregation reduces over that dim
— under pjit with the client dim sharded over the ("pod","data") mesh axes,
that reduction lowers to exactly one all-reduce per round, the paper's
O(d log τ) term (see launch/train.py for the LLM-scale equivalent where
microbatch cohorts play the client role).

The cohort client function is the SAME jitted fn the federated loop uses
(fed/client.py's ``make_grad_fim_fn``) — ``from_strategy`` derives the
whole round step from a registered strategy object, so the Python-loop
and vmapped paths cannot drift apart."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, fim_lbfgs
from repro.edge.device import flops_grad_fim
from repro.edge.runtime import EdgeRuntime
from repro.fed import client as fed_client
from repro.fed import codecs, comm


def _build_round_step(client_fn: Callable, server_update: Callable,
                      compress_fn: Optional[Callable] = None):
    """round_step(params, opt_state, cohort_batch, weights, key=None):
    vmap the per-client fn over the stacked cohort, optionally round-trip
    each client's (grad, Γ) payload through the codec (``key`` supplies
    the per-client randomness; None skips compression), aggregate once,
    apply the pure server update."""

    def round_step(params, opt_state, cohort_batch, weights, key=None):
        grads, diags, losses = jax.vmap(client_fn, in_axes=(None, 0))(
            params, cohort_batch)
        if compress_fn is not None and key is not None:
            keys = jax.random.split(key, losses.shape[0])
            grads, diags = jax.vmap(compress_fn, in_axes=((0, 0), 0))(
                (grads, diags), keys)
        grad = aggregation.weighted_mean(grads, weights)      # Σ_k (n_k/n) ∇F_k
        diag = aggregation.weighted_mean(diags, weights)      # Σ_k (n_k/n) Γ_k
        new_params, new_state, stats = server_update(
            opt_state, params, grad, diag)
        stats["loss"] = jnp.mean(losses)
        return new_params, new_state, stats

    return jax.jit(round_step)


def make_round_step(loss_fn: Callable, per_example_loss: Callable | None,
                    ocfg: fim_lbfgs.FimLbfgsConfig, fim_mode: str = "per_example"):
    """Returns round_step(params, opt_state, cohort_batch, weights).

    cohort_batch: {"x": (K, B, ...), "y": (K, B)} — one stacked batch per
    selected client; weights: (K,) sample counts n_k."""
    client_fn = fed_client.make_grad_fim_fn(loss_fn, per_example_loss, fim_mode)

    def server_update(opt_state, params, grad, diag):
        return fim_lbfgs.update(opt_state, params, grad, diag, ocfg)

    return _build_round_step(client_fn, server_update)


def from_strategy(strategy):
    """Derive the vmapped cohort ``round_step`` from a registered strategy
    (repro.fed.strategies): the strategy's own jitted client fn and pure
    server update, so the sequential and mesh-parallel paths share code.

    The strategy's codec (``FedConfig.compress``) is threaded through as
    well: pass a PRNG ``key`` to the returned step and every client's
    payload is round-tripped through ``strategy.compress_payload`` inside
    the same jitted round (stateless — the vmapped path keeps no per-
    client error-feedback residuals, so sparsifiers here quantify the
    raw, feedback-free compression error)."""
    try:
        client_fn = strategy.cohort_client_fn
        server_update = strategy.cohort_server_update
    except AttributeError as e:
        raise NotImplementedError(
            f"strategy {getattr(strategy, 'name', strategy)!r} does not "
            "expose a vmappable cohort path (needs cohort_client_fn + "
            "cohort_server_update)") from e
    compress_fn = None
    codec = getattr(strategy, "codec", codecs.NONE)
    if not codec.identity:
        def compress_fn(payload, key):
            out, _ = strategy.compress_payload(payload, key)
            return out
    jitted = _build_round_step(client_fn, server_update, compress_fn)

    def round_step(params, opt_state, cohort_batch, weights, key=None):
        return jitted(params, opt_state, cohort_batch, weights, key)

    # advertise the wire format so with_edge bills the same codec the
    # payloads actually round-trip through — one spec, not two
    round_step.codec = codec
    return round_step


def with_edge(round_step: Callable, edge: EdgeRuntime, n_params: int,
              compress=None, tracer=None):
    """Wrap a jitted ``round_step`` with the edge cost model.

    ``tracer`` (a :class:`repro.obs.trace.Tracer`) attaches observability
    to the given ``edge`` runtime — round/client spans on the simulated
    timeline, byte/energy/drop metrics — exactly as passing the tracer to
    ``EdgeRuntime(...)`` directly would; the kwarg exists so callers who
    received an already-built runtime can still trace it.

    The vmapped cohort is the selected client set; after the device-side
    step, the wrapper advances the edge clock by the synchronous-round
    wall time (per-client grad+FIM compute plus the 2d-float uplink under
    the configured topology) and drains batteries.  stats gains
    ``wall_s`` / ``sim_time_s`` / ``energy_j`` host-side entries.

    The wrapped step takes an optional ``clients`` array — the TRUE
    selected client ids — so device heterogeneity and battery drain hit
    the right fleet entries; without it, cohort slot i falls back to
    fleet entry i (mod fleet size).

    The uplink is costed at the codec's wire size, so edge time/energy
    shrink exactly as the ledger bytes do.  The codec is derived from the
    ``round_step`` itself (``from_strategy`` attaches the strategy's
    codec); ``compress`` exists only to state it explicitly and must
    match — billing a wire format the step does not round-trip raises,
    so cost and accuracy cannot be paired apart by accident.

    Each round the edge's AllocationPolicy apportions the shared
    bandwidth budget over the given cohort (``EdgeRuntime.allocate_for``
    — selection already happened upstream, only the ``allocate`` stage
    runs, and it runs BEFORE the device step so deadline enforcement can
    shape the aggregation), so e.g. ``bandwidth_opt`` shrinks the sync
    barrier here too.  Granted deadlines are enforced: a cohort slot
    whose device busts min(its grant, EdgeConfig.enforce_deadline_s) is
    cut off at the barrier — its weight is zeroed so the in-jit
    weighted_mean re-normalizes over the on-time partial cohort, and an
    all-dropped round applies no server step.
    Policies that emit per-client *codecs* are rejected: the vmapped
    path round-trips every client through the one run codec, and billing
    wire formats the payloads never saw is the divergence this layer
    exists to forbid."""
    if tracer is not None:
        edge.tracer = tracer
        if edge.async_agg is not None:
            edge.async_agg.tracer = tracer
    step_codec = getattr(round_step, "codec", codecs.NONE)
    codec = step_codec if compress is None else codecs.make(compress)
    if codec.spec() != step_codec.spec():
        raise ValueError(
            f"round_step round-trips payloads through "
            f"{step_codec.spec()!r} but billing was requested at "
            f"{codec.spec()!r}; build the step with the same codec "
            "(simulator.from_strategy attaches FedConfig.compress)")
    down_bytes = float(n_params * comm.BYTES_F32)

    def wire_fn(override=None):
        # grad+FIM payloads are summable: fully aggregatable on the wire
        return float((override or codec).wire_bytes(2.0 * n_params)), 0.0

    def edge_round_step(params, opt_state, cohort_batch, weights,
                        clients: Optional[np.ndarray] = None, key=None):
        if key is None and not codec.identity:
            # billing compressed wire bytes for payloads that never
            # round-trip would pair uncompressed accuracy with compressed
            # cost — the silent divergence this layer exists to forbid
            raise ValueError(
                f"codec {codec.spec()!r} bills compressed uplink bytes: "
                "pass key=... so the payloads actually round-trip through "
                "it (or build the step with compress='none')")
        k, b = cohort_batch["y"].shape[:2]
        if clients is None:
            cohort = np.arange(k) % edge.num_clients
        else:
            cohort = np.asarray(clients, dtype=int)
            if cohort.shape != (k,):
                raise ValueError(
                    f"clients must map each of the {k} cohort slots to a "
                    f"fleet entry, got shape {cohort.shape}")
            if cohort.size and (cohort.min() < 0
                                or cohort.max() >= edge.num_clients):
                raise ValueError(
                    f"client ids must be in [0, {edge.num_clients}), "
                    f"got range [{cohort.min()}, {cohort.max()}]")
        est, decision = edge.allocate_for(
            cohort, wire_fn, flops_grad_fim(n_params, b), codec=codec)
        if decision.heterogeneous_codecs:
            raise ValueError(
                f"allocation policy {edge.cfg.scheduler!r} assigns "
                "per-client upload codecs, but the vmapped cohort path "
                "round-trips every client through the one run codec — "
                "use FederatedRun for adaptive per-client wire formats")
        # deadline enforcement: a cohort slot whose device busted its
        # granted deadline contributes nothing — its weight is zeroed, so
        # weighted_mean re-normalizes over the on-time partial cohort
        # (an all-dropped round applies no server step at all)
        mask = None
        if decision.n_dropped:
            mask = np.asarray([float(int(cc) not in decision.dropped)
                               for cc in cohort], dtype=np.float32)
            weights = jnp.asarray(weights) * mask
        if mask is not None and not mask.any():
            new_params, new_state, stats = (
                params, opt_state, {"loss": float("nan")})
        else:
            # only forward key when given: a bare 4-arg round_step stays
            # valid
            args = (params, opt_state, cohort_batch, weights)
            new_params, new_state, stats = (
                round_step(*args) if key is None else round_step(*args, key))
        # duplicate cohort slots (mod fallback) share one subchannel but
        # carry one payload each — bill every slot
        uniq, counts = np.unique(cohort, return_counts=True)
        mult = {int(u): int(c) for u, c in zip(uniq, counts, strict=True)}
        up_arr = np.asarray([mult[int(i)] * wire_fn()[0]
                             for i in decision.selected])
        rec = edge.finish_round_sync(est, up_arr, down_bytes)
        stats = dict(stats)
        stats.update(wall_s=rec["wall_s"], sim_time_s=rec["clock_s"],
                     energy_j=rec["energy_j"], dropped=rec["dropped"])
        if "barrier_s" in rec:
            stats["barrier_s"] = rec["barrier_s"]
        return new_params, new_state, stats

    return edge_round_step
