"""Mesh-parallel federated cohort simulation.

server.py loops clients in Python (faithful to the paper's sequential
simulation).  This module is the *production* path: the selected cohort's
batches are stacked on a leading client dim, client gradients + FIM
diagonals are computed with vmap, and the aggregation reduces over that dim
— under pjit with the client dim sharded over the ("pod","data") mesh axes,
that reduction lowers to exactly one all-reduce per round, the paper's
O(d log τ) term (see launch/train.py for the LLM-scale equivalent where
microbatch cohorts play the client role)."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, fim, fim_lbfgs
from repro.edge.device import flops_grad_fim
from repro.edge.runtime import EdgeRuntime
from repro.fed import comm


def make_round_step(loss_fn: Callable, per_example_loss: Callable | None,
                    ocfg: fim_lbfgs.FimLbfgsConfig, fim_mode: str = "per_example"):
    """Returns round_step(params, opt_state, cohort_batch, weights).

    cohort_batch: {"x": (K, B, ...), "y": (K, B)} — one stacked batch per
    selected client; weights: (K,) sample counts n_k."""

    def client_fn(params, batch):
        loss, grad = jax.value_and_grad(loss_fn)(params, batch)
        if fim_mode == "per_example" and per_example_loss is not None:
            diag = fim.per_example_diag(per_example_loss, params, batch["x"], batch["y"])
        else:
            diag = fim.microbatch_diag(grad)
        return grad, diag, loss

    def round_step(params, opt_state, cohort_batch, weights):
        grads, diags, losses = jax.vmap(client_fn, in_axes=(None, 0))(
            params, cohort_batch)
        grad = aggregation.weighted_mean(grads, weights)      # Σ_k (n_k/n) ∇F_k
        diag = aggregation.weighted_mean(diags, weights)      # Σ_k (n_k/n) Γ_k
        new_params, new_state, stats = fim_lbfgs.update(
            opt_state, params, grad, diag, ocfg)
        stats["loss"] = jnp.mean(losses)
        return new_params, new_state, stats

    return jax.jit(round_step)


def with_edge(round_step: Callable, edge: EdgeRuntime, n_params: int,
              compress: str = "none"):
    """Wrap a jitted ``round_step`` with the edge cost model.

    The vmapped cohort is the selected client set; after the device-side
    step, the wrapper advances the edge clock by the synchronous-round
    wall time (per-client grad+FIM compute plus the 2d-float uplink under
    the configured topology) and drains batteries.  stats gains
    ``wall_s`` / ``sim_time_s`` / ``energy_j`` host-side entries."""
    per_el = comm.BYTES_INT8 if compress == "int8" else comm.BYTES_F32
    up_bytes = 2.0 * n_params * per_el
    down_bytes = float(n_params * comm.BYTES_F32)

    def edge_round_step(params, opt_state, cohort_batch, weights):
        new_params, new_state, stats = round_step(
            params, opt_state, cohort_batch, weights)
        k, b = cohort_batch["y"].shape[:2]
        cohort = np.arange(k) % edge.num_clients
        edge.channel.sample()
        est = edge.estimate(cohort, up_bytes, flops_grad_fim(n_params, b))
        rec = edge.finish_round_sync(est, up_bytes, down_bytes)
        stats = dict(stats)
        stats.update(wall_s=rec["wall_s"], sim_time_s=rec["clock_s"],
                     energy_j=rec["energy_j"])
        return new_params, new_state, stats

    return edge_round_step
