"""Communication accounting + compression for the federated runtime.

Theorem 3 (paper Sec. V-B) claims O(d·log τ + m²) communication per round
for Algorithm 1 vs O(k·d) for FedAvg.  The ledger counts the *actual floats
exchanged* by each scheme in the simulation, under both topologies the
theorem distinguishes:

  * star  — every selected client uploads to the server directly (what a
    basic FEEL deployment does; server-link bytes scale with k);
  * tree  — in-network aggregation: uploads are summed pairwise along a
    binary tree, so the server link carries one aggregate and the *depth*
    (log₂ τ) bounds any node's traffic.  This is the reading under which
    Theorem 3's O(d log τ) holds, and the exact analogue of the ICI
    tree/ring all-reduce the TPU mapping lowers to (DESIGN.md §3).

Compressed uploads live in :mod:`repro.fed.codecs` (the pluggable codec
registry: int8 stochastic rounding, top-k / rand-k sparsification with
error feedback); the ledger only meters the *wire bytes* a codec
declares, via ``upload(..., wire_bytes=...)``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import numpy as np

BYTES_F32 = 4
BYTES_INT8 = 1


def tree_n_floats(tree) -> int:
    return sum(int(leaf.size) for leaf in jax.tree.leaves(tree))


@dataclass
class CommLedger:
    """Per-round communication in bytes, split by direction/topology."""
    down_bytes: float = 0.0          # server -> clients (broadcasts)
    up_star_bytes: float = 0.0       # server link, star topology
    up_tree_bytes: float = 0.0       # max per-node traffic, tree aggregation
    scalar_bytes: float = 0.0        # Gram-matrix / m² scalar exchanges
    rounds: int = 0

    def broadcast(self, n_floats: int, n_clients: int) -> float:
        # one multicast payload counted once per client link; returns the
        # bytes added so callers (the obs metrics layer) can mirror the
        # ledger without re-deriving its rules
        added = n_floats * BYTES_F32 * n_clients
        self.down_bytes += added
        return added

    def upload(self, n_floats: float, n_clients: int,
               bytes_per_el: int = BYTES_F32, aggregatable: bool = True,
               wire_bytes: float | None = None) -> tuple[float, float]:
        """A per-client upload of ``n_floats`` elements.

        ``wire_bytes`` overrides the linear ``n_floats * bytes_per_el``
        payload size with a codec's declared wire size (sparsified uploads
        carry indices, so bytes are not per-element uniform).

        aggregatable=True (gradients/FIM/summable params): in-network tree
        aggregation applies — each level halves the number of payloads, so
        any single node forwards at most ceil(log2 k) payloads of size d.
        aggregatable=False (FedAvg-style distinct local models the server
        must see individually): the tree carries every payload to the root,
        no gain over star.

        Returns the ``(star, tree)`` bytes added, so the obs metrics
        layer mirrors the ledger exactly without re-deriving its rules."""
        if n_clients <= 0:
            # nobody transmitted: the tree depth floor must not bill
            return 0.0, 0.0
        payload = (float(wire_bytes) if wire_bytes is not None
                   else n_floats * bytes_per_el)
        d_star = payload * n_clients
        if aggregatable:
            depth = max(1, math.ceil(math.log2(max(n_clients, 2))))
            d_tree = payload * depth
        else:
            d_tree = payload * n_clients
        self.up_star_bytes += d_star
        self.up_tree_bytes += d_tree
        return d_star, d_tree

    def upload_per_client(self, wire_bytes,
                          aggregatable: bool = True) -> tuple[float, float]:
        """Per-client uploads whose wire sizes DIFFER (per-client codecs,
        e.g. the adaptive_codec allocation policy).  ``wire_bytes`` is a
        sequence of per-client byte counts.

        star: every payload crosses the server link — the sum.
        tree, aggregatable: one summed payload per level; any node's
        traffic is bounded by the densest contribution, so the per-node
        metric bills depth × max.  tree, non-aggregatable: every payload
        reaches the root — the sum again.  With uniform sizes all three
        reduce exactly to :meth:`upload`.  Returns the ``(star, tree)``
        bytes added.

        ``wire_bytes`` may be a list or an ndarray; both are summed with
        the same numpy reduction, so the fleet fast path (arrays) and the
        dict path (lists) bill bitwise-identical totals."""
        sizes = np.asarray(wire_bytes, dtype=float)
        k = sizes.size
        if k == 0:
            return 0.0, 0.0
        d_star = float(sizes.sum())
        if aggregatable:
            depth = max(1, math.ceil(math.log2(max(k, 2))))
            d_tree = depth * float(sizes.max())
        else:
            d_tree = d_star
        self.up_star_bytes += d_star
        self.up_tree_bytes += d_tree
        return d_star, d_tree

    def scalars(self, n: int) -> float:
        added = n * BYTES_F32
        self.scalar_bytes += added
        return added

    def end_round(self) -> None:
        self.rounds += 1

    def summary(self) -> dict:
        r = max(self.rounds, 1)
        return {
            "rounds": self.rounds,
            "down_MB_per_round": self.down_bytes / r / 1e6,
            "up_star_MB_per_round": self.up_star_bytes / r / 1e6,
            "up_tree_MB_per_round": self.up_tree_bytes / r / 1e6,
            "scalar_KB_per_round": self.scalar_bytes / r / 1e3,
        }
