"""FedProx [Li et al., MLSys 2020] — the registry's third-party drop-in
proof: a sixth algorithm that lands as ~25 lines against the FedStrategy
protocol with zero driver changes.

Clients minimize F_k(w) + (μ/2)‖w − w_t‖² — the proximal term bounds
local drift under non-IID partitions and device-level incomplete work.
Everything else (delta payloads, FedAvg byte accounting, async
eligibility, and — because deltas are summable — the full codec matrix
including top-k / rand-k error-feedback sparsification) is inherited
from the FedAvg scaffolding.
"""
from __future__ import annotations

from repro.fed import client as fed_client
from repro.fed.strategies.base import register
from repro.fed.strategies.fedavg import LocalSolveStrategy


@register("fedprox")
class FedProxStrategy(LocalSolveStrategy):
    def _build_solver(self) -> None:
        self._prox = fed_client.make_fedprox_fn(self._loss)

    def _local_solve(self, params, batches):
        return self._prox(params, batches,
                          lr=float(self.fcfg.learning_rate),
                          mu=float(self.fcfg.prox_mu))
