"""Algorithm 1 (FIM-driven distributed L-BFGS) as a FedStrategy.

Clients upload (∇F_k, Γ_k) — summable, so the plan is fully
tree-aggregatable (Theorem 3's O(d log τ)) and async-eligible; the server
runs the FIM-L-BFGS quasi-Newton step on the aggregated pair, exchanging
only the (2m+1)² Gram scalars on top.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregation, fim_lbfgs
from repro.edge import device as edge_device
from repro.fed import client as fed_client
from repro.fed.strategies.base import FedStrategy, PhasePlan, RoundPlan, register
from repro.models import cnn


@register("fim_lbfgs")
class FimLbfgsStrategy(FedStrategy):
    def _build(self, key) -> None:
        self.params, _ = cnn.init(self.mcfg, key)
        def _loss(p, b):
            return cnn.softmax_loss(p, self.mcfg, b)
        self._loss = _loss
        kernels = getattr(self.fcfg, "kernels", "auto")
        self._grad_fim = fed_client.make_grad_fim_fn(
            self._loss, cnn.per_example_loss_fn(self.mcfg), self.fcfg.fim_mode,
            kernels=kernels)
        self.ocfg = fim_lbfgs.FimLbfgsConfig(
            learning_rate=self.fcfg.second_order_lr, m=self.fcfg.lbfgs_m,
            damping=self.fcfg.fim_damping, fim_ema=self.fcfg.fim_ema,
            max_step_norm=self.fcfg.max_step_norm, kernels=kernels)
        self.opt_state = fim_lbfgs.init(self.params, self.ocfg)
        self._eval = jax.jit(lambda p, x, y: cnn.accuracy(p, self.mcfg, x, y))

    def _make_plan(self) -> RoundPlan:
        d = self.n_params()
        return RoundPlan(
            phases=(PhasePlan("grad_fim", down_floats=d, up_floats=2.0 * d,
                              codec=self.codec, aggregatable=True),),
            flops=lambda n: edge_device.flops_grad_fim(self.n_params(), n),
            summable=True,
            round_scalars=(2 * self.fcfg.lbfgs_m + 1) ** 2,  # Gram exchange
        )

    def client_step(self, data, rng, context=None):
        xs, ys = data
        # Full local gradient/Fisher (the ERM F_k over D_k, as in
        # DANE/GIANT); stochastic batches are exercised by the LLM-scale
        # path where full data is impossible.
        batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
        g, f, loss = self._grad_fim(self.params, batch)
        return (g, f), float(loss)

    def compress_payload(self, payload, key, residual=None, codec=None):
        out, residual = (codec or self.codec).roundtrip(payload, key, residual)
        g, f = out
        # the Fisher diagonal must stay nonnegative through the roundtrip
        return (g, jax.tree.map(jnp.abs, f)), residual

    def aggregate(self, payloads, weights):
        w = jnp.asarray(weights, jnp.float32)
        grad = aggregation.weighted_mean(
            jax.tree.map(lambda *t: jnp.stack(t), *[p[0] for p in payloads]), w)
        fimd = aggregation.weighted_mean(
            jax.tree.map(lambda *t: jnp.stack(t), *[p[1] for p in payloads]), w)
        return grad, fimd

    def server_step(self, aggregate) -> None:
        grad, fimd = aggregate
        self.params, self.opt_state, _ = fim_lbfgs.update(
            self.opt_state, self.params, grad, fimd, self.ocfg)

    # -- vmapped cohort path (fed/simulator.py) --------------------------
    @property
    def cohort_client_fn(self):
        """Pure (params, batch) -> (grad, Γ, loss), vmappable over a
        stacked cohort batch."""
        return self._grad_fim

    def cohort_server_update(self, opt_state, params, grad, fim_diag):
        """Pure server update for the jitted cohort round_step."""
        return fim_lbfgs.update(opt_state, params, grad, fim_diag, self.ocfg)
