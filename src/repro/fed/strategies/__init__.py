"""Registry-backed federated algorithm strategies (see base.py for the
protocol).  Importing this package registers the built-in strategies:
fim_lbfgs, fedavg_sgd, fedavg_adam, fedprox, feddane, fedova,
fedova_lbfgs."""
from repro.fed.strategies.base import (FedStrategy, PhasePlan, RoundPlan,
                                       get, names, register)
from repro.fed.strategies import (  # noqa: F401  (registration side effects)
    fedavg, feddane, fedova, fedprox, fim_lbfgs)

__all__ = ["FedStrategy", "PhasePlan", "RoundPlan", "get", "names",
           "register", "fedavg", "feddane", "fedova", "fedprox",
           "fim_lbfgs"]
