"""FedDANE [Li et al., Asilomar 2019] as a two-phase FedStrategy.

Phase 1 (``round_context``): broadcast w_t, every client uploads its full
local gradient; the aggregate ∇f(w_t) is summable (tree-aggregatable).
Phase 2: broadcast the global gradient, clients run inner SGD on the
DANE-corrected objective and upload their local models — k distinct
iterates, NOT aggregatable, which is FedDANE's O(2·k·d) in Theorem 3's
terms and why the plan is not ``summable`` (no async until a summable
surrogate strategy is registered).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import aggregation
from repro.edge import device as edge_device
from repro.fed import client as fed_client
from repro.fed.strategies.base import FedStrategy, PhasePlan, RoundPlan, register
from repro.models import cnn


@register("feddane")
class FedDaneStrategy(FedStrategy):
    def _build(self, key) -> None:
        self.params, _ = cnn.init(self.mcfg, key)
        def _loss(p, b):
            return cnn.softmax_loss(p, self.mcfg, b)
        self._loss = _loss
        self._grad_fim = fed_client.make_grad_fim_fn(
            self._loss, cnn.per_example_loss_fn(self.mcfg), self.fcfg.fim_mode,
            kernels=getattr(self.fcfg, "kernels", "auto"))
        self._dane = fed_client.make_feddane_fn(self._loss)
        self._eval = jax.jit(lambda p, x, y: cnn.accuracy(p, self.mcfg, x, y))
        # the context phase's gradient uploads route through the codec too
        # (stateless — no error-feedback accumulator for the pre-phase)
        self._ckey = jax.random.PRNGKey(self.fcfg.seed + 29)

    def _make_plan(self) -> RoundPlan:
        d = self.n_params()
        e = self.fcfg.local_epochs
        return RoundPlan(
            phases=(
                PhasePlan("gradient", down_floats=d, up_floats=d,
                          codec=self.codec, aggregatable=True),
                PhasePlan("inner_solve", down_floats=d, up_floats=d,
                          codec=self.codec, aggregatable=False),
            ),
            flops=lambda n: (edge_device.flops_grad_fim(self.n_params(), n)
                             + edge_device.flops_local_sgd(self.n_params(), n, e)),
            summable=False,
        )

    def round_context(self, datas, rng):
        """Phase 1: full local gradients -> the cohort's global gradient;
        each client's context is (global_grad, its own ∇F_k(w_t))."""
        if not datas:
            return []
        local_grads, sent_grads, weights = [], [], []
        for xs, ys in datas:
            batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
            g, _, _ = self._grad_fim(self.params, batch)
            local_grads.append(g)  # the client keeps its exact gradient
            if not self.codec.identity:
                self._ckey, sub = jax.random.split(self._ckey)
                g, _ = self.codec.roundtrip(g, sub)
            sent_grads.append(g)   # the server only sees the wire version
            weights.append(len(xs))
        w = jnp.asarray(weights, jnp.float32)
        global_grad = aggregation.weighted_mean(
            jax.tree.map(lambda *t: jnp.stack(t), *sent_grads), w)
        return list(zip([global_grad] * len(datas), local_grads, strict=True))

    def client_step(self, data, rng, context=None):
        xs, ys = data
        global_grad, g0 = context
        batches = fed_client.stack_batches(
            xs, ys, self.fcfg.batch_size, self.fcfg.local_epochs, rng)
        p, loss = self._dane(self.params, batches, global_grad, g0,
                             lr=float(self.fcfg.learning_rate), mu=0.1)
        return p, float(loss)

    def server_step(self, aggregate) -> None:
        self.params = aggregate
