"""FedAvg-family strategies: local solves, model-delta payloads.

Clients run E local epochs and upload their model *delta* w_k − w_t.  The
delta form makes one aggregation path serve both modes: synchronously,
w_t + Σ (n_k/n)(w_k − w_t) equals FedAvg's weighted model mean; under
buffered-async aggregation a stale delta is a (staleness-discounted)
correction to the *current* params rather than a pull back toward the
stale starting point — so the plan is ``summable`` and async support
falls out.  The paper's Theorem 3 accounting is unchanged: the server
still learns k distinct iterates, so the uploads are NOT in-network
tree-aggregatable (O(k·d) at the root).
"""
from __future__ import annotations

import jax

from repro.edge import device as edge_device
from repro.fed import client as fed_client
from repro.fed.strategies.base import FedStrategy, PhasePlan, RoundPlan, register
from repro.models import cnn


class LocalSolveStrategy(FedStrategy):
    """Shared scaffolding: softmax model, delta payloads, FedAvg plan.
    Subclasses provide ``_local_solve(params, batches, rng)``."""

    def _build(self, key) -> None:
        self.params, _ = cnn.init(self.mcfg, key)
        def _loss(p, b):
            return cnn.softmax_loss(p, self.mcfg, b)
        self._loss = _loss
        self._eval = jax.jit(lambda p, x, y: cnn.accuracy(p, self.mcfg, x, y))
        self._build_solver()

    def _build_solver(self) -> None:
        raise NotImplementedError

    def _local_solve(self, params, batches):
        raise NotImplementedError

    def _make_plan(self) -> RoundPlan:
        d = self.n_params()
        e = self.fcfg.local_epochs
        return RoundPlan(
            # the paper's accounting: k distinct local models reach the
            # server — O(k·d), no in-network aggregation gain (Thm 3)
            phases=(PhasePlan("local_model", down_floats=d, up_floats=d,
                              codec=self.codec, aggregatable=False),),
            flops=lambda n: edge_device.flops_local_sgd(self.n_params(), n, e),
            summable=True,  # delta payloads sum — async- and sparsify-eligible
        )

    def client_step(self, data, rng, context=None):
        xs, ys = data
        batches = fed_client.stack_batches(
            xs, ys, self.fcfg.batch_size, self.fcfg.local_epochs, rng)
        p, loss = self._local_solve(self.params, batches)
        delta = jax.tree.map(lambda a, b: a - b, p, self.params)
        return delta, float(loss)

    def server_step(self, aggregate) -> None:
        self.params = jax.tree.map(lambda p, dl: p + dl,
                                   self.params, aggregate)


@register("fedavg_sgd")
class FedAvgSgdStrategy(LocalSolveStrategy):
    """FedAvg with local SGD [McMahan et al.]."""

    def _build_solver(self) -> None:
        self._sgd = fed_client.make_local_sgd_fn(self._loss)

    def _local_solve(self, params, batches):
        return self._sgd(params, batches, lr=float(self.fcfg.learning_rate))


@register("fedavg_adam")
class FedAvgAdamStrategy(LocalSolveStrategy):
    """Table II's "FedAvg-based Adam": clients run local Adam, the server
    averages (Adam lr convention: ~10x smaller than the SGD lr)."""

    def _build_solver(self) -> None:
        self._adam = fed_client.make_local_adam_fn(self._loss)

    def _local_solve(self, params, batches):
        return self._adam(params, batches,
                          lr=float(self.fcfg.learning_rate) * 0.1)
