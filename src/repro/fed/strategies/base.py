"""The ``FedStrategy`` protocol + registry.

Every federated algorithm in this repo is a self-describing strategy
object; ``FederatedRun`` (fed/server.py) is a *generic* round driver that
never branches on the algorithm name.  A strategy declares:

  * ``round_plan()`` — a :class:`RoundPlan`: per-phase upload/download
    floats, the upload's wire codec (repro.fed.codecs), and
    ``aggregatable`` flags, plus client FLOPs.  The plan is the single
    source of truth consumed by CommLedger metering, edge time/energy
    estimation, and scheduler planning — the ledger records exactly what
    the plan predicts, by construction, under every codec.
  * ``client_step(data, rng, context)`` — one client's local work,
    returning ``(payload, loss)``.  Payloads whose plan is ``summable``
    may be summed in-network and buffered asynchronously (FedBuff-style),
    so async edge support falls out of the declaration.
  * ``aggregate(payloads, weights)`` — combine client payloads (the same
    code path serves synchronous n_k-weighted and asynchronous
    staleness-weighted aggregation).
  * ``server_step(aggregate)`` — apply the aggregate to the server model.

Multi-phase algorithms (FedDANE's gradient round before the inner solves)
implement ``round_context``, which sees the whole cohort once and hands
each client its per-client context; the extra phase's bytes live in the
same plan.

Registering a strategy makes it constructible by name through
``FederatedRun(model_cfg, fed_cfg, train, test, algorithm="<name>")``::

    @register("my_alg")
    class MyStrategy(FedStrategy):
        ...
"""
from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import aggregation
from repro.fed import codecs, comm


# ---------------------------------------------------------------------------
# RoundPlan: the strategy's declared per-round resource footprint
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PhasePlan:
    """One communication phase of a round (per *selected client*).

    ``codec`` declares the upload's wire format (repro.fed.codecs): its
    ``wire_bytes(up_floats)`` is the single number CommLedger metering,
    edge uplink time/energy, and scheduler estimates all consume — the
    built-in strategies attach the run codec (``FedConfig.compress``) to
    every payload-carrying phase, so compressed wire sizes reach all
    three by construction.

    ``aggregatable`` carries the Theorem 3 semantics: summable payloads
    (gradients, Fisher diagonals, per-class OVA components) admit
    in-network tree aggregation — any node forwards O(log τ) payloads —
    while distinct local models must each reach the root (O(k·d))."""
    name: str
    down_floats: float = 0.0          # broadcast floats (server -> client)
    up_floats: float = 0.0            # upload floats (client -> server)
    codec: codecs.PayloadCodec = codecs.NONE   # upload wire format
    aggregatable: bool = True

    def wire_up_bytes(self) -> float:
        """Per-client upload bytes of this phase under its codec."""
        return self.codec.wire_bytes(self.up_floats)


@dataclass(frozen=True)
class RoundPlan:
    """Everything the generic driver needs to meter, estimate, and
    schedule one round of a strategy — consumed once, never branched on
    by algorithm name.

    flops(n_k) predicts one client's round FLOPs given its local sample
    count (partition sizes are run-constant, so the driver caches it).
    ``summable`` gates buffered-async aggregation: a stale summable
    payload is still a valid (staleness-discounted) additive update —
    and it also gates *sparsifying* codecs (top-k / rand-k), which zero
    coordinates and are only meaningful for such additive payloads.
    """
    phases: tuple[PhasePlan, ...]
    flops: Callable[[int], float]
    summable: bool = False
    round_scalars: int = 0            # per-round scalar floats (Gram m²)
    scalars_per_client: int = 0       # per-client scalar floats (OVA masks)

    def upload_bytes(self) -> float:
        """Per-client upload wire bytes per round (all phases)."""
        return float(sum(p.wire_up_bytes() for p in self.phases))

    def downlink_bytes(self) -> float:
        """Per-client broadcast bytes per round (all phases)."""
        return float(sum(p.down_floats * comm.BYTES_F32 for p in self.phases))

    def nonagg_upload_bytes(self) -> float:
        """The non-aggregatable share of upload_bytes (0 = fully summable
        in-network; FedDANE's model phase makes it a strict subset)."""
        return float(sum(p.wire_up_bytes()
                         for p in self.phases if not p.aggregatable))


# ---------------------------------------------------------------------------
# The strategy protocol
# ---------------------------------------------------------------------------
class FedStrategy(abc.ABC):
    """One federated algorithm as a self-describing object.

    Owns the server-side model/optimizer state and the jitted client
    functions; the driver owns sampling, metering, compression keys, the
    edge runtime, and the client loop."""

    name: str = ""  # filled in by ``register``

    def __init__(self, model_cfg: Any, fed_cfg: Any, n_classes: int):
        self.mcfg = model_cfg
        self.fcfg = fed_cfg
        self.n_classes = n_classes
        # the run's payload codec (FedConfig.compress); _make_plan attaches
        # it to payload-carrying phases so wire bytes flow everywhere.
        # FedConfig.kernels selects the Pallas encode fast path
        self.codec = codecs.make(fed_cfg.compress,
                                 kernels=getattr(fed_cfg, "kernels", None))
        self._n_params_cache: Optional[int] = None
        self._plan_cache: Optional[RoundPlan] = None
        self._build(jax.random.PRNGKey(fed_cfg.seed))

    # -- construction ----------------------------------------------------
    @abc.abstractmethod
    def _build(self, key: jax.Array) -> None:
        """Initialize model params, optimizer state, and jitted fns."""

    # -- declaration -----------------------------------------------------
    @abc.abstractmethod
    def _make_plan(self) -> RoundPlan:
        """Declare this strategy's per-round resource footprint."""

    def round_plan(self) -> RoundPlan:
        if self._plan_cache is None:
            plan = self._make_plan()
            if self.codec.sparsifying and not plan.summable:
                raise ValueError(
                    f"codec {self.codec.spec()!r} sparsifies payload "
                    "coordinates, which is only meaningful for additive "
                    f"(summable) payloads; strategy {self.name!r} uploads "
                    "distinct models/components (summable=False) — use "
                    "compress='none' or 'int8'")
            self._plan_cache = plan
        return self._plan_cache

    def n_params(self) -> int:
        """Float count of ONE broadcast model.  Default: the ``params``
        pytree built by ``_build``; strategies with a different server
        state (OVA's stacked components) override."""
        if self._n_params_cache is None:
            self._n_params_cache = comm.tree_n_floats(self.params)
        return self._n_params_cache

    # -- checkpoint/resume ----------------------------------------------
    def state_dict(self) -> dict:
        """Every server-side array that mutates across rounds, as a
        pytree ``repro.checkpoint`` round-trips (see
        :mod:`repro.checkpoint.run_state`).  Default: the ``params``
        pytree plus ``opt_state`` when the strategy keeps one; override
        for any extra mutable server state."""
        sd: dict = {"params": self.params}
        if hasattr(self, "opt_state"):
            sd["opt_state"] = self.opt_state
        return sd

    def load_state_dict(self, state: dict) -> None:
        self.params = jax.tree.map(jnp.asarray, state["params"])
        if "opt_state" in state:
            self.opt_state = jax.tree.map(jnp.asarray, state["opt_state"])

    # -- one round -------------------------------------------------------
    def round_context(self, datas: Sequence[tuple], rng: Any
                      ) -> Optional[Sequence[Any]]:
        """Optional cohort-wide pre-phase (FedDANE's gradient round).

        datas: list of (xs, ys) for the selected cohort.  Returns a
        per-client context sequence (or None), threaded into each
        ``client_step``."""
        return None

    @abc.abstractmethod
    def client_step(self, data: tuple, rng: Any,
                    context: Any = None) -> tuple[Any, float]:
        """One client's local update on data=(xs, ys).

        Returns (payload, loss).  The payload is whatever
        ``aggregate`` consumes — for summable plans it must be a pytree
        that remains meaningful under weighted summation."""

    def aggregate(self, payloads: Sequence[Any],
                  weights: Sequence[float]) -> Any:
        """Combine client payloads under (n_k- or staleness-) weights.
        Default: weighted mean over the stacked payload pytrees — right
        for any single-pytree payload (deltas, models, gradients);
        structured payloads (grad+Fisher pairs, masked OVA stacks)
        override."""
        return aggregation.weighted_mean(
            jax.tree.map(lambda *t: jnp.stack(t), *payloads),
            jnp.asarray(weights, jnp.float32))

    @abc.abstractmethod
    def server_step(self, aggregate: Any) -> None:
        """Apply an aggregate to the server model/optimizer state."""

    def compress_payload(self, payload: Any, key: Any, residual: Any = None,
                         codec: Optional[codecs.PayloadCodec] = None
                         ) -> tuple[Any, Any]:
        """Round-trip the payload through ``codec`` (default: the run's
        codec; an allocation policy may hand a client its own wire
        format, e.g. adaptive_codec's channel-scheduled top-k ratios).
        Returns ``(payload, new_residual)`` — the driver owns the
        per-client error-feedback residual and threads it back in next
        round.  Strategies whose payloads need structure-aware handling
        (e.g. a nonnegative Fisher diagonal, an OVA presence mask that
        must not be quantized) override this."""
        return (codec or self.codec).roundtrip(payload, key, residual)

    # -- evaluation ------------------------------------------------------
    def evaluate(self, x: Any, y: Any) -> float:
        """Test accuracy of the current server model.  Default: the
        jitted ``self._eval`` over ``self.params`` (built in ``_build``);
        strategies with other model state override."""
        return float(self._eval(self.params, x, y))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[..., FedStrategy]] = {}


def register(name: str, factory: Optional[Callable[..., FedStrategy]] = None):
    """Register ``factory(model_cfg, fed_cfg, n_classes) -> FedStrategy``
    under ``name``.  Usable as a decorator on a strategy class or called
    directly with a factory (variants of one class register twice)."""

    def _do(f):
        try:
            f.name = name
        except (AttributeError, TypeError):
            pass  # e.g. a functools.partial; the registry key still works
        _REGISTRY[name] = f
        return f

    return _do if factory is None else _do(factory)


def get(name: str) -> Callable[..., FedStrategy]:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown federated strategy {name!r}; known: {names()}")
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)
