"""FedOVA (paper Sec. IV-B, Algorithm 2) as a FedStrategy — optionally
driven by the FIM-L-BFGS server step ("fedova_lbfgs", the paper's claim
that the two contributions compose).

Each client trains only the binary OVA components whose class appears in
its local data and uploads (trained component stack, class-presence
mask); the grouped aggregation (Eq. 11) is a per-class weighted mean, so
the uploads ARE tree-aggregatable in Theorem 3's accounting.  The payload
is *not* summable (the mask-grouped mean needs each client's mask), so
async stays off until a summable surrogate is registered.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedova, fim_lbfgs
from repro.edge import device as edge_device
from repro.fed import client as fed_client
from repro.fed import comm
from repro.fed.strategies.base import FedStrategy, PhasePlan, RoundPlan, register
from repro.models import cnn


class FedOvaStrategy(FedStrategy):
    server_opt = "sgd"  # "sgd" (Alg. 2 as written) | "fim_lbfgs"

    def _build(self, key) -> None:
        bcfg = self.mcfg.binary()
        self.bcfg = bcfg
        self.model = fedova.OvaModel(
            components=jax.vmap(lambda k: cnn.init(bcfg, k)[0])(
                jax.random.split(key, self.n_classes)),
            n_classes=self.n_classes,
        )
        def _binary_loss(p, b):
            return cnn.binary_loss(p, bcfg, b)
        self._binary_loss = _binary_loss
        self._local_sgd = fed_client.make_local_sgd_fn(self._binary_loss)
        self._apply = jax.jit(lambda p, x: cnn.apply(p, bcfg, x))
        if self.server_opt == "fim_lbfgs":
            kernels = getattr(self.fcfg, "kernels", "auto")
            self.ocfg = fim_lbfgs.FimLbfgsConfig(
                learning_rate=self.fcfg.second_order_lr, m=self.fcfg.lbfgs_m,
                damping=self.fcfg.fim_damping, fim_ema=self.fcfg.fim_ema,
                max_step_norm=self.fcfg.max_step_norm, kernels=kernels)
            one = jax.tree.map(lambda leaf: leaf[0], self.model.components)
            self.opt_state = jax.vmap(
                lambda _: fim_lbfgs.init(one, self.ocfg))(
                    jnp.arange(self.n_classes))
            self._grad_fim = fed_client.make_grad_fim_fn(
                self._binary_loss, cnn.per_example_loss_fn(bcfg, binary=True),
                self.fcfg.fim_mode, kernels=kernels)

    def n_params(self) -> int:
        """One binary component (the broadcast/upload unit)."""
        if self._n_params_cache is None:
            one = jax.tree.map(lambda leaf: leaf[0], self.model.components)
            self._n_params_cache = comm.tree_n_floats(one)
        return self._n_params_cache

    def _classes_per_client(self) -> int:
        return min(self.fcfg.noniid_l or self.n_classes, self.n_classes)

    def _make_plan(self) -> RoundPlan:
        d = self.n_params()
        n = self.n_classes
        e = self.fcfg.local_epochs
        c = self._classes_per_client()
        return RoundPlan(
            # server broadcasts the full component stack; each client
            # uploads only the components it trained (its local label
            # set), and Eq. 11's grouped mean sums them in-network.
            # up_floats is the plan's *prediction* (and what the ledger
            # meters): exact under non-IID-l partitions (each client
            # holds exactly l labels); for IID shards smaller than the
            # class count it is an upper bound on the data-dependent
            # truth
            phases=(PhasePlan("ova_components", down_floats=float(d * n),
                              up_floats=float(d * c), codec=self.codec,
                              aggregatable=True),),
            flops=lambda nk: edge_device.flops_local_sgd(
                self.n_params(), nk, e) * self._classes_per_client(),
            summable=False,  # the grouped mean needs per-client masks
            scalars_per_client=n,  # class-presence masks
        )

    def client_step(self, data, rng, context=None):
        xs, ys = data
        n = self.model.n_classes
        mask = np.zeros(n, np.float32)
        client_comp = self.model.components  # start from server components
        losses = []
        for c in np.unique(ys):
            c = int(c)
            mask[c] = 1.0
            yb = (ys == c).astype(np.int64)
            batches = fed_client.stack_batches(
                xs, yb, self.fcfg.batch_size, self.fcfg.local_epochs, rng)
            comp_c = jax.tree.map(lambda leaf, cc=c: leaf[cc],
                                  self.model.components)
            comp_new, loss = self._train_component(c, comp_c, batches)
            client_comp = jax.tree.map(
                lambda full, new, cc=c: full.at[cc].set(new),
                client_comp, comp_new)
            losses.append(float(loss))
        return (client_comp, mask), float(np.mean(losses)) if losses else float("nan")

    def _train_component(self, c, comp_c, batches):
        if self.server_opt == "fim_lbfgs":
            big = {"x": batches["x"].reshape((-1,) + batches["x"].shape[2:]),
                   "y": batches["y"].reshape(-1)}
            g, f, loss = self._grad_fim(comp_c, big)
            ost = jax.tree.map(lambda s: s[c], self.opt_state)
            comp_new, ost, _ = fim_lbfgs.update(ost, comp_c, g, f, self.ocfg)
            self.opt_state = jax.tree.map(
                lambda s, o: s.at[c].set(o), self.opt_state, ost)
            return comp_new, loss
        return self._local_sgd(comp_c, batches,
                               lr=float(self.fcfg.learning_rate))

    def compress_payload(self, payload, key, residual=None, codec=None):
        # codec the component stack only: the class-presence mask is
        # metered as scalars and must survive the wire exactly
        comp, mask = payload
        comp, residual = (codec or self.codec).roundtrip(comp, key, residual)
        return (comp, mask), residual

    def aggregate(self, payloads, weights):
        comps = [p[0] for p in payloads]
        masks = [p[1] for p in payloads]
        stacked = jax.tree.map(lambda *t: jnp.stack(t), *comps)
        return stacked, jnp.asarray(np.stack(masks))

    def server_step(self, aggregate) -> None:
        stacked, masks = aggregate
        self.model = fedova.aggregate(self.model, stacked, masks)

    def evaluate(self, x, y) -> float:
        return float(fedova.accuracy(self._apply, self.model, x, y))


register("fedova", FedOvaStrategy)


@register("fedova_lbfgs")
class FedOvaLbfgsStrategy(FedOvaStrategy):
    server_opt = "fim_lbfgs"
