"""Server-side federated orchestration (paper Sec. III-A pipeline).

``FederatedRun`` is a *generic* round driver over the pluggable
:mod:`repro.fed.strategies` registry — it never branches on the algorithm
name.  Each registered strategy declares its per-round resource footprint
(a ``RoundPlan``) and supplies client/aggregate/server steps; the driver
owns everything algorithm-independent:

  * client sampling and per-client resource allocation (optionally
    through a repro.edge AllocationPolicy, fed by the plan's predicted
    *wire* bytes and FLOPs — the policy's RoundDecision fixes each
    selected client's uplink bandwidth share and, optionally, its own
    upload codec),
  * CommLedger metering, driven once per round from the plan — the
    ledger's actuals equal the plan's prediction by construction, under
    every payload codec,
  * upload compression through the run codec (``FedConfig.compress`` ->
    repro.fed.codecs: int8 stochastic rounding, top-k / rand-k
    sparsification) including the per-client error-feedback residuals
    the sparsifiers need — keyed by true client id, so stale async
    deltas keep their correction,
  * synchronous edge finishing and buffered-async aggregation — async is
    available to any strategy whose plan marks its payload ``summable``,
  * deadline enforcement: ``Allocation.deadline_s`` is a runtime
    contract — a client whose realized finish busts its grant is cut off
    at the barrier (upload discarded whole, on-air bytes billed, the
    on-time partial cohort aggregated with re-normalized weights; async
    dispatches get per-client expiry events that hand granted spectrum
    back to the pool).

Registered algorithms: "fim_lbfgs" (Algorithm 1), "fedavg_sgd",
"fedavg_adam", "fedprox", "feddane", "fedova" / "fedova_lbfgs"
(Algorithm 2, optionally composed with the FIM-L-BFGS server step).

The run loop mimics the paper's experimental protocol: K clients,
fraction q sampled per round, E local epochs, batch size B, non-IID-l
partitions.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.configs.paper_models import CNNConfig
from repro.data.partition import noniid_partition
from repro.data.synthetic import Dataset
from repro.edge.runtime import EdgeRuntime
from repro.fed import codecs, comm, strategies
from repro.obs import trace as obs


def _tree_norm(tree) -> float:
    """L2 norm over every leaf of a pytree (error-feedback residuals)."""
    return float(np.sqrt(sum(float(jnp.vdot(leaf, leaf).real)
                             for leaf in jax.tree.leaves(tree))))


class FederatedRun:
    """Generic federated round driver: ``algorithm`` resolves through the
    strategy registry; everything per-algorithm lives in the strategy."""

    def __init__(self, model_cfg: CNNConfig, fed_cfg: FedConfig,
                 train: Dataset, test: Dataset, algorithm: str,
                 tracer=None):
        self.mcfg = model_cfg
        self.fcfg = fed_cfg
        self.train, self.test = train, test
        self.algorithm = algorithm
        # obs: spans/events/metrics/audit; the shared no-op default keeps
        # the untraced driver free (one attribute check per site)
        self.tracer = tracer if tracer is not None else obs.NULL_TRACER
        self.rng = np.random.default_rng(fed_cfg.seed)
        self.ledger = comm.CommLedger()
        self._qkey = jax.random.PRNGKey(fed_cfg.seed + 17)
        self.partition = noniid_partition(
            train.y, fed_cfg.num_clients, fed_cfg.noniid_l, train.n_classes,
            seed=fed_cfg.seed,
        )
        self.strategy = strategies.get(algorithm)(
            model_cfg, fed_cfg, train.n_classes)
        # round_plan() validates the (strategy, codec) pair: a sparsifying
        # codec on a non-summable payload raises instead of silently
        # no-opping (the old `compressible` flag's failure mode)
        self.plan = self.strategy.round_plan()
        self.codec = self.strategy.codec
        self._ef_residual: dict[int, object] = {}  # client id -> EF state
        # ---- optional resource-constrained edge simulation (repro.edge)
        self.edge: Optional[EdgeRuntime] = None
        if fed_cfg.edge is not None:
            if fed_cfg.edge.mode == "async" and not self.plan.summable:
                raise ValueError(
                    "async edge mode needs summable client payloads; "
                    f"{algorithm!r} supports sync edge simulation only")
            self.edge = EdgeRuntime(fed_cfg.edge, fed_cfg.num_clients,
                                    fed_cfg.seed, tracer=self.tracer)
            if self.edge.policy.needs_summable and not self.plan.summable:
                raise ValueError(
                    f"allocation policy {fed_cfg.edge.scheduler!r} emits "
                    "per-client sparsifying codecs, which only additive "
                    f"(summable) payloads survive; {algorithm!r} uploads "
                    "distinct models/components (summable=False)")
        self._edge_est = None
        self._decision = None           # this round's RoundDecision
        self._round_verdict = None      # its DeadlineVerdict (None = no
                                        # finite deadline this round)
        self._flops_cache: dict[int, float] = {}
        # eligible ids + per-client flops are run-constant (the partition
        # never changes); cached so a fleet-scale round stays O(cohort)
        # in python instead of O(population) list comprehensions
        self._eligible: Optional[list[int]] = None
        self._eligible_flops: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    # checkpoint/resume (repro.checkpoint.run_state): sync-mode runs
    # round-trip bit-identically — save at a round boundary, restore
    # into a freshly constructed run with the same configs
    def save(self, path: str) -> None:
        from repro.checkpoint import save_run
        save_run(path, self)

    def restore_from(self, path: str) -> "FederatedRun":
        from repro.checkpoint import load_run
        return load_run(path, self)

    # ------------------------------------------------------------------
    # convenience views into the strategy (examples/benchmarks poke these)
    @property
    def params(self):
        return getattr(self.strategy, "params", None)

    @property
    def model(self):
        return getattr(self.strategy, "model", None)

    # ------------------------------------------------------------------
    # planning: the strategy's RoundPlan feeds scheduling + estimation
    def _plan_flops(self, k: int) -> float:
        """Per-client round FLOPs (partition sizes are run-constant)."""
        if k not in self._flops_cache:
            self._flops_cache[k] = self.plan.flops(len(self.partition[k]))
        return self._flops_cache[k]

    # ------------------------------------------------------------------
    def _wire_fn(self, codec=None) -> tuple[float, float]:
        """One client's (aggregatable, non-aggregatable) upload wire
        bytes under a per-client codec override (None = the plan's
        phase codecs).  This is the single byte authority the allocation
        policy, the ledger, and the edge clock all consume — plan ==
        ledger per client, by construction."""
        agg = nonagg = 0.0
        for ph in self.plan.phases:
            if not ph.up_floats:
                continue
            wire = (codec or ph.codec).wire_bytes(ph.up_floats)
            if ph.aggregatable:
                agg += wire
            else:
                nonagg += wire
        return agg, nonagg

    def _decision_bytes(self) -> tuple[np.ndarray, np.ndarray]:
        """(total, non-aggregatable) per-client wire bytes aligned with
        the current decision's selected cohort.  Without per-client
        codec overrides every client costs the same — one wire_fn call
        instead of O(cohort)."""
        if not self._decision.heterogeneous_codecs:
            n = self._decision.n_selected
            agg0, nonagg0 = self._wire_fn(None)
            return np.full(n, agg0 + nonagg0), np.full(n, nonagg0)
        pairs = [self._wire_fn(self._decision.codec_for(i))
                 for i in self._decision.selected]
        agg = np.asarray([p[0] for p in pairs])
        nonagg = np.asarray([p[1] for p in pairs])
        return agg + nonagg, nonagg

    def sample_clients(self) -> list[int]:
        k = max(1, int(self.fcfg.participation * self.fcfg.num_clients))
        if self._eligible is None:
            self._eligible = [i for i in range(self.fcfg.num_clients)
                              if len(self.partition[i]) > 0]
            self._eligible_flops = np.asarray(
                [self._plan_flops(i) for i in self._eligible])
        eligible = self._eligible
        if self.edge is None:
            return list(self.rng.choice(eligible, size=min(k, len(eligible)),
                                        replace=False))
        flops = self._eligible_flops
        if self.edge.async_agg is not None:  # don't re-pick in-flight clients
            eligible = [i for i in eligible if i not in self.edge.busy]
            flops = np.asarray([self._plan_flops(i) for i in eligible])
        selected, est, decision = self.edge.decide(
            k, eligible, self._wire_fn, flops,
            summable=self.plan.summable, codec=self.codec)
        self._edge_est = est
        self._decision = decision
        # pin the round <-> verdict pairing at decide time, so metering
        # can never scale bytes by a different round's tx_frac
        self._round_verdict = self.edge.verdicts[-1]
        return selected

    def _meter_round(self, selected: list[int]) -> None:
        """CommLedger metering, generically from the plan: the ledger's
        actuals are the plan's predictions by construction — also under
        per-client codec overrides from the allocation policy, where
        each client is billed its own wire size.  An empty cohort still
        counts as a round but bills nothing — no uploads, no Gram scalar
        exchange (the server step is skipped too).

        Deadline drops truncate billing: a client cut off at the barrier
        is billed only the ``tx_frac`` of its upload that was on the air
        before the cutoff (its payload never lands), and the Gram scalar
        exchange covers only the clients whose uploads did land — so
        ledger ≤ plan, with equality iff nobody was dropped.

        With a tracer attached, every ledger delta is mirrored into the
        ``bytes_wire_total`` counter (direction × topology × codec ×
        phase labels, from the ledger's own return values — never
        re-derived), and every upload adds a per-(round, client, phase)
        planned-vs-billed row to the :class:`~repro.obs.metrics.PlanAudit`
        — the plan == ledger invariant as a runtime audit."""
        n_selected = len(selected)
        if n_selected == 0:
            self.ledger.end_round()
            return
        tr = self.tracer
        rid = self.ledger.rounds        # 0-based: end_round not called yet
        hetero = (self._decision is not None
                  and self._decision.heterogeneous_codecs)
        verdict = self._round_verdict
        frac = {}
        frac_arr = None
        if verdict is not None and verdict.any_dropped:
            frac = {int(c): float(f)
                    for c, f in zip(verdict.clients, verdict.tx_frac,
                                    strict=True)
                    if f < 1.0}
            # aligned fast path: on the edge sync path the verdict judges
            # exactly the selected cohort in order, so tx_frac is already
            # the per-client byte fraction — no dict lookups per client
            if np.array_equal(verdict.clients, np.asarray(selected)):
                frac_arr = verdict.tx_frac
            else:
                frac_arr = np.asarray([frac.get(int(i), 1.0)
                                       for i in selected])
        for ph in self.plan.phases:
            if ph.down_floats:
                # every selected client received the broadcast, including
                # the ones later cut off on the uplink
                added = self.ledger.broadcast(ph.down_floats, n_selected)
                if tr.enabled:
                    tr.metrics.counter("bytes_wire_total").inc(
                        added, direction="down", topology="shared",
                        phase=ph.name, codec="none")
            if not ph.up_floats:
                continue
            if hetero:
                planned = [(self._decision.codec_for(i) or ph.codec)
                           .wire_bytes(ph.up_floats) for i in selected]
                billed = [w * frac.get(int(i), 1.0)
                          for w, i in zip(planned, selected, strict=True)]
                d_star, d_tree = self.ledger.upload_per_client(
                    billed, aggregatable=ph.aggregatable)
                codec_label = "per_client"
            elif frac:
                # uniform codec + deadline drops: bill tx_frac of the
                # uniform wire size as one array op (same float ops as
                # the per-client list path — w · frac elementwise, then
                # upload_per_client's shared numpy reduction)
                w_uniform = ph.wire_up_bytes()
                planned = np.full(n_selected, w_uniform)
                billed = planned * frac_arr
                d_star, d_tree = self.ledger.upload_per_client(
                    billed, aggregatable=ph.aggregatable)
                codec_label = ph.codec.spec()
            else:
                w_uniform = ph.wire_up_bytes()
                planned = billed = [w_uniform] * n_selected
                d_star, d_tree = self.ledger.upload(
                    ph.up_floats, n_selected, aggregatable=ph.aggregatable,
                    wire_bytes=w_uniform)
                codec_label = ph.codec.spec()
            if tr.enabled:
                c = tr.metrics.counter("bytes_wire_total")
                c.inc(d_star, direction="up", topology="star",
                      phase=ph.name, codec=codec_label)
                c.inc(d_tree, direction="up", topology="tree",
                      phase=ph.name, codec=codec_label)
                for i, p, b in zip(selected, planned, billed, strict=True):
                    tr.audit.add(rid, int(i), ph.name, p, b)
        n_landed = n_selected - (0 if self._decision is None
                                 else self._decision.n_dropped)
        n_scalars = (self.plan.round_scalars
                     + self.plan.scalars_per_client * n_landed)
        if n_scalars and n_landed:
            added = self.ledger.scalars(n_scalars)
            if tr.enabled:
                tr.metrics.counter("bytes_wire_total").inc(
                    added, direction="scalar", topology="shared",
                    phase="gram", codec="none")
        self.ledger.end_round()

    def _edge_sync_finish(self, info: dict) -> dict:
        if self.edge is not None and self.edge.async_agg is None:
            # the plan's aggregatable flags say which uploads sum in the
            # network (gradients/FIM/OVA components) and which must reach
            # the root individually (local models); mixed plans (FedDANE)
            # carve out the non-aggregatable share.  Bytes are per-client
            # arrays so heterogeneous codecs cost each uplink correctly.
            up, nonagg = self._decision_bytes()
            rec = self.edge.finish_round_sync(
                self._edge_est, up, self.plan.downlink_bytes(),
                nonagg_bytes=nonagg)
            info.update(wall_s=rec["wall_s"], sim_time_s=rec["clock_s"],
                        energy_j=rec["energy_j"])
            if "barrier_s" in rec:
                info["barrier_s"] = rec["barrier_s"]
        return info

    def _client_data(self, k: int):
        idx = self.partition[k]
        return self.train.x[idx], self.train.y[idx]

    # ------------------------------------------------------------------
    def round(self) -> dict:
        """One generic federated round: meter from the plan, run the
        optional cohort pre-phase, collect client payloads (round-tripped
        through the run codec, with per-client error feedback), then
        either dispatch into the async buffer or aggregate synchronously.

        An empty cohort (an exclusionary scheduler, e.g. energy_threshold,
        can reject everyone) is recorded as ``cohort=0`` with no ``loss``
        entry and the server step skipped — never an np.mean([]) NaN.
        A cohort whose every client busted its deadline is the same
        empty-cohort round, except the partial uploads are billed and the
        clock/batteries advance.

        Deadline enforcement: clients the runtime cut off at the barrier
        (``decision.dropped``) never land — their client step is not run
        (a hard drop: no partial deltas, no error-feedback update), the
        server aggregates the on-time partial cohort with re-normalized
        n_k weights, and the ledger bills only their on-air bytes."""
        selected = self.sample_clients()
        n_dropped = (0 if self._decision is None
                     else self._decision.n_dropped)
        # survivors preserves selection order on both decision types, so
        # this equals filtering `selected` by the dropped set
        landed = (selected if not n_dropped
                  else self._decision.survivors)
        self._meter_round(selected)
        datas = [self._client_data(i) for i in landed]
        context = self.strategy.round_context(datas, self.rng)
        payloads, weights, losses = [], [], []
        for j, (cid, data) in enumerate(zip(landed, datas, strict=True)):
            payload, loss = self.strategy.client_step(
                data, self.rng, None if context is None else context[j])
            # the allocation policy may hand this client its own wire
            # format (adaptive_codec); default is the run codec
            codec = self.codec
            if self._decision is not None:
                codec = self._decision.codec_for(cid) or codec
            if not codec.identity:
                self._qkey, sub = jax.random.split(self._qkey)
                if self.tracer.enabled:
                    # wall-clock encode cost + achieved ratio live in the
                    # metrics registry only — never on the sim timeline,
                    # so traced replays stay deterministic
                    t0 = time.perf_counter()  # repro: allow[RPL001]
                    payload, res = self.strategy.compress_payload(
                        payload, sub, self._ef_residual.get(cid),
                        codec=codec)
                    payload = jax.block_until_ready(payload)
                    m = self.tracer.metrics
                    m.histogram("codec_encode_s").observe(
                        time.perf_counter() - t0,  # repro: allow[RPL001]
                        codec=codec.spec())
                    n_up = sum(ph.up_floats for ph in self.plan.phases)
                    m.gauge("codec_ratio").set(
                        codecs.achieved_ratio(codec, n_up),
                        codec=codec.spec())
                    if res is not None:
                        m.gauge("ef_residual_norm").set(_tree_norm(res),
                                                        client=int(cid))
                else:
                    payload, res = self.strategy.compress_payload(
                        payload, sub, self._ef_residual.get(cid),
                        codec=codec)
                if res is not None:
                    self._ef_residual[cid] = res
            payloads.append(payload)
            weights.append(len(data[0]))
            losses.append(loss)
        info = {"cohort": len(landed)}
        if n_dropped:
            info["dropped"] = n_dropped
        if losses:
            info["loss"] = float(np.mean(losses))
        if self.edge is not None and self.edge.async_agg is not None:
            # buffered async: dispatch this cohort, aggregate whatever
            # buffer of (possibly stale) results arrives first
            self.edge.dispatch_async(self._edge_est, weights, payloads,
                                     self.plan.downlink_bytes())
            entries, w_st = self.edge.pop_async_buffer()
            if entries:
                agg = self.strategy.aggregate(
                    [e.payload for e in entries],
                    jnp.asarray(w_st, jnp.float32))
                self.strategy.server_step(agg)
            rec = self.edge.history[-1]
            info.update(wall_s=rec["wall_s"], sim_time_s=rec["clock_s"],
                        energy_j=rec["energy_j"], aggregated=len(entries))
            return info
        if payloads:
            agg = self.strategy.aggregate(
                payloads, jnp.asarray(weights, jnp.float32))
            self.strategy.server_step(agg)
        return self._edge_sync_finish(info)

    # ------------------------------------------------------------------
    def evaluate(self, max_examples: int = 2000) -> float:
        x = jnp.asarray(self.test.x[:max_examples])
        y = jnp.asarray(self.test.y[:max_examples])
        return self.strategy.evaluate(x, y)

    def run(self, rounds: Optional[int] = None, eval_every: int = 5,
            target_accuracy: Optional[float] = None, verbose: bool = False):
        """Drive ``rounds`` federated rounds, evaluating every
        ``eval_every``.  Per-round progress goes through the tracer's
        structured log (``log_round``): with the default NULL_TRACER the
        record is rendered to stdout when ``verbose`` (byte-compatible
        with the old progress print); a real ``Tracer`` additionally
        keeps every record for export."""
        rounds = rounds or self.fcfg.rounds
        history = []
        for t in range(rounds):
            info = self.round()
            info["round"] = t + 1
            is_eval = (t + 1) % eval_every == 0 or t == rounds - 1
            if is_eval:
                info["accuracy"] = self.evaluate()
            self.tracer.log_round(info, render=verbose and is_eval)
            history.append(info)
            if (is_eval and target_accuracy
                    and info["accuracy"] >= target_accuracy):
                return history
        return history
