"""Server-side federated orchestration (paper Sec. III-A pipeline).

Implements every training scheme the paper evaluates:
  * "fim_lbfgs"   — Algorithm 1 (the paper's optimizer)
  * "fedavg_sgd"  — FedAvg with local SGD [McMahan et al.]
  * "fedavg_adam" — FedAvg with a server-side Adam on the aggregated
                    pseudo-gradient (FedOpt reading of "FedAvg-based Adam")
  * "feddane"     — FedDANE two-phase Newton-type rounds [Li et al.]
  * "fedova"      — Algorithm 2 (OVA components + grouped aggregation),
                    optionally driven by the FIM-L-BFGS server step
                    ("fedova_lbfgs"), demonstrating the paper's claim that
                    the two contributions compose.

The run loop mimics the paper's experimental protocol: K clients, fraction
q sampled per round, E local epochs, batch size B, non-IID-l partitions.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.configs.paper_models import CNNConfig
from repro.core import aggregation, baselines, fedova, fim_lbfgs
from repro.edge import device as edge_device
from repro.edge.runtime import EdgeRuntime
from repro.fed import comm
from repro.data.partition import noniid_partition
from repro.data.synthetic import Dataset
from repro.fed import client as fed_client
from repro.models import cnn


class FederatedRun:
    def __init__(self, model_cfg: CNNConfig, fed_cfg: FedConfig,
                 train: Dataset, test: Dataset, algorithm: str):
        self.mcfg = model_cfg
        self.fcfg = fed_cfg
        self.train, self.test = train, test
        self.algorithm = algorithm
        self.rng = np.random.default_rng(fed_cfg.seed)
        self.ledger = comm.CommLedger()
        self.compress = getattr(fed_cfg, "compress", "none")
        self._qkey = jax.random.PRNGKey(fed_cfg.seed + 17)
        self.partition = noniid_partition(
            train.y, fed_cfg.num_clients, fed_cfg.noniid_l, train.n_classes,
            seed=fed_cfg.seed,
        )
        key = jax.random.PRNGKey(fed_cfg.seed)
        self.is_ova = algorithm.startswith("fedova")
        if self.is_ova:
            bcfg = model_cfg.binary()
            self.bcfg = bcfg
            self.model = fedova.OvaModel(
                components=jax.vmap(lambda k: cnn.init(bcfg, k)[0])(
                    jax.random.split(key, train.n_classes)),
                n_classes=train.n_classes,
            )
            self._binary_loss = lambda p, b: cnn.binary_loss(p, bcfg, b)
            self._local_sgd = fed_client.make_local_sgd_fn(self._binary_loss)
            self._apply = jax.jit(lambda p, x: cnn.apply(p, bcfg, x))
            if algorithm == "fedova_lbfgs":
                ocfg = fim_lbfgs.FimLbfgsConfig(
                    learning_rate=fed_cfg.second_order_lr, m=fed_cfg.lbfgs_m,
                    damping=fed_cfg.fim_damping, fim_ema=fed_cfg.fim_ema,
                    max_step_norm=fed_cfg.max_step_norm)
                self.ocfg = ocfg
                one = jax.tree.map(lambda l: l[0], self.model.components)
                self.opt_state = jax.vmap(lambda _: fim_lbfgs.init(one, ocfg))(
                    jnp.arange(train.n_classes))
                self._grad_fim = fed_client.make_grad_fim_fn(
                    self._binary_loss, cnn.per_example_loss_fn(bcfg, binary=True),
                    fed_cfg.fim_mode if hasattr(fed_cfg, "fim_mode") else "per_example")
        else:
            self.params, _ = cnn.init(model_cfg, key)
            self._loss = lambda p, b: cnn.softmax_loss(p, model_cfg, b)
            self._local_sgd = fed_client.make_local_sgd_fn(self._loss)
            self._local_adam = fed_client.make_local_adam_fn(self._loss)
            self._dane = fed_client.make_feddane_fn(self._loss)
            self._grad_fim = fed_client.make_grad_fim_fn(
                self._loss, cnn.per_example_loss_fn(model_cfg), "per_example")
            self.opt_state, self._opt_update = baselines.make(
                "fim_lbfgs" if algorithm == "fim_lbfgs" else "fedavg_sgd",
                self.params, fed_cfg)
        self._eval = jax.jit(lambda p, x, y: cnn.accuracy(p, model_cfg, x, y))
        # ---- optional resource-constrained edge simulation (repro.edge)
        edge_cfg = getattr(fed_cfg, "edge", None)
        self.edge: Optional[EdgeRuntime] = None
        if edge_cfg is not None:
            if edge_cfg.mode == "async" and (
                    self.is_ova or algorithm == "feddane"):
                raise ValueError(
                    "async edge mode needs summable client payloads; "
                    f"{algorithm!r} supports sync edge simulation only")
            self.edge = EdgeRuntime(edge_cfg, fed_cfg.num_clients,
                                    fed_cfg.seed)
        self._edge_est = None
        self._n_params_cache: Optional[int] = None
        self._flops_cache: dict[int, float] = {}

    # ------------------------------------------------------------------
    # edge planning: payload bytes + client FLOPs per round, per algorithm
    # (parameter counts and partition sizes are run-constant -> cached)
    def _n_params(self) -> int:
        if self._n_params_cache is None:
            if self.is_ova:
                one = jax.tree.map(lambda l: l[0], self.model.components)
                self._n_params_cache = comm.tree_n_floats(one)
            else:
                self._n_params_cache = comm.tree_n_floats(self.params)
        return self._n_params_cache

    def _ova_classes_per_client(self) -> int:
        n_cls = self.train.n_classes
        return min(self.fcfg.noniid_l or n_cls, n_cls)

    def _plan_upload_bytes(self) -> float:
        """Predicted per-client upload bytes per round (matches the ledger)."""
        d = self._n_params()
        per_el = comm.BYTES_INT8 if self.compress == "int8" else comm.BYTES_F32
        if self.algorithm == "fim_lbfgs":
            return 2.0 * d * per_el                 # ∇F_k and Γ_k
        if self.algorithm == "feddane":
            return 2.0 * d * comm.BYTES_F32         # gradient + model phases
        if self.is_ova:
            return float(d * self._ova_classes_per_client() * comm.BYTES_F32)
        return float(d * comm.BYTES_F32)            # local model

    def _plan_downlink_bytes(self) -> float:
        d = self._n_params()
        if self.is_ova:
            return float(d * self.train.n_classes * comm.BYTES_F32)
        if self.algorithm == "feddane":
            return 2.0 * d * comm.BYTES_F32         # ω_t then global gradient
        return float(d * comm.BYTES_F32)

    def _plan_flops(self, k: int) -> float:
        if k in self._flops_cache:
            return self._flops_cache[k]
        self._flops_cache[k] = self._plan_flops_uncached(k)
        return self._flops_cache[k]

    def _plan_flops_uncached(self, k: int) -> float:
        n = len(self.partition[k])
        p = self._n_params()
        e = self.fcfg.local_epochs
        if self.algorithm == "fim_lbfgs":
            return edge_device.flops_grad_fim(p, n)
        if self.algorithm == "feddane":
            return (edge_device.flops_grad_fim(p, n)
                    + edge_device.flops_local_sgd(p, n, e))
        if self.is_ova:
            return (edge_device.flops_local_sgd(p, n, e)
                    * self._ova_classes_per_client())
        return edge_device.flops_local_sgd(p, n, e)

    # ------------------------------------------------------------------
    def sample_clients(self) -> list[int]:
        k = max(1, int(self.fcfg.participation * self.fcfg.num_clients))
        eligible = [i for i in range(self.fcfg.num_clients)
                    if len(self.partition[i]) > 0]
        if self.edge is None:
            return list(self.rng.choice(eligible, size=min(k, len(eligible)),
                                        replace=False))
        if self.edge.async_agg is not None:  # don't re-pick in-flight clients
            eligible = [i for i in eligible if i not in self.edge.busy]
        flops = np.asarray([self._plan_flops(i) for i in eligible])
        selected, est = self.edge.select(
            k, eligible, self._plan_upload_bytes(), flops)
        self._edge_est = est
        return selected

    def _edge_sync_finish(self, info: dict) -> dict:
        if self.edge is not None and self.edge.async_agg is None:
            # gradient/FIM (and per-class OVA component) uploads sum in the
            # network; FedAvg local-model uploads do not; FedDANE is half
            # and half (phase-1 gradients sum, phase-2 models do not —
            # matching the ledger's aggregatable flags above)
            aggregatable = self.algorithm == "fim_lbfgs" or self.is_ova
            nonagg = None
            if self.algorithm == "feddane":
                nonagg = self._n_params() * comm.BYTES_F32  # the model phase
            rec = self.edge.finish_round_sync(
                self._edge_est, self._plan_upload_bytes(),
                self._plan_downlink_bytes(), aggregatable=aggregatable,
                nonagg_bytes=nonagg)
            info.update(wall_s=rec["wall_s"], sim_time_s=rec["clock_s"],
                        energy_j=rec["energy_j"])
        return info

    def _client_data(self, k: int):
        idx = self.partition[k]
        return self.train.x[idx], self.train.y[idx]

    # ------------------------------------------------------------------
    def round(self) -> dict:
        selected = self.sample_clients()
        if self.is_ova:
            return self._round_fedova(selected)
        if self.algorithm == "fim_lbfgs":
            return self._round_fim_lbfgs(selected)
        if self.algorithm == "feddane":
            return self._round_feddane(selected)
        return self._round_fedavg(selected)

    def _round_fim_lbfgs(self, selected) -> dict:
        grads, fims, weights, losses = [], [], [], []
        d = comm.tree_n_floats(self.params)
        self.ledger.broadcast(d, len(selected))          # send ω_t
        for k in selected:
            xs, ys = self._client_data(k)
            # Full local gradient/Fisher (the ERM F_k over D_k, as in
            # DANE/GIANT); stochastic batches are exercised by the
            # LLM-scale path where full data is impossible.
            batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
            g, f, l = self._grad_fim(self.params, batch)
            if self.compress == "int8":
                self._qkey, k1, k2 = jax.random.split(self._qkey, 3)
                g = comm.roundtrip(g, k1)
                f = jax.tree.map(jnp.abs, comm.roundtrip(f, k2))
            grads.append(g); fims.append(f); weights.append(len(xs))
            losses.append(float(l))
        per_el = comm.BYTES_INT8 if self.compress == "int8" else comm.BYTES_F32
        self.ledger.upload(d, len(selected), per_el)     # ∇F_k uploads
        self.ledger.upload(d, len(selected), per_el)     # Γ_k uploads
        m = self.fcfg.lbfgs_m
        self.ledger.scalars((2 * m + 1) ** 2)            # Gram exchange (m²)
        self.ledger.end_round()
        info = {"loss": float(np.mean(losses)) if losses else float("nan")}
        if self.edge is not None and self.edge.async_agg is not None:
            # buffered async: dispatch this cohort, aggregate whatever
            # buffer of (possibly stale) results arrives first
            self.edge.dispatch_async(self._edge_est, weights,
                                     list(zip(grads, fims)),
                                     self._plan_downlink_bytes())
            entries, w_st = self.edge.pop_async_buffer()
            if entries:
                wj = jnp.asarray(w_st, jnp.float32)
                grad = aggregation.weighted_mean(
                    jax.tree.map(lambda *t: jnp.stack(t),
                                 *[e.payload[0] for e in entries]), wj)
                fimd = aggregation.weighted_mean(
                    jax.tree.map(lambda *t: jnp.stack(t),
                                 *[e.payload[1] for e in entries]), wj)
                self.params, self.opt_state, _ = self._opt_update(
                    self.opt_state, self.params, grad, fimd)
            rec = self.edge.history[-1]
            info.update(wall_s=rec["wall_s"], sim_time_s=rec["clock_s"],
                        energy_j=rec["energy_j"], aggregated=len(entries))
            return info
        if grads:
            w = jnp.asarray(weights, jnp.float32)
            grad = aggregation.weighted_mean(
                jax.tree.map(lambda *t: jnp.stack(t), *grads), w)
            fimd = aggregation.weighted_mean(
                jax.tree.map(lambda *t: jnp.stack(t), *fims), w)
            self.params, self.opt_state, stats = self._opt_update(
                self.opt_state, self.params, grad, fimd)
        return self._edge_sync_finish(info)

    def _round_fedavg(self, selected) -> dict:
        results, weights, losses = [], [], []
        d = comm.tree_n_floats(self.params)
        self.ledger.broadcast(d, len(selected))
        # FedAvg-type uploads are NOT tree-aggregatable with weights alone
        # in the paper's accounting (server receives k local models): the
        # O(kd) of Theorem 3's comparison.
        self.ledger.upload(d, len(selected), aggregatable=False)
        self.ledger.end_round()
        for k in selected:
            xs, ys = self._client_data(k)
            batches = fed_client.stack_batches(
                xs, ys, self.fcfg.batch_size, self.fcfg.local_epochs, self.rng)
            if self.algorithm == "fedavg_adam":
                # Table II's "FedAvg-based Adam": clients run local Adam,
                # server averages (Adam lr convention: ~10x smaller).
                p, l = self._local_adam(self.params, batches,
                                        lr=float(self.fcfg.learning_rate) * 0.1)
            else:
                p, l = self._local_sgd(self.params, batches,
                                       lr=float(self.fcfg.learning_rate))
            results.append(p); weights.append(len(xs)); losses.append(float(l))
        info = {"loss": float(np.mean(losses)) if losses else float("nan")}
        if self.edge is not None and self.edge.async_agg is not None:
            # async FedAvg aggregates model *deltas* so a stale update is a
            # (discounted) correction to the current params, not a pull
            # back toward the stale starting point
            deltas = [jax.tree.map(lambda a, b: a - b, p, self.params)
                      for p in results]
            self.edge.dispatch_async(self._edge_est, weights, deltas,
                                     self._plan_downlink_bytes())
            entries, w_st = self.edge.pop_async_buffer()
            if entries:
                wj = jnp.asarray(w_st, jnp.float32)
                delta = aggregation.weighted_mean(
                    jax.tree.map(lambda *t: jnp.stack(t),
                                 *[e.payload for e in entries]), wj)
                self.params = jax.tree.map(lambda p, dl: p + dl,
                                           self.params, delta)
            rec = self.edge.history[-1]
            info.update(wall_s=rec["wall_s"], sim_time_s=rec["clock_s"],
                        energy_j=rec["energy_j"], aggregated=len(entries))
            return info
        if results:
            w = jnp.asarray(weights, jnp.float32)
            stacked = jax.tree.map(lambda *t: jnp.stack(t), *results)
            self.params = aggregation.weighted_mean(stacked, w)
        return self._edge_sync_finish(info)

    def _round_feddane(self, selected) -> dict:
        if not selected:
            self.ledger.end_round()  # empty rounds still count, as in
            return self._edge_sync_finish({"loss": float("nan")})  # fedavg
        d = comm.tree_n_floats(self.params)
        # phase 1: broadcast w_t, clients upload gradients (aggregatable)
        self.ledger.broadcast(d, len(selected))
        grads, weights = [], []
        for k in selected:
            xs, ys = self._client_data(k)
            batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}
            g, _, _ = self._grad_fim(self.params, batch)
            grads.append(g); weights.append(len(xs))
        self.ledger.upload(d, len(selected))
        w = jnp.asarray(weights, jnp.float32)
        stacked_g = jax.tree.map(lambda *t: jnp.stack(t), *grads)
        global_grad = aggregation.weighted_mean(stacked_g, w)
        # phase 2: broadcast the global gradient, clients run corrected
        # inner solves and upload their local models (NOT aggregatable:
        # the server averages k distinct iterates — FedDANE's O(2kd))
        self.ledger.broadcast(d, len(selected))
        results, losses = [], []
        for j, k in enumerate(selected):
            xs, ys = self._client_data(k)
            batches = fed_client.stack_batches(
                xs, ys, self.fcfg.batch_size, self.fcfg.local_epochs, self.rng)
            g0 = jax.tree.map(lambda t: t[j], stacked_g)
            p, l = self._dane(self.params, batches, global_grad, g0,
                              lr=float(self.fcfg.learning_rate), mu=0.1)
            results.append(p); losses.append(float(l))
        self.ledger.upload(d, len(selected), aggregatable=False)
        self.ledger.end_round()
        stacked = jax.tree.map(lambda *t: jnp.stack(t), *results)
        self.params = aggregation.weighted_mean(stacked, w)
        return self._edge_sync_finish({"loss": float(np.mean(losses))})

    def _round_fedova(self, selected) -> dict:
        n = self.model.n_classes
        d_comp = self._n_params()              # one binary component
        # server broadcasts the full OVA component stack to each client
        self.ledger.broadcast(d_comp * n, len(selected))
        comps, masks, losses = [], [], []
        for k in selected:
            xs, ys = self._client_data(k)
            mask = np.zeros(n, np.float32)
            client_comp = self.model.components  # start from server components
            for c in np.unique(ys):
                c = int(c)
                mask[c] = 1.0
                yb = (ys == c).astype(np.int64)
                batches = fed_client.stack_batches(
                    xs, yb, self.fcfg.batch_size, self.fcfg.local_epochs, self.rng)
                comp_c = jax.tree.map(lambda l: l[c], self.model.components)
                if self.algorithm == "fedova_lbfgs":
                    big = {"x": batches["x"].reshape((-1,) + batches["x"].shape[2:]),
                           "y": batches["y"].reshape(-1)}
                    g, f, l = self._grad_fim(comp_c, big)
                    ost = jax.tree.map(lambda s: s[c], self.opt_state)
                    comp_new, ost, _ = fim_lbfgs.update(ost, comp_c, g, f, self.ocfg)
                    self.opt_state = jax.tree.map(
                        lambda s, o: s.at[c].set(o), self.opt_state, ost)
                else:
                    comp_new, l = self._local_sgd(
                        comp_c, batches, lr=float(self.fcfg.learning_rate))
                client_comp = jax.tree.map(
                    lambda full, new, cc=c: full.at[cc].set(new), client_comp, comp_new)
                losses.append(float(l))
            comps.append(client_comp)
            masks.append(mask)
        if selected:
            # each client uploads only the components it trained (its local
            # label set); the grouped aggregation (Eq. 11) is a per-class
            # weighted mean, so these uploads ARE tree-aggregatable
            mean_floats = d_comp * float(np.stack(masks).sum(1).mean())
            self.ledger.upload(mean_floats, len(selected))
            self.ledger.scalars(n * len(selected))  # class-presence masks
            stacked = jax.tree.map(lambda *t: jnp.stack(t), *comps)
            self.model = fedova.aggregate(
                self.model, stacked, jnp.asarray(np.stack(masks)))
        self.ledger.end_round()
        return self._edge_sync_finish(
            {"loss": float(np.mean(losses)) if losses else float("nan")})

    # ------------------------------------------------------------------
    def evaluate(self, max_examples: int = 2000) -> float:
        x = jnp.asarray(self.test.x[:max_examples])
        y = jnp.asarray(self.test.y[:max_examples])
        if self.is_ova:
            return float(fedova.accuracy(self._apply, self.model, x, y))
        return float(self._eval(self.params, x, y))

    def run(self, rounds: Optional[int] = None, eval_every: int = 5,
            target_accuracy: Optional[float] = None, verbose: bool = False):
        rounds = rounds or self.fcfg.rounds
        history = []
        for t in range(rounds):
            info = self.round()
            if (t + 1) % eval_every == 0 or t == rounds - 1:
                info["accuracy"] = self.evaluate()
                if verbose:
                    print(f"round {t+1:4d} loss {info['loss']:.4f} "
                          f"acc {info['accuracy']:.4f}")
                if target_accuracy and info["accuracy"] >= target_accuracy:
                    info["round"] = t + 1
                    history.append(info)
                    return history
            info["round"] = t + 1
            history.append(info)
        return history
