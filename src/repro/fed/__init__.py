from repro.fed import client, server, simulator  # noqa: F401
