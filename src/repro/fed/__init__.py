from repro.fed import client, server, simulator, strategies  # noqa: F401
