from repro.fed import client, codecs, server, simulator, strategies  # noqa: F401
