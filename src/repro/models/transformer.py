"""Decoder / encoder transformer stacks (dense, MoE, audio-encoder, VLM).

Layers are stored *stacked* (leading ``num_layers`` dim) and executed with
``lax.scan`` so the HLO — and hence 1-CPU dry-run compile time for the
512-device production mesh — is O(1) in depth.  ``jax.checkpoint`` wraps the
scanned body when ``cfg.remat`` so 4k x 256 training activations fit HBM.

The hybrid (Jamba) family lives in models/hybrid.py; pure SSM reuses the
mamba2 mixer directly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2, moe
from repro.models.layers import constrain, dense_init, embed_init, mlp_apply, mlp_init, rms_norm

LOSS_CHUNK = 512  # sequence chunk for the CE loss (bounds logits memory)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg, key):
    """Returns (params, axes) — axes is a matching pytree of logical-axis
    strings for utils/sharding.py."""
    L, d = cfg.num_layers, cfg.d_model
    dtype = cfg.activation_dtype
    keys = jax.random.split(key, 8)

    params, axes = {}, {}
    if cfg.frontend == "audio_embed":
        # stub frontend: inputs arrive as (B, S, d_model) frame embeddings;
        # a single linear adapter stands in for the conv feature projector.
        params["embed"] = dense_init(keys[0], (d, d), dtype)
        axes["embed"] = "embed,embed"
    else:
        params["embed"] = embed_init(keys[0], (cfg.vocab_size, d), dtype)
        axes["embed"] = "vocab,embed"

    layer_p, layer_a = {}, {}
    if cfg.family == "ssm":
        layer_p["mixer"], layer_a["mixer"] = mamba2.mamba_init(keys[1], cfg, stack=L)
    else:
        layer_p["attn"], layer_a["attn"] = attn.attn_init(keys[1], cfg, stack=L)
    layer_p["ln1"] = jnp.ones((L, d), dtype)
    layer_a["ln1"] = "layers,embed"
    if cfg.d_ff:
        if cfg.num_experts:
            layer_p["ffn"], layer_a["ffn"] = moe.moe_init(keys[2], cfg, stack=L)
        else:
            layer_p["ffn"], layer_a["ffn"] = mlp_init(keys[2], d, cfg.d_ff, dtype, stack=L)
        layer_p["ln2"] = jnp.ones((L, d), dtype)
        layer_a["ln2"] = "layers,embed"
    params["layers"], axes["layers"] = layer_p, layer_a

    params["final_ln"] = jnp.ones((d,), dtype)
    axes["final_ln"] = "embed"
    params["head"] = dense_init(keys[3], (d, cfg.vocab_size), dtype)
    axes["head"] = "embed,vocab"
    return params, axes


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------
def _layer_body(cfg, p, x, positions, causal):
    h = x + (
        mamba2.mamba_apply(p["mixer"], cfg, rms_norm(x, p["ln1"]))
        if cfg.family == "ssm"
        else attn.attn_apply(p["attn"], cfg, rms_norm(x, p["ln1"]), positions, causal)
    )
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff:
        z = rms_norm(h, p["ln2"])
        if cfg.num_experts:
            out, (aux, _drop) = moe.moe_apply(p["ffn"], cfg, z)
        else:
            out = mlp_apply(p["ffn"], z)
        h = h + out
    return h, aux


def embed_inputs(params, cfg, inputs):
    if cfg.frontend == "audio_embed":
        x = jnp.einsum("bsd,de->bse", inputs.astype(cfg.activation_dtype), params["embed"])
    else:
        x = jnp.take(params["embed"], inputs, axis=0)
    return constrain(x, "batch,seq,embed")


def forward(params, cfg, inputs, positions=None):
    """inputs: (B,S) int tokens, or (B,S,d) embeddings for audio.
    Returns (hidden (B,S,d), total_aux_loss)."""
    x = embed_inputs(params, cfg, inputs)
    causal = not cfg.is_encoder
    if positions is None:
        positions = jnp.arange(x.shape[1])

    def body(carry, lp):
        h, aux = carry
        h, a = _layer_body(cfg, lp, h, positions, causal)
        h = constrain(h, "batch,seq,embed")
        return (h, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = rms_norm(x, params["final_ln"])
    return x, aux


def logits_fn(params, cfg, hidden):
    out = jnp.einsum("bsd,dv->bsv", hidden, params["head"]).astype(jnp.float32)
    return constrain(out, "batch,seq,vocab")


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def _chunked_ce(params, cfg, hidden, labels, mask):
    """Cross-entropy evaluated in sequence chunks to bound logits memory."""
    B, S, d = hidden.shape
    chunk = min(LOSS_CHUNK, S)
    while S % chunk:
        chunk //= 2
    nc = S // chunk
    h = hidden.reshape(B, nc, chunk, d).swapaxes(0, 1)
    y = labels.reshape(B, nc, chunk).swapaxes(0, 1)
    m = mask.reshape(B, nc, chunk).swapaxes(0, 1)

    def step(acc, inp):
        hc, yc, mc = inp
        lg = logits_fn(params, cfg, hc)                     # (B,chunk,V) f32
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, yc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (acc[0] + nll.sum(), acc[1] + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())), (h, y, m))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, cfg, batch):
    """Next-token LM loss.  batch: {"tokens": (B,S)} (+optional mask)."""
    tokens = batch["tokens"]
    hidden, aux = forward(params, cfg, tokens)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:]), jnp.zeros_like(tokens[:, :1])], axis=1
    ).astype(jnp.float32)
    ce = _chunked_ce(params, cfg, hidden, labels, mask)
    return ce + cfg.router_aux_coef * aux, {"ce": ce, "aux": aux}


def encoder_loss(params, cfg, batch):
    """Masked-unit prediction (hubert-style): per-frame classification."""
    feats, labels = batch["features"], batch["labels"]
    hidden, aux = forward(params, cfg, feats)
    mask = batch.get("mask", jnp.ones(labels.shape, jnp.float32))
    ce = _chunked_ce(params, cfg, hidden, labels, mask)
    return ce + cfg.router_aux_coef * aux, {"ce": ce, "aux": aux}


def loss_fn(params, cfg, batch):
    return encoder_loss(params, cfg, batch) if cfg.is_encoder else lm_loss(params, cfg, batch)


# ---------------------------------------------------------------------------
# decode (serve_step)
# ---------------------------------------------------------------------------
class DecodeCache(NamedTuple):
    layer_cache: object  # stacked (L, ...) KVCache or MambaState
    pos: jax.Array


def init_cache(cfg, batch: int, context: int):
    window = min(cfg.window, context) if cfg.attn_variant == "sliding_window" else context
    L = cfg.num_layers
    def prefix(a):
        return ("layers," + a) if a else "layers"
    if cfg.family == "ssm":
        st = mamba2.state_init(cfg, batch)
        stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape).copy(), st)
        ax = jax.tree.map(prefix, mamba2.state_axes())
    else:
        kc = attn.cache_init(cfg, batch, window, cfg.activation_dtype)
        stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape).copy(), kc)
        ax = jax.tree.map(prefix, attn.cache_axes())
    return DecodeCache(stacked, jnp.zeros((), jnp.int32)), DecodeCache(ax, "")


def decode_step(params, cfg, cache: DecodeCache, token):
    """token: (B,1) int32 (or (B,1,d) audio embeds) -> (logits (B,1,V), cache)."""
    x = embed_inputs(params, cfg, token)

    def body(h, scanned):
        lp, lc = scanned
        if cfg.family == "ssm":
            out, lc2 = mamba2.mamba_decode(lp["mixer"], cfg, rms_norm(h, lp["ln1"]), lc)
        else:
            lc = lc._replace(pos=cache.pos)
            out, lc2 = attn.attn_decode(lp["attn"], cfg, rms_norm(h, lp["ln1"]), lc)
            lc2 = lc2._replace(pos=lc2.pos * 0)  # pos tracked once, at top level
        h = h + out
        if cfg.d_ff:
            z = rms_norm(h, lp["ln2"])
            if cfg.num_experts:
                out2, _ = moe.moe_apply(lp["ffn"], cfg, z)
            else:
                out2 = mlp_apply(lp["ffn"], z)
            h = h + out2
        return h, lc2

    h, new_layer_cache = jax.lax.scan(body, x, (params["layers"], cache.layer_cache))
    h = rms_norm(h, params["final_ln"])
    logits = logits_fn(params, cfg, h)
    return logits, DecodeCache(new_layer_cache, cache.pos + 1)
