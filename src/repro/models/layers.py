"""Shared model layers: norms, RoPE, SwiGLU MLP, parameter initializers.

Parameters are plain pytrees of jnp arrays.  Every init returns a matching
pytree of logical-axis strings (see utils/sharding.py); leaves with a leading
stacked-layer dimension prefix the "layers" logical axis.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.utils import sharding as shd

# ---------------------------------------------------------------------------
# Activation sharding constraint helper (no-op outside a mesh context).
# ---------------------------------------------------------------------------
_CURRENT_MESH = None


class use_mesh:
    """Context manager installing the mesh used for activation constraints."""

    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        global _CURRENT_MESH
        self._prev, _CURRENT_MESH = _CURRENT_MESH, self.mesh
        return self.mesh

    def __exit__(self, *exc):
        global _CURRENT_MESH
        _CURRENT_MESH = self._prev
        return False


def constrain(x, axes: str):
    """with_sharding_constraint by logical axes; identity when no mesh set."""
    if _CURRENT_MESH is None:
        return x
    spec = shd.spec_for(x.shape, axes, _CURRENT_MESH)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_CURRENT_MESH, spec)
    )


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype, in_axis: int = -2):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / math.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm with f32 *accumulation*, activation-dtype storage, and a
    custom VJP that keeps cotangents in the activation dtype.

    Two production reasons for not using the textbook x.astype(f32) form:
      * forward: the f32 copy of x is saved per layer by remat-under-scan
        (~10GB/device at 4k x 36L);
      * backward: a dot_general with preferred_element_type=f32 emits f32
        cotangents, which then ride every residual-stream all-reduce and
        FSDP all-gather at 2x the bytes (observed on dbrx-132b train:
        the dominant collectives were f32).
    The variance is f32-accumulated via einsum (no f32 materialization);
    the custom VJP computes the exact RMSNorm gradient with f32 per-position
    scalars and activation-dtype tensors."""
    return _rms_fwd(x, scale, eps)[0]


def _rms_stats(x, eps):
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    return jax.lax.rsqrt(var + eps)  # (...,) f32


def _rms_fwd(x, scale, eps):
    inv = _rms_stats(x, eps)
    y = (x * inv[..., None].astype(x.dtype)) * scale
    return y, (x, scale, inv)


def _rms_bwd(eps, res, g):
    x, scale, inv = res
    d = x.shape[-1]
    gs = g * scale                                             # (..., d)
    # <gs, x> per position, f32-accumulated
    dot = jnp.einsum("...d,...d->...", gs, x,
                     preferred_element_type=jnp.float32)
    coef = (inv ** 3) * dot / d                                # (...,) f32
    dx = gs * inv[..., None].astype(x.dtype) \
        - x * coef[..., None].astype(x.dtype)
    xn = x * inv[..., None].astype(x.dtype)
    dscale = jnp.sum((g * xn).astype(jnp.float32),
                     axis=tuple(range(g.ndim - 1)))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_init(key, d_model: int, d_ff: int, dtype, stack: int | None = None):
    ks = jax.random.split(key, 3)
    lead = (stack,) if stack else ()
    pre = "layers," if stack else ""
    params = {
        "wi": dense_init(ks[0], lead + (d_model, d_ff), dtype),
        "wg": dense_init(ks[1], lead + (d_model, d_ff), dtype),
        "wo": dense_init(ks[2], lead + (d_ff, d_model), dtype, in_axis=-2),
    }
    axes = {
        "wi": pre + "embed,mlp",
        "wg": pre + "embed,mlp",
        "wo": pre + "mlp,embed",
    }
    return params, axes


def mlp_apply(p, x):
    h = jnp.einsum("...d,df->...f", x, p["wi"]) * jax.nn.silu(
        jnp.einsum("...d,df->...f", x, p["wg"])
    )
    h = constrain(h, "batch,seq,mlp") if h.ndim == 3 else h
    return jnp.einsum("...f,fd->...d", h, p["wo"])
