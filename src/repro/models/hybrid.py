"""Jamba-style hybrid stack: Mamba + attention at a 1:7 ratio, MoE every
second FFN (arXiv:2403.19887).

A *period* of 8 layers is structured as three identical "mm" blocks
(mamba+dense-FFN, mamba+MoE-FFN) followed by one "ma" block
(mamba+dense-FFN, attention+MoE-FFN) — preserving Jamba's layer census
exactly (per 8 layers: 7 mamba, 1 attention, 4 MoE FFNs, 4 dense FFNs).
Periods are stacked and scanned; the inner mm blocks are a nested scan, so
HLO size is O(1) in depth.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2, moe
from repro.models.layers import constrain, dense_init, embed_init, mlp_apply, mlp_init, rms_norm
from repro.models.transformer import _chunked_ce, logits_fn

PERIOD = 8
MM_PER_PERIOD = 3


def _restack(tree, axes, P: int, inner: int):
    """(P*inner, ...) stacked leaves -> (P, inner, ...), prefixing axes."""
    return (
        jax.tree.map(lambda x: x.reshape((P, inner) + x.shape[1:]), tree),
        jax.tree.map(lambda a: "layers," + a, axes),
    )


def init_params(cfg, key):
    assert cfg.num_layers % PERIOD == 0, "jamba stack needs multiples of 8 layers"
    P = cfg.num_layers // PERIOD
    d, dtype = cfg.d_model, cfg.activation_dtype
    ks = jax.random.split(key, 16)

    def norms(stack, n):
        return jnp.ones((stack, d), dtype), "layers,embed"

    mm_p, mm_a = {}, {}
    for i, name in enumerate(("m1", "m2")):
        mp, ma = mamba2.mamba_init(ks[i], cfg, stack=P * MM_PER_PERIOD)
        mm_p[name], mm_a[name] = _restack(mp, ma, P, MM_PER_PERIOD)
    fd, fda = mlp_init(ks[2], d, cfg.d_ff, dtype, stack=P * MM_PER_PERIOD)
    mm_p["ffn_d"], mm_a["ffn_d"] = _restack(fd, fda, P, MM_PER_PERIOD)
    fe, fea = moe.moe_init(ks[3], cfg, stack=P * MM_PER_PERIOD)
    mm_p["ffn_e"], mm_a["ffn_e"] = _restack(fe, fea, P, MM_PER_PERIOD)
    for n in ("ln_m1", "ln_f1", "ln_m2", "ln_f2"):
        mm_p[n] = jnp.ones((P, MM_PER_PERIOD, d), dtype)
        mm_a[n] = "layers,layers,embed"

    ma_p, ma_a = {}, {}
    ma_p["m"], ma_a["m"] = mamba2.mamba_init(ks[4], cfg, stack=P)
    ma_p["ffn_d"], ma_a["ffn_d"] = mlp_init(ks[5], d, cfg.d_ff, dtype, stack=P)
    ma_p["attn"], ma_a["attn"] = attn.attn_init(ks[6], cfg, stack=P)
    ma_p["ffn_e"], ma_a["ffn_e"] = moe.moe_init(ks[7], cfg, stack=P)
    for n in ("ln_m", "ln_f1", "ln_a", "ln_f2"):
        ma_p[n] = jnp.ones((P, d), dtype)
        ma_a[n] = "layers,embed"

    params = {
        "embed": embed_init(ks[8], (cfg.vocab_size, d), dtype),
        "mm": mm_p,
        "ma": ma_p,
        "final_ln": jnp.ones((d,), dtype),
        "head": dense_init(ks[9], (d, cfg.vocab_size), dtype),
    }
    axes = {
        "embed": "vocab,embed",
        "mm": mm_a,
        "ma": ma_a,
        "final_ln": "embed",
        "head": "embed,vocab",
    }
    return params, axes


def _mm_block(cfg, p, h, aux):
    h = h + mamba2.mamba_apply(p["m1"], cfg, rms_norm(h, p["ln_m1"]))
    h = h + mlp_apply(p["ffn_d"], rms_norm(h, p["ln_f1"]))
    h = h + mamba2.mamba_apply(p["m2"], cfg, rms_norm(h, p["ln_m2"]))
    out, (a, _) = moe.moe_apply(p["ffn_e"], cfg, rms_norm(h, p["ln_f2"]))
    return h + out, aux + a


def _ma_block(cfg, p, h, aux, positions):
    h = h + mamba2.mamba_apply(p["m"], cfg, rms_norm(h, p["ln_m"]))
    h = h + mlp_apply(p["ffn_d"], rms_norm(h, p["ln_f1"]))
    h = h + attn.attn_apply(p["attn"], cfg, rms_norm(h, p["ln_a"]), positions, True)
    out, (a, _) = moe.moe_apply(p["ffn_e"], cfg, rms_norm(h, p["ln_f2"]))
    return h + out, aux + a


def forward(params, cfg, tokens, positions=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "batch,seq,embed")
    if positions is None:
        positions = jnp.arange(x.shape[1])

    def period_body(carry, scanned):
        h, aux = carry
        mm_p, ma_p = scanned

        def mm_body(c, mp):
            hh, aa = c
            hh, aa = _mm_block(cfg, mp, hh, aa)
            return (constrain(hh, "batch,seq,embed"), aa), None

        mm_fn = jax.checkpoint(mm_body) if cfg.remat else mm_body
        (h, aux), _ = jax.lax.scan(mm_fn, (h, aux), mm_p)
        h, aux = _ma_block(cfg, ma_p, h, aux, positions)
        return (constrain(h, "batch,seq,embed"), aux), None

    body = jax.checkpoint(period_body) if cfg.remat else period_body
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["mm"], params["ma"])
    )
    return rms_norm(x, params["final_ln"]), aux


def lm_loss(params, cfg, batch):
    tokens = batch["tokens"]
    hidden, aux = forward(params, cfg, tokens)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:]), jnp.zeros_like(tokens[:, :1])], axis=1
    ).astype(jnp.float32)
    ce = _chunked_ce(params, cfg, hidden, labels, mask)
    return ce + cfg.router_aux_coef * aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
class HybridCache(NamedTuple):
    mm_m1: object   # (P, 3, ...) MambaState
    mm_m2: object
    ma_m: object    # (P, ...) MambaState
    ma_kv: object   # (P, ...) KVCache
    pos: jax.Array


def init_cache(cfg, batch: int, context: int):
    P = cfg.num_layers // PERIOD
    window = min(cfg.window, context) if cfg.attn_variant == "sliding_window" else context
    st = mamba2.state_init(cfg, batch)
    stax = mamba2.state_axes()

    def stack(x, lead):
        return jax.tree.map(lambda t: jnp.broadcast_to(t, lead + t.shape).copy(), x)

    kv = attn.cache_init(cfg, batch, window, cfg.activation_dtype)
    cache = HybridCache(
        mm_m1=stack(st, (P, MM_PER_PERIOD)),
        mm_m2=stack(st, (P, MM_PER_PERIOD)),
        ma_m=stack(st, (P,)),
        ma_kv=stack(kv, (P,)),
        pos=jnp.zeros((), jnp.int32),
    )
    pre2 = jax.tree.map(lambda a: "layers,layers," + a, stax)
    axes = HybridCache(
        mm_m1=pre2, mm_m2=pre2,
        ma_m=jax.tree.map(lambda a: "layers," + a, stax),
        ma_kv=jax.tree.map(lambda a: ("layers," + a) if a else "layers", attn.cache_axes()),
        pos="",
    )
    return cache, axes


def decode_step(params, cfg, cache: HybridCache, token):
    x = jnp.take(params["embed"], token, axis=0)  # (B,1,d)
    pos = cache.pos

    def period_body(h, scanned):
        mm_p, ma_p, c_m1, c_m2, c_mam, c_kv = scanned

        def mm_body(hh, inner):
            mp, s1, s2 = inner
            out, s1n = mamba2.mamba_decode(mp["m1"], cfg, rms_norm(hh, mp["ln_m1"]), s1)
            hh = hh + out
            hh = hh + mlp_apply(mp["ffn_d"], rms_norm(hh, mp["ln_f1"]))
            out, s2n = mamba2.mamba_decode(mp["m2"], cfg, rms_norm(hh, mp["ln_m2"]), s2)
            hh = hh + out
            out, _ = moe.moe_apply(mp["ffn_e"], cfg, rms_norm(hh, mp["ln_f2"]))
            return hh + out, (s1n, s2n)

        h, (s1n, s2n) = jax.lax.scan(mm_body, h, (mm_p, c_m1, c_m2))
        out, mam_n = mamba2.mamba_decode(ma_p["m"], cfg, rms_norm(h, ma_p["ln_m"]), c_mam)
        h = h + out
        h = h + mlp_apply(ma_p["ffn_d"], rms_norm(h, ma_p["ln_f1"]))
        c_kv = c_kv._replace(pos=pos)
        out, kv_n = attn.attn_decode(ma_p["attn"], cfg, rms_norm(h, ma_p["ln_a"]), c_kv)
        kv_n = kv_n._replace(pos=kv_n.pos * 0)
        h = h + out
        out, _ = moe.moe_apply(ma_p["ffn_e"], cfg, rms_norm(h, ma_p["ln_f2"]))
        return h + out, (s1n, s2n, mam_n, kv_n)

    h, (m1, m2, mam, kv) = jax.lax.scan(
        period_body, x,
        (params["mm"], params["ma"], cache.mm_m1, cache.mm_m2, cache.ma_m, cache.ma_kv),
    )
    h = rms_norm(h, params["final_ln"])
    logits = logits_fn(params, cfg, h)
    return logits, HybridCache(m1, m2, mam, kv, pos + 1)
