"""Attention: GQA/MQA/MHA with RoPE, q-chunked online computation, optional
sliding window, and KV-cache decode (ring buffer for sliding window).

The training/prefill path is a ``lax.scan`` over query chunks so peak score
memory is O(q_chunk * S) instead of O(S^2) — this is the pure-jnp analogue of
the Pallas flash-attention kernel in ``repro/kernels/flash_attention.py``
(which is the TPU target; XLA:CPU compiles this path for the dry run).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, constrain, dense_init, rms_norm

NEG_INF = -1e30


def attn_init(key, cfg, stack: int | None = None):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    lead = (stack,) if stack else ()
    pre = "layers," if stack else ""
    params = {
        "wq": dense_init(ks[0], lead + (d, cfg.num_heads * hd), cfg.activation_dtype),
        "wk": dense_init(ks[1], lead + (d, cfg.num_kv_heads * hd), cfg.activation_dtype),
        "wv": dense_init(ks[2], lead + (d, cfg.num_kv_heads * hd), cfg.activation_dtype),
        "wo": dense_init(ks[3], lead + (cfg.num_heads * hd, d), cfg.activation_dtype),
    }
    axes = {
        "wq": pre + "embed,qkv",
        "wk": pre + "embed,qkv",
        "wv": pre + "embed,qkv",
        "wo": pre + "qkv,embed",
    }
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones(lead + (hd,), cfg.activation_dtype)
        params["k_norm"] = jnp.ones(lead + (hd,), cfg.activation_dtype)
        axes["q_norm"] = pre + "head_dim"
        axes["k_norm"] = pre + "head_dim"
    return params, axes


def _project_qkv(p, cfg, x, positions):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch,seq,heads,head_dim")
    k = constrain(k, "batch,seq,kv_heads,head_dim")
    v = constrain(v, "batch,seq,kv_heads,head_dim")
    return q, k, v


def _chunked_attention(q, k, v, cfg, positions, causal: bool):
    """q:(B,S,H,hd) k,v:(B,S,KV,hd) -> (B,S,H,hd).

    Scans over query chunks; each step attends the chunk against the full
    (masked) key set with an explicit causal / sliding-window mask.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV  # query heads per kv head
    chunk = min(cfg.attn_q_chunk, S)
    while S % chunk:
        chunk //= 2
    nq = S // chunk
    scale = hd ** -0.5
    qs = q.reshape(B, nq, chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    pos_q = positions.reshape(nq, chunk) if positions.ndim == 1 else None
    pos_k = positions if positions.ndim == 1 else None

    def step(_, inputs):
        qc, pq = inputs  # (B,chunk,KV,G,hd), (chunk,)
        scores = jnp.einsum("bckgh,bskh->bkgcs", qc.astype(jnp.float32) * scale,
                            k.astype(jnp.float32))
        mask = jnp.ones((chunk, S), bool)
        if causal:
            mask &= pq[:, None] >= pos_k[None, :]
        if cfg.attn_variant == "sliding_window":
            mask &= pos_k[None, :] > (pq[:, None] - cfg.window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgcs,bskh->bckgh", probs, v.astype(jnp.float32))
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(step, None, (qs, pos_q))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)


def attn_apply(p, cfg, x, positions=None, causal: bool = True):
    """Full-sequence attention (train / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = _chunked_attention(q, k, v, cfg, positions, causal)
    out = out.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jax.Array        # (B, W, KV, hd)
    v: jax.Array        # (B, W, KV, hd)
    pos: jax.Array      # () int32 — absolute position of the next token


def cache_init(cfg, batch: int, window: int, dtype) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (batch, window, cfg.num_kv_heads, hd)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def cache_axes() -> KVCache:
    return KVCache(k="batch,window,kv_heads,head_dim",
                   v="batch,window,kv_heads,head_dim", pos="")


def attn_decode(p, cfg, x, cache: KVCache):
    """One-token decode. x: (B, 1, d).  Ring-buffer write for sliding window;
    for full attention the window equals the max context so the ring index is
    just the position."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    W = cache.k.shape[1]
    pos = cache.pos
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    slot = pos % W
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))

    KV = k.shape[2]
    G = cfg.num_heads // KV
    scale = hd ** -0.5
    qh = q.reshape(B, 1, KV, G, hd)
    scores = jnp.einsum("bckgh,bskh->bkgcs", qh.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))  # (B,KV,G,1,W)
    # Ring-buffer validity: after writing position `pos`, the cache holds the
    # last min(pos+1, W) positions.  Before the first wrap only slots
    # 0..pos are populated; after wrapping every slot is live.
    slots = jnp.arange(W)
    valid = jnp.where(pos >= W, jnp.ones((W,), bool), slots <= pos)
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh", probs, v.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.num_heads * hd).astype(x.dtype)
    y = jnp.einsum("bsq,qd->bsd", out, p["wo"])
    return y, KVCache(k=k, v=v, pos=pos + 1)
