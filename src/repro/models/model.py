"""Unified model API over all architecture families.

    init(cfg, key)            -> (params, axes)
    loss_fn(params, cfg, b)   -> (scalar, metrics)     [train shapes]
    prefill_fn(params, cfg,b) -> hidden/logits         [prefill shapes]
    init_cache(cfg, B, ctx)   -> (cache, cache_axes)   [decode shapes]
    decode_fn(params,cfg,c,t) -> (logits, cache)

``input_specs(cfg, shape)`` returns jax.ShapeDtypeStruct stand-ins for every
model input of the given input-shape config — the dry-run lowers against
these (no allocation).  Audio/VLM frontends are stubs: hubert receives frame
embeddings (B, S, d_model), chameleon receives pre-quantized VQ token ids.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import hybrid, transformer


def is_hybrid(cfg: ArchConfig) -> bool:
    return cfg.family == "hybrid"


def init(cfg: ArchConfig, key):
    return hybrid.init_params(cfg, key) if is_hybrid(cfg) else transformer.init_params(cfg, key)


def loss_fn(params, cfg: ArchConfig, batch):
    return hybrid.lm_loss(params, cfg, batch) if is_hybrid(cfg) else transformer.loss_fn(params, cfg, batch)


def prefill_fn(params, cfg: ArchConfig, batch):
    """Full-sequence forward returning last-position logits (prefill / encode)."""
    fwd = hybrid.forward if is_hybrid(cfg) else transformer.forward
    inputs = batch.get("tokens", batch.get("features"))
    hidden, _ = fwd(params, cfg, inputs)
    if cfg.is_encoder:  # encode: per-frame logits
        return transformer.logits_fn(params, cfg, hidden[:, -transformer.LOSS_CHUNK:])
    return transformer.logits_fn(params, cfg, hidden[:, -1:])


def init_cache(cfg: ArchConfig, batch: int, context: int):
    return hybrid.init_cache(cfg, batch, context) if is_hybrid(cfg) else transformer.init_cache(cfg, batch, context)


def decode_fn(params, cfg: ArchConfig, cache, token):
    return hybrid.decode_step(params, cfg, cache, token) if is_hybrid(cfg) else transformer.decode_step(params, cfg, cache, token)


# ---------------------------------------------------------------------------
# input specs for the dry run
# ---------------------------------------------------------------------------
def supports_shape(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(supported, reason-if-not). Encoders have no decode; full-attention
    archs run long_500k only via the sliding-window variant (handled by
    shape_variant below)."""
    if cfg.is_encoder and shape.kind == "decode":
        return False, "encoder-only: no autoregressive decode step"
    return True, ""


def shape_variant(cfg: ArchConfig, shape: ShapeConfig) -> ArchConfig:
    """Per-shape config adjustments (documented in DESIGN.md):
    - long_500k on full-attention archs -> sliding-window variant
      (sub-quadratic; SSM/hybrid keep native recurrence for their mamba
      layers, but their *attention* layers also ring-buffer at the window).
    - decode paths never remat."""
    cfg = cfg.replace(remat=shape.kind == "train" and cfg.remat)
    if shape.name == "long_500k" and cfg.family != "ssm":
        cfg = cfg.replace(attn_variant="sliding_window")
    return cfg


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """ShapeDtypeStructs for the step function's data arguments."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio_embed":
            specs = {"features": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)}
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            return specs
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    # decode: one new token against a length-S context
    if cfg.frontend == "audio_embed":
        return {"token": jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.float32)}
    return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def input_axes(cfg: ArchConfig, shape: ShapeConfig):
    """Logical axes for input_specs (batch -> data/pod)."""
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio_embed":
            ax = {"features": "batch,seq,embed"}
            if shape.kind == "train":
                ax["labels"] = "batch,seq"
            return ax
        return {"tokens": "batch,seq"}
    return {"token": "batch,seq,embed" if cfg.frontend == "audio_embed" else "batch,seq"}


def synth_batch(cfg: ArchConfig, shape: ShapeConfig, key):
    """Materialized random batch matching input_specs (for smoke tests)."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = cfg.vocab_size if name in ("tokens", "token", "labels") else 2
            out[name] = jax.random.randint(sub, s.shape, 0, max(hi, 2), s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, s.dtype)
    return out
