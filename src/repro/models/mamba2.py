"""Mamba-2 (SSD — state-space duality) mixer, chunked scan + one-step decode.

Implements the chunked SSD algorithm of Dao & Gu (arXiv:2405.21060): the
sequence is split into chunks of length Q; within a chunk the recurrence is
evaluated as a masked quadratic form (MXU-friendly), across chunks a
``lax.scan`` carries the (heads, state, head_dim) SSM state.  The scan keeps
peak memory at O(B * heads * Q^2) per step regardless of sequence length,
which is what makes the 500k-token decode/train shapes lowerable.

Decode is the dual recurrent form: state <- exp(dt*A) * state + dt * B (x) x.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import constrain, dense_init, rms_norm


def _dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_state


def mamba_init(key, cfg, stack: int | None = None):
    d = cfg.d_model
    d_in, nh, N = _dims(cfg)
    conv_ch = d_in + 2 * N  # x, B, C go through the depthwise conv
    ks = jax.random.split(key, 5)
    lead = (stack,) if stack else ()
    pre = "layers," if stack else ""
    params = {
        # order: [z (d_in), x (d_in), B (N), C (N), dt (nh)]
        "in_proj": dense_init(ks[0], lead + (d, 2 * d_in + 2 * N + nh), cfg.activation_dtype),
        "conv_w": (jax.random.normal(ks[1], lead + (cfg.ssm_conv, conv_ch)) * 0.1).astype(cfg.activation_dtype),
        "A_log": jnp.zeros(lead + (nh,), jnp.float32),
        "D": jnp.ones(lead + (nh,), jnp.float32),
        "dt_bias": jnp.zeros(lead + (nh,), jnp.float32),
        "norm": jnp.ones(lead + (d_in,), cfg.activation_dtype),
        "out_proj": dense_init(ks[2], lead + (d_in, d), cfg.activation_dtype),
    }
    axes = {
        "in_proj": pre + "embed,ssm_inner",
        "conv_w": pre + "conv,ssm_inner",
        "A_log": pre + "ssm_heads",
        "D": pre + "ssm_heads",
        "dt_bias": pre + "ssm_heads",
        "norm": pre + "ssm_inner",
        "out_proj": pre + "ssm_inner,embed",
    }
    return params, axes


def _split_proj(cfg, zxbcdt):
    d_in, nh, N = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:2 * d_in + 2 * N]
    dt = zxbcdt[..., 2 * d_in + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w):
    """Depthwise causal conv along seq. xBC: (B,S,ch); conv_w: (K,ch)."""
    K = conv_w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(K):  # K is 4: unrolled taps beat a conv op at this size.
        # Correlation convention: conv_w[K-1] multiplies the current step —
        # must match the decode window layout in mamba_decode.
        out = out + pad[:, i:i + xBC.shape[1]].astype(jnp.float32) * conv_w[i].astype(jnp.float32)
    return jax.nn.silu(out).astype(xBC.dtype)


class MambaState(NamedTuple):
    ssm: jax.Array    # (B, nh, hd, N) f32
    conv: jax.Array   # (B, K-1, conv_ch) — last K-1 conv inputs


def state_init(cfg, batch: int) -> MambaState:
    d_in, nh, N = _dims(cfg)
    return MambaState(
        ssm=jnp.zeros((batch, nh, cfg.ssm_head_dim, N), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, d_in + 2 * N), jnp.float32),
    )


def state_axes() -> MambaState:
    return MambaState(ssm="batch,ssm_heads,head_dim,ssm_state",
                      conv="batch,conv,ssm_inner")


def mamba_apply(p, cfg, x):
    """Full-sequence SSD. x: (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    d_in, nh, N = _dims(cfg)
    hd = cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    zxbcdt = jnp.einsum("bsd,dz->bsz", x, p["in_proj"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC, p["conv_w"])
    xs = xBC[..., :d_in].reshape(B, S, nh, hd)
    Bm = xBC[..., d_in:d_in + N].astype(jnp.float32)        # (B,S,N)
    Cm = xBC[..., d_in + N:].astype(jnp.float32)            # (B,S,N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                # (nh,)
    dA = dt * A                                             # (B,S,nh)

    # chunk views
    xs_c = xs.reshape(B, nc, Q, nh, hd).astype(jnp.float32)
    B_c = Bm.reshape(B, nc, Q, N)
    C_c = Cm.reshape(B, nc, Q, N)
    dt_c = dt.reshape(B, nc, Q, nh)
    dA_c = dA.reshape(B, nc, Q, nh)

    def chunk_step(state, inp):
        xs_q, B_q, C_q, dt_q, dA_q = inp   # (B,Q,nh,hd) (B,Q,N) (B,Q,N) (B,Q,nh) (B,Q,nh)
        cs = jnp.cumsum(dA_q, axis=1)                        # (B,Q,nh)
        total = cs[:, -1]                                    # (B,nh)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bqn,bhdn,bqh->bqhd", C_q, state, jnp.exp(cs))
        # intra-chunk masked quadratic form
        decay = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])  # (B,Q,Q,nh) i,j
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        decay = jnp.where(mask[None, :, :, None], decay, 0.0)
        scores = jnp.einsum("bin,bjn->bij", C_q, B_q)[..., None] * decay  # (B,Q,Q,nh)
        y_intra = jnp.einsum("bijh,bjh,bjhd->bihd", scores, dt_q, xs_q)
        # state update: decay old state across the chunk + new outer products
        carry_decay = jnp.exp(total)[:, :, None, None]
        state_new = jnp.einsum("bqh,bqh,bqhd,bqn->bhdn",
                               jnp.exp(total[:, None, :] - cs), dt_q, xs_q, B_q)
        state = state * carry_decay + state_new
        return state, (y_inter + y_intra)

    state0 = jnp.zeros((B, nh, hd, N), jnp.float32)
    def swap(t):  # scan over chunks
        return jnp.swapaxes(t, 0, 1)
    _, ys = jax.lax.scan(chunk_step, state0,
                         (swap(xs_c), swap(B_c), swap(C_c), swap(dt_c), swap(dA_c)))
    y = jnp.swapaxes(ys, 0, 1).reshape(B, S, nh, hd)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_in)
    y = rms_norm(y.astype(x.dtype), p["norm"]) * jax.nn.silu(z)
    y = constrain(y, "batch,seq,ssm_inner")
    return jnp.einsum("bsz,zd->bsd", y, p["out_proj"])


def mamba_decode(p, cfg, x, state: MambaState):
    """One-token decode. x: (B,1,d)."""
    B = x.shape[0]
    d_in, nh, N = _dims(cfg)
    hd = cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,dz->bsz", x, p["in_proj"])[:, 0]  # (B, z)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # conv over the ring of the last K inputs
    window = jnp.concatenate([state.conv, xBC[:, None].astype(jnp.float32)], axis=1)  # (B,K,ch)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(jnp.float32))
    xBC_c = jax.nn.silu(conv_out)
    xs = xBC_c[..., :d_in].reshape(B, nh, hd)
    Bm = xBC_c[..., d_in:d_in + N]
    Cm = xBC_c[..., d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                          # (B,nh)

    ssm = state.ssm * dA[:, :, None, None] + jnp.einsum(
        "bh,bhd,bn->bhdn", dt, xs, Bm)
    y = jnp.einsum("bhdn,bn->bhd", ssm, Cm) + p["D"][None, :, None] * xs
    y = y.reshape(B, d_in)
    y = rms_norm(y.astype(x.dtype), p["norm"]) * jax.nn.silu(z)
    out = jnp.einsum("bz,zd->bd", y, p["out_proj"])[:, None]
    new_state = MambaState(ssm=ssm, conv=window[:, 1:])
    return out, new_state
