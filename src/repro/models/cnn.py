"""The paper's experiment CNNs (Sec. VI-A), in plain JAX.

Used by the federated runtime for the Table II-V / Fig 3-4 reproductions:
multi-class softmax classifiers for FedAvg/FedDANE baselines and 1-logit
binary component classifiers for FedOVA.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.paper_models import CNNConfig
from repro.models.layers import dense_init


def init(cfg: CNNConfig, key, dtype=jnp.float32):
    params, axes = {}, {}
    ch_in = cfg.input_shape[-1]
    h, w = cfg.input_shape[:2]
    ks = jax.random.split(key, len(cfg.conv_channels) + len(cfg.fc_units) + 1)
    for i, ch in enumerate(cfg.conv_channels):
        params[f"conv{i}"] = {
            "w": dense_init(ks[i], (3, 3, ch_in, ch), dtype, in_axis=-2) / 3.0,
            "b": jnp.zeros((ch,), dtype),
        }
        axes[f"conv{i}"] = {"w": "conv,conv,embed,mlp", "b": "mlp"}
        ch_in = ch
        h, w = -(-h // cfg.pool[0]), -(-w // cfg.pool[1])
    feat = h * w * ch_in
    for j, units in enumerate(cfg.fc_units):
        params[f"fc{j}"] = {
            "w": dense_init(ks[len(cfg.conv_channels) + j], (feat, units), dtype),
            "b": jnp.zeros((units,), dtype),
        }
        axes[f"fc{j}"] = {"w": "embed,mlp", "b": "mlp"}
        feat = units
    params["out"] = {
        "w": dense_init(ks[-1], (feat, cfg.num_classes), dtype),
        "b": jnp.zeros((cfg.num_classes,), dtype),
    }
    axes["out"] = {"w": "embed,vocab", "b": "vocab"}
    return params, axes


def apply(params, cfg: CNNConfig, x):
    """x: (B, H, W, C) -> logits (B, num_classes)."""
    for i in range(len(cfg.conv_channels)):
        p = params[f"conv{i}"]
        x = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + p["b"]
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max,
            (1, cfg.pool[0], cfg.pool[1], 1), (1, cfg.pool[0], cfg.pool[1], 1),
            "SAME",
        )
    x = x.reshape(x.shape[0], -1)
    for j in range(len(cfg.fc_units)):
        p = params[f"fc{j}"]
        x = jax.nn.relu(x @ p["w"] + p["b"])
    p = params["out"]
    return x @ p["w"] + p["b"]


def softmax_loss(params, cfg: CNNConfig, batch):
    """Multi-class CE (FedAvg-style training)."""
    logits = apply(params, cfg, batch["x"]).astype(jnp.float32)
    labels = batch["y"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def binary_loss(params, cfg: CNNConfig, batch):
    """One-vs-all component loss: sigmoid BCE on 1-logit head.
    batch["y"] in {0,1}: membership of the component's class."""
    logits = apply(params, cfg, batch["x"]).astype(jnp.float32)[:, 0]
    y = batch["y"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def accuracy(params, cfg: CNNConfig, x, y) -> jax.Array:
    return jnp.mean(jnp.argmax(apply(params, cfg, x), axis=-1) == y)


def per_example_loss_fn(cfg: CNNConfig, binary: bool = False):
    """Single-example loss closure used by the exact per-example FIM path."""
    loss = binary_loss if binary else softmax_loss

    def f(params, x, y):
        return loss(params, cfg, {"x": x[None], "y": y[None]})

    return f
