"""Mixture-of-Experts FFN with top-k routing and capacity-factor dispatch.

Dispatch strategy (TPU-native, static shapes): tokens are processed in
groups of ``cfg.moe_group``; within a group each (token, k) assignment gets a
slot in a per-expert capacity buffer via a one-hot cumulative-sum position
(the GShard/Switch construction), but materialized through scatter/gather on
an (E, C, d) buffer instead of the (T, E, C) one-hot dispatch tensor — the
latter is O(T*E*C) memory and infeasible at 32k sequence x 128 experts.
Tokens overflowing an expert's capacity are dropped (standard capacity-factor
semantics); the load-balance auxiliary loss (Switch, Eq. 4-6) keeps the
router near-uniform so drops stay rare.

Sharding: groups ride the (pod, data) axes, experts ride the model axis.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import constrain, dense_init


def moe_init(key, cfg, stack: int | None = None):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    lead = (stack,) if stack else ()
    pre = "layers," if stack else ""
    params = {
        # router is REPLICATED ("router_experts" -> None): every expert
        # shard must compute identical routing decisions locally (the
        # expert-parallel path relies on it); it is d x E, i.e. tiny.
        "router": dense_init(ks[0], lead + (d, E), jnp.float32),
        "wi": dense_init(ks[1], lead + (E, d, ff), cfg.activation_dtype),
        "wg": dense_init(ks[2], lead + (E, d, ff), cfg.activation_dtype),
        "wo": dense_init(ks[3], lead + (E, ff, d), cfg.activation_dtype, in_axis=-2),
    }
    axes = {
        "router": pre + "embed,router_experts",
        "wi": pre + "experts,embed,expert_mlp",
        "wg": pre + "experts,embed,expert_mlp",
        "wo": pre + "experts,expert_mlp,embed",
    }
    return params, axes


def _capacity(group: int, top_k: int, num_experts: int, factor: float) -> int:
    c = math.ceil(top_k * group / num_experts * factor)
    return max(8, -(-c // 8) * 8) if group >= 64 else max(1, c)


def moe_apply(p, cfg, x):
    """x: (B, S, d) -> (out, (aux_loss, dropped)).

    Two paths:
      * expert-parallel shard_map (production): each model shard runs ONLY
        its E/shards local experts over its (model-replicated) activations
        and the combine is one token-sized psum over the model axis — no
        all-to-all, no buffer replication.  §Perf hillclimb (b): GSPMD's
        lowering of the scatter/gather dispatch all-reduced the full
        (G,E,C,d) capacity buffer per layer (4.0 TB/chip on dbrx-132b
        train_4k); constraining the buffer made it *worse* (39 TB/chip —
        hypothesis refuted, see EXPERIMENTS.md §Perf); the shard_map
        formulation reduces the MoE collective to ~tokens x d per layer,
        the same order as the dense TP all-reduce.
      * GSPMD scatter/gather fallback for CPU tests / meshes that don't
        divide the expert count.
    """
    from repro.models import layers as L

    mesh = L._CURRENT_MESH
    if mesh is not None and "model" in mesh.axis_names:
        model_size = mesh.shape["model"]
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        batch_size = 1
        for a in batch_axes:
            batch_size *= mesh.shape[a]
        # EP pays one FSDP expert-weight re-gather per layer per step; that
        # amortizes over many tokens (train/prefill) but regresses decode
        # (measured 22x on dbrx-132b decode_32k: 1 token/seq can't amortize
        # 400MB of expert gathers).  Gate by tokens-per-step, like
        # production MoE servers that switch dispatch regimes.
        enough_tokens = x.shape[0] * x.shape[1] >= 4 * cfg.moe_group
        if (cfg.num_experts % model_size == 0
                and x.shape[0] % max(batch_size, 1) == 0
                and enough_tokens):
            return _moe_apply_expert_parallel(p, cfg, x, mesh, batch_axes)
    return _moe_apply_gspmd(p, cfg, x)


def _moe_apply_expert_parallel(p, cfg, x, mesh, batch_axes):
    """shard_map expert parallelism (see moe_apply docstring)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    E = cfg.num_experts
    model_size = mesh.shape["model"]
    E_local = E // model_size
    shard_fn = getattr(jax, "shard_map", None)
    if shard_fn is None:  # pragma: no cover - older jax
        from jax.experimental.shard_map import shard_map as shard_fn

    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)

    def local_moe(xl, router, wi, wg, wo):
        # xl: (B_local, S, d); wi/wg/wo: (E_local, ...) local experts
        shard = jax.lax.axis_index("model")
        e_off = shard * E_local
        Bl, S, d = xl.shape
        N = Bl * S
        group = min(cfg.moe_group, N)
        while N % group:
            group //= 2
        G, T = N // group, group
        k = cfg.top_k
        C = _capacity(T, k, E, cfg.capacity_factor)

        xg = xl.reshape(G, T, d)
        logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)                       # (G,T,E)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

        onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)       # (G,T,k,E)
        flat = onehot.reshape(G, T * k, E)
        pos = jnp.cumsum(flat, axis=1) - flat
        slot = jnp.sum(pos * flat, axis=-1)                           # (G,T*k)
        e_flat = expert_idx.reshape(G, T * k)
        e_loc = e_flat - e_off
        mine = (e_loc >= 0) & (e_loc < E_local)
        keep = (slot < C) & mine
        e_loc_c = jnp.clip(e_loc, 0, E_local - 1)
        slot_c = jnp.where(keep, slot, C)

        x_rep = jnp.repeat(xg, k, axis=1)
        g_idx = jnp.arange(G)[:, None]
        buf = jnp.zeros((G, E_local, C + 1, d), xg.dtype)
        buf = buf.at[g_idx, e_loc_c, slot_c].add(
            x_rep * keep[..., None].astype(xg.dtype))
        buf = buf[:, :, :C]

        h = jnp.einsum("gecd,edf->gecf", buf, wi) * jax.nn.silu(
            jnp.einsum("gecd,edf->gecf", buf, wg))
        out_buf = jnp.einsum("gecf,efd->gecd", h, wo)
        out_buf = jnp.concatenate(
            [out_buf, jnp.zeros((G, E_local, 1, d), out_buf.dtype)], axis=2)
        tok = out_buf[g_idx, e_loc_c, slot_c]
        tok = tok * (gate_vals.reshape(G, T * k, 1)
                     * keep[..., None]).astype(tok.dtype)
        out = jnp.sum(tok.reshape(G, T, k, d), axis=2)
        # each shard contributed only its experts' outputs:
        out = jax.lax.psum(out, "model")

        frac = jnp.mean(onehot.sum(2).astype(jnp.float32), axis=(0, 1))
        aux = E * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))
        # dropped fraction counts capacity overflows of LOCAL experts only;
        # psum over model reassembles the global count.
        dropped = jnp.sum((mine & (slot >= C)).astype(jnp.float32))
        dropped = jax.lax.psum(dropped, "model") / (G * T * k)
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
            dropped = jax.lax.pmean(dropped, batch_axes)
        return out.reshape(Bl, S, d), aux, dropped

    out, aux, dropped = shard_fn(
        local_moe, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(bspec, None, None), P(), P()),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return out, (aux, dropped)


def _moe_apply_gspmd(p, cfg, x):
    """GSPMD scatter/gather dispatch (test / fallback path)."""
    B, S, d = x.shape
    N = B * S
    group = min(cfg.moe_group, N)
    while N % group:
        group //= 2
    G = N // group
    T = group
    E, k = cfg.num_experts, cfg.top_k
    C = _capacity(T, k, E, cfg.capacity_factor)

    xg = constrain(x.reshape(G, T, d), "expert_group,seq,embed")
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                               # (G,T,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                       # (G,T,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Slot assignment: position of each (token, k) within its expert queue,
    # computed per group (the paper-analogous "per-cohort" dispatch).
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)               # (G,T,k,E)
    flat = onehot.reshape(G, T * k, E)                                    # token-major
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat                       # (G,T*k,E)
    slot = jnp.sum(pos_in_expert * flat, axis=-1)                         # (G,T*k)
    e_flat = expert_idx.reshape(G, T * k)
    keep = slot < C
    slot_c = jnp.where(keep, slot, C)  # overflow row C is sliced off

    # Scatter tokens into the (G, E, C+1, d) expert buffer.
    x_rep = jnp.repeat(xg, k, axis=1)                                     # (G,T*k,d)
    g_idx = jnp.arange(G)[:, None]
    buf = jnp.zeros((G, E, C + 1, d), xg.dtype)
    buf = buf.at[g_idx, e_flat, slot_c].add(
        x_rep * keep[..., None].astype(xg.dtype))
    buf = constrain(buf[:, :, :C], "expert_group,experts,cap,embed")

    # Expert FFN (SwiGLU), batched over (group, expert).
    h = jnp.einsum("gecd,edf->gecf", buf, p["wi"]) * jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", buf, p["wg"]))
    h = constrain(h, "expert_group,experts,cap,expert_mlp")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["wo"])                    # (G,E,C,d)
    out_buf = constrain(out_buf, "expert_group,experts,cap,embed")

    # Gather back and combine with gates.
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((G, E, 1, d), out_buf.dtype)], axis=2)
    tok_out = out_buf[g_idx, e_flat, slot_c]                              # (G,T*k,d)
    tok_out = tok_out * (gate_vals.reshape(G, T * k, 1)
                         * keep[..., None]).astype(tok_out.dtype)
    out = jnp.sum(tok_out.reshape(G, T, k, d), axis=2)
    out = constrain(out, "expert_group,seq,embed")

    # Switch load-balance loss: fraction of tokens per expert x mean prob.
    frac = jnp.mean(onehot.sum(axis=2).astype(jnp.float32), axis=(0, 1))  # (E,)
    mean_prob = jnp.mean(probs, axis=(0, 1))                              # (E,)
    aux = E * jnp.sum(frac * mean_prob)
    dropped = jnp.mean(1.0 - keep.astype(jnp.float32))
    return out.reshape(B, S, d), (aux, dropped)
