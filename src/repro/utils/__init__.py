from repro.utils import pytree, sharding  # noqa: F401
