"""Logical-axis sharding rules (t5x style).

Every parameter / optimizer-state leaf is annotated with a logical-axis
string like ``"layers,heads,embed"`` (strings are pytree *leaves*, so the
annotation tree mirrors the parameter tree).  A rule table maps logical axis
names to mesh axis names; ``spec_for`` additionally enforces divisibility —
if a dimension does not divide by the mesh axis size we fall back to
replication for that dimension (e.g. phi4-mini's 24 query heads on a
16-way model axis).  This keeps every assigned architecture lowerable on the
production mesh without per-arch special cases.
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, tuple]

# Default logical -> mesh axis rules for the production meshes.
#   "data" axes carry the federated client cohorts (and the global batch);
#   "model" carries megatron/expert sharding.  The "pod" axis (multi-pod
#   mesh) extends the data axis — cohorts span pods.
DEFAULT_RULES: dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "clients": ("pod", "data"),
    "vocab": "model",
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv": "model",
    "experts": "model",
    "router_experts": None,   # router weights replicated (see models/moe.py)
    "expert_mlp": None,
    "expert_group": ("pod", "data"),
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "layers": None,
    "seq": None,
    "window": None,
    "cap": None,
    "conv": None,
    "history": None,  # L-BFGS (s, y) memory dimension
}


# ZeRO-1 rules for *optimizer state* (L-BFGS history, Fisher diag, moments):
# additionally shard the embed dim over the data/pod axes.  Parameters stay
# replicated across data (classic TP-within-pod + DP), but the m-deep
# history at 132B params cannot (20 x 2 x params bf16), so optimizer state
# is fully sharded; the round update all-gathers the step — standard ZeRO-1
# semantics, and the collective cost shows up honestly in the roofline.
OPT_RULES: dict[str, MeshAxes] = dict(DEFAULT_RULES, embed=("pod", "data"))

# Full FSDP rules for *parameters* of the >=100B architectures (dbrx-132b,
# qwen3-moe-235b): TP=16 alone leaves >16GB of weights per chip, so the
# embed dim of every weight additionally shards over data/pod.  XLA inserts
# the per-layer all-gather inside the scan (classic FSDP re-gather), which
# the roofline then attributes to the collective term.
PARAM_RULES_FSDP: dict[str, MeshAxes] = dict(DEFAULT_RULES, embed=("pod", "data"))


def parse_axes(axes: Optional[str]) -> tuple:
    if axes is None or axes == "":
        return ()
    return tuple(a.strip() for a in axes.split(","))


def _mesh_size(mesh: Mesh, mesh_axes: MeshAxes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    size = 1
    for a in mesh_axes:
        size *= mesh.shape[a]
    return size


def spec_for(
    shape: Sequence[int],
    axes: Optional[str],
    mesh: Mesh,
    rules: Optional[Mapping[str, MeshAxes]] = None,
) -> P:
    """PartitionSpec for ``shape`` annotated with logical ``axes``.

    Falls back to replication per-dimension when the dim size does not divide
    the mesh axis size, or when the mesh lacks the mapped axis (single-pod
    mesh has no "pod" axis).
    """
    rules = dict(DEFAULT_RULES, **(rules or {}))
    names = parse_axes(axes)
    if len(names) != len(shape):
        raise ValueError(f"axes {names} do not match shape {shape}")
    spec, used = [], set()
    for dim, name in zip(shape, names, strict=True):
        mesh_axes = rules.get(name)
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        if mesh_axes is not None:
            mesh_axes = tuple(a for a in mesh_axes if a in mesh.shape and a not in used)
            if not mesh_axes:
                mesh_axes = None
        if mesh_axes is not None:
            size = 1
            for a in mesh_axes:
                size *= mesh.shape[a]
            if size == 0 or dim % size != 0:
                mesh_axes = None
        if mesh_axes is None:
            spec.append(None)
        else:
            used.update(mesh_axes)
            spec.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    return P(*spec)


def shardings_for_tree(tree_shapes, axes_tree, mesh: Mesh, rules=None):
    """NamedSharding tree for a pytree of ShapeDtypeStruct/arrays + axis strings."""
    return jax.tree.map(
        lambda x, ax: NamedSharding(mesh, spec_for(x.shape, ax, mesh, rules)),
        tree_shapes,
        axes_tree,
    )


def specs_for_tree(tree_shapes, axes_tree, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda x, ax: spec_for(x.shape, ax, mesh, rules), tree_shapes, axes_tree
    )


def data_spec(mesh: Mesh, *trailing: Optional[str]) -> P:
    """Batch-leading PartitionSpec: batch over (pod, data), rest replicated."""
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return P(batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None), *trailing)
