"""Pytree vector-space helpers.

The optimizer layers (core/lbfgs.py, core/fim_lbfgs.py) treat model
parameters as a single d-dimensional vector that happens to be stored as a
pytree of sharded arrays.  These helpers implement the vector-space algebra
(dot, axpy, scale, norm) leaf-wise so that sharding is preserved and the only
cross-device traffic a dot product induces is a scalar all-reduce — the
communication structure the paper's Theorem 3 counts as O(m^2) scalars.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_dot(a, b) -> jax.Array:
    """<a, b> over every leaf, accumulated in f32.

    Contracts every dim in place via dot_general — never ravel()s: merging
    sharded dims would make GSPMD all-gather the whole tensor, while the
    in-place contraction keeps shards local and all-reduces one scalar."""
    def leaf(x, y):
        dims = tuple(range(x.ndim))
        return jax.lax.dot_general(
            x, y, ((dims, dims), ((), ())), preferred_element_type=jnp.float32)

    leaves = jax.tree.leaves(jax.tree.map(leaf, a, b))
    return jnp.sum(jnp.stack(leaves)) if leaves else jnp.float32(0.0)


def tree_axpy(alpha, x, y):
    """alpha * x + y, leaf-wise (keeps y's dtype)."""
    return jax.tree.map(lambda xi, yi: (alpha * xi.astype(jnp.float32) + yi.astype(jnp.float32)).astype(yi.dtype), x, y)


def tree_scale(alpha, x):
    return jax.tree.map(lambda xi: (alpha * xi.astype(jnp.float32)).astype(xi.dtype), x)


def tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_mul(a, b):
    """Hadamard product (used for diagonal-FIM * vector products)."""
    return jax.tree.map(lambda x, y: x * y, a, b)


def tree_norm(a) -> jax.Array:
    return jnp.sqrt(tree_dot(a, a))


def tree_zeros_like(a, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros_like(x, dtype=dtype or x.dtype), a)


def tree_ones_like(a, dtype=None):
    return jax.tree.map(lambda x: jnp.ones_like(x, dtype=dtype or x.dtype), a)


def tree_cast(a, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), a)


def tree_size(a) -> int:
    """Total number of scalar parameters (static)."""
    return sum(int(x.size) for x in jax.tree.leaves(a))


def tree_stack_push(buf, x, index):
    """Write pytree ``x`` into slot ``index`` of a stacked (m, ...) buffer.

    The circular L-BFGS history is stored as a pytree whose leaves carry a
    leading history dimension of size m; this is a functional, jit-friendly
    write (lax dynamic_update_index semantics via .at[]).
    """
    return jax.tree.map(lambda b, xi: b.at[index].set(xi.astype(b.dtype)), buf, x)


def tree_stack_init(x, m: int, dtype=None):
    """Allocate an (m, ...) zero history buffer shaped like pytree ``x``."""
    return jax.tree.map(
        lambda xi: jnp.zeros((m,) + xi.shape, dtype=dtype or xi.dtype), x
    )


def tree_stack_index(buf, index):
    """Read slot ``index`` from a stacked history buffer."""
    return jax.tree.map(lambda b: b[index], buf)
