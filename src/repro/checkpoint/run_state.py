"""Mid-run checkpoint/resume for :class:`repro.fed.server.FederatedRun`.

``save_run(path, run)`` captures everything that mutates across sync
rounds — the strategy's server state (params + optimizer), the driver's
rng streams (numpy generator states + the jax compression key), the
``CommLedger`` counters, and the edge runtime's clock / batteries /
channel rng / scenario state — so ``load_run(path, run)`` into a freshly
constructed run with the *same configs* continues exactly where the
original left off: the resumed run's ledger and per-round drop sets are
bit-identical to the uninterrupted run's tail (``tests/test_resume.py``,
scenario on or off).

Two artifacts per checkpoint: ``<path>`` is the npz array pytree
(:func:`repro.checkpoint.save`), ``<path>.meta.json`` the scalar state
(rng states carry arbitrary-precision ints, which JSON keeps exact and
npz floats would not).  Both writes are atomic (tmp + rename).

Scope (raises otherwise):
  * sync mode only — the async in-flight heap/holds are not captured;
  * no pending error-feedback residuals (per-client EF pytrees).

Round *numbering* restarts at 0 in the resumed run (trace round ids,
``history`` indices): it is observability only — no simulation state
reads it.
"""
from __future__ import annotations

import json
import os
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import restore, save

_LEDGER_FIELDS = ("down_bytes", "up_star_bytes", "up_tree_bytes",
                  "scalar_bytes", "rounds")
_EDGE_COUNTERS = ("energy_j", "dropped_total", "deadline_dropped_total",
                  "unavailable_total", "realloc_rounds")


def _check_resumable(run) -> None:
    edge = run.edge
    if edge is not None and edge.async_agg is not None:
        raise ValueError(
            "run_state checkpoints sync-mode runs only: the async "
            "aggregator's in-flight uploads / held spectrum are live "
            "event-heap state this format does not capture")
    if run._ef_residual:
        raise ValueError(
            "run has pending per-client error-feedback residuals; "
            "run_state does not capture EF state — checkpoint with "
            "compress='none'/'int8' (no EF) or at an EF-free boundary")


def _array_tree(run) -> dict:
    """The npz side: every mutable array, as one pytree."""
    tree: dict = {"strategy": run.strategy.state_dict(),
                  "qkey": np.asarray(run._qkey)}
    edge = run.edge
    if edge is not None:
        tree["battery_j"] = np.asarray(edge.fleet.battery_j)
        if edge.scenario is not None:
            tree["scenario"] = edge.scenario.state_dict()["arrays"]
    return tree


def _meta(run) -> dict:
    """The JSON side: rng states, counters, the simulated clock."""
    m: dict = {
        "algorithm": run.algorithm,
        "rng": run.rng.bit_generator.state,
        "ledger": {f: getattr(run.ledger, f) for f in _LEDGER_FIELDS},
    }
    edge = run.edge
    if edge is not None:
        m["edge"] = {
            "clock_s": edge.clock.now,
            "rng": edge.rng.bit_generator.state,
            "channel_rng": edge.channel._rng.bit_generator.state,
            "drop_reasons": dict(edge.drop_reasons),
            "phase_s": dict(edge.phase_s),
        }
        for f in _EDGE_COUNTERS:
            m["edge"][f] = getattr(edge, f)
        if edge.scenario is not None:
            m["scenario"] = edge.scenario.state_dict()["meta"]
    return m


def save_run(path: str, run) -> None:
    """Checkpoint ``run`` (a sync-mode FederatedRun) at a round
    boundary: arrays to ``path`` (npz pytree), scalar state to
    ``path + '.meta.json'``."""
    _check_resumable(run)
    save(path, _array_tree(run))
    meta_path = path + ".meta.json"
    d = os.path.dirname(os.path.abspath(meta_path))
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(_meta(run), fh)
        os.replace(tmp, meta_path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load_run(path: str, run):
    """Restore a checkpoint into ``run`` — a freshly constructed
    FederatedRun with the same configs as the saved one — and return
    it.  The fresh run supplies the pytree template (dtypes/shapes), so
    a config mismatch fails loudly instead of resuming wrong."""
    _check_resumable(run)
    with open(path + ".meta.json") as fh:
        meta = json.load(fh)
    if meta["algorithm"] != run.algorithm:
        raise ValueError(
            f"checkpoint was saved from algorithm {meta['algorithm']!r}, "
            f"this run is {run.algorithm!r}")
    # check the scenario spec BEFORE the array restore: two different
    # scenarios usually disagree on their state arrays too, and the raw
    # pytree KeyError would mask the actual config mismatch
    sc = None if run.edge is None else run.edge.scenario
    if sc is not None and "scenario" in meta:
        ckpt_spec = meta["scenario"].get("spec", sc.spec)
        if ckpt_spec != sc.spec:
            raise ValueError(
                f"scenario spec mismatch: checkpoint has {ckpt_spec!r}, "
                f"this run has {sc.spec!r}")
    tree = restore(path, _array_tree(run))

    run.strategy.load_state_dict(tree["strategy"])
    run._qkey = jnp.asarray(tree["qkey"])
    run.rng.bit_generator.state = meta["rng"]
    for f in _LEDGER_FIELDS:
        setattr(run.ledger, f, meta["ledger"][f])

    edge = run.edge
    if (edge is None) != ("edge" not in meta):
        raise ValueError("checkpoint and run disagree on whether an edge "
                         "runtime is configured")
    if edge is not None:
        em = meta["edge"]
        # a fresh EventClock at the saved simulated time (sync mode: the
        # heap is empty between rounds, only `now` carries over)
        edge.clock = type(edge.clock)(em["clock_s"])
        edge.rng.bit_generator.state = em["rng"]
        edge.channel._rng.bit_generator.state = em["channel_rng"]
        edge.fleet.battery_j[:] = tree["battery_j"]
        for f in _EDGE_COUNTERS:
            setattr(edge, f, em[f])
        edge.drop_reasons = dict(em["drop_reasons"])
        edge.phase_s = dict(em["phase_s"])
        if edge.scenario is not None:
            if "scenario" not in meta:
                raise ValueError("run has a scenario but the checkpoint "
                                 "saved none")
            edge.scenario.load_state_dict(
                {"arrays": tree.get("scenario", {}), "meta": meta["scenario"]})
        elif "scenario" in meta:
            raise ValueError("checkpoint saved scenario state but the run "
                             "has no scenario configured")
    return run
