from repro.checkpoint.checkpoint import restore, save  # noqa: F401
from repro.checkpoint.run_state import load_run, save_run  # noqa: F401
