"""npz-based pytree checkpointing (no orbax in this environment).

Leaves are stored under their '/'-joined tree path; restore rebuilds into a
caller-supplied template (so dtypes/shardings are re-imposed by the caller's
device_put).  Atomic via temp-file rename."""
from __future__ import annotations

import os
import tempfile

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(path: str, tree) -> None:
    flat, _ = _flatten(tree)
    # numpy can't serialize ml_dtypes (bfloat16 etc.) — store as a raw
    # uint16/uint8 view; restore() re-imposes the template dtype anyway.
    for k, v in list(flat.items()):
        if v.dtype.kind not in "biufc":  # e.g. bfloat16
            flat[k] = v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)
            flat["__viewdtype__/" + k] = np.str_(str(v.dtype))
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, **flat)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    finally:
        for t in (tmp, tmp + ".npz"):
            if os.path.exists(t):
                os.remove(t)


def restore(path: str, template):
    data = np.load(path)
    flat, treedef = _flatten(template)
    missing = set(flat) - set(data.files)
    if missing:
        raise KeyError(f"checkpoint {path} missing keys: {sorted(missing)[:5]}...")
    tmpl_leaves = jax.tree_util.tree_leaves(template)
    restored = []
    for k, t in zip(flat, tmpl_leaves, strict=True):
        v = data[k]
        meta = "__viewdtype__/" + k
        if meta in data.files:
            import ml_dtypes  # noqa: F401 — registers the dtype names
            v = v.view(np.dtype(str(data[meta])))
        restored.append(np.asarray(v).astype(t.dtype).reshape(t.shape))
    return jax.tree_util.tree_unflatten(treedef, restored)
