"""Qwen3-32B — dense, GQA + qk_norm. [hf:Qwen/Qwen3-8B family card]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-32b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    lbfgs_m=4,
))


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        name="qwen3-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=4, head_dim=32, d_ff=512, vocab_size=512,
        dtype="float32", attn_q_chunk=64, remat=False,
    )
