"""DBRX-132B — fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    num_experts=16,
    top_k=4,
    lbfgs_m=4,  # 132B params: history kept short + bf16 to fit HBM
    fsdp=True,
    grad_accum_dtype="bfloat16",
    train_n_micro=8,
))


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        name="dbrx-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=4, head_dim=32, d_ff=384, vocab_size=512,
        num_experts=4, top_k=2, dtype="float32", moe_group=64,
        attn_q_chunk=64, ssm_chunk=32, remat=False,
    )
