"""Chameleon-34B — early-fusion VLM; images arrive as VQ tokens inside the
text vocabulary, so the backbone input is token ids. [arXiv:2405.09818]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chameleon-34b",
    family="vlm",
    source="arXiv:2405.09818",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,    # chameleon uses qk-norm for training stability
    lbfgs_m=4,
))


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        name="chameleon-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=4, head_dim=32, d_ff=512, vocab_size=512,
        dtype="float32", attn_q_chunk=64, remat=False,
    )
