from repro.configs.base import (  # noqa: F401
    ASSIGNED, ArchConfig, FedConfig, INPUT_SHAPES, ShapeConfig, get, names,
    register,
)
