"""The paper's own experiment models (Sec. VI-A).

CNN classifiers used in Tables II-V / Figs 3-4:
  * F-MNIST : 2 conv layers (16, 32 ch) + 2x2 maxpool + ReLU  [McMahan '17]
  * CIFAR-10: VGG11-style conv stack                          [Simonyan '15]
  * KWS     : 3 conv layers (16, 32, 64 ch) + 256-unit FC on 50x16 MFCCs

These run end-to-end on CPU with the federated runtime; channel widths are
faithful, and reduced variants are used where tests need speed.
"""
from dataclasses import dataclass
from typing import Sequence



@dataclass(frozen=True)
class CNNConfig:
    name: str
    input_shape: tuple          # (H, W, C)
    num_classes: int
    conv_channels: Sequence[int]
    fc_units: Sequence[int]
    pool: tuple = (2, 2)
    dataset: str = "fmnist"

    def binary(self) -> "CNNConfig":
        """FedOVA component classifier: same body, 1-logit head."""
        import dataclasses
        return dataclasses.replace(self, num_classes=1)


FMNIST_CNN = CNNConfig(
    name="fmnist_cnn", input_shape=(28, 28, 1), num_classes=10,
    conv_channels=(16, 32), fc_units=(128,), dataset="fmnist",
)

CIFAR_VGG = CNNConfig(
    name="cifar_vgg11", input_shape=(32, 32, 3), num_classes=10,
    conv_channels=(64, 128, 256, 256, 512, 512, 512, 512),
    fc_units=(512,), dataset="cifar10",
)

KWS_CNN = CNNConfig(
    name="kws_cnn", input_shape=(50, 16, 1), num_classes=10,
    conv_channels=(16, 32, 64), fc_units=(256,), pool=(1, 2), dataset="kws",
)

CNN_CONFIGS = {c.name: c for c in (FMNIST_CNN, CIFAR_VGG, KWS_CNN)}


def reduced(cfg: CNNConfig) -> CNNConfig:
    import dataclasses
    return dataclasses.replace(
        cfg, conv_channels=tuple(min(c, 16) for c in cfg.conv_channels[:2]),
        fc_units=(32,),
    )
