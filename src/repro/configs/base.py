"""Architecture + run configuration system.

Every assigned architecture lives in its own ``src/repro/configs/<id>.py``
exporting ``CONFIG`` (exact published shape, cited) and ``smoke_config()``
(a reduced same-family variant for CPU smoke tests).  ``registry.get(name)``
resolves ``--arch`` flags for the launcher / dry-run / benchmarks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, TYPE_CHECKING

import jax.numpy as jnp

if TYPE_CHECKING:  # annotation only — repro.edge stays an optional layer
    from repro.edge.runtime import EdgeConfig


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    source: str  # citation for the config numbers
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0          # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_every: int = 1         # apply MoE FFN every Nth layer (jamba: 2)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0        # hybrid: one attention layer per `attn_every`
    # --- attention details ---
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    attn_variant: str = "full"      # "full" | "sliding_window"
    window: int = 4096
    is_encoder: bool = False
    frontend: Optional[str] = None  # None | "audio_embed" | "vq_tokens"
    # --- numerics / optimizer plumbing ---
    dtype: str = "bfloat16"
    remat: bool = True
    lbfgs_m: int = 10
    lbfgs_dtype: str = "bfloat16"
    fim_mode: str = "microbatch"    # "per_example" | "microbatch"
    moe_group: int = 1024           # tokens per MoE dispatch group
    attn_q_chunk: int = 256
    fsdp: bool = False              # shard params over data axes too
                                    # (needed when params/TP > HBM: >=100B)
    grad_accum_dtype: str = "float32"  # bf16 halves the grad/Fisher
                                       # all-reduce bytes (Theorem 3's O(d))
    train_n_micro: int = 0          # 0 = launcher default; FSDP archs use
                                    # fewer microbatches (gather traffic
                                    # scales with n_micro)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + per-layer), for rooflines."""
        d, hd = self.d_model, self.resolved_head_dim
        n = self.vocab_size * d  # embedding (+ tied head)
        if not self.is_encoder and self.vocab_size:
            n += self.vocab_size * d  # untied LM head
        for layer in range(self.num_layers):
            is_attn = self._layer_is_attention(layer)
            if is_attn:
                n += d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd)
                n += (self.num_heads * hd) * d
                n += 2 * d  # norms
            else:  # mamba mixer
                d_in = self.ssm_expand * d
                nheads = d_in // self.ssm_head_dim
                n += d * (2 * d_in + 2 * self.ssm_state + nheads) + d_in * d + 2 * d
            if self._layer_is_moe(layer):
                n += self.num_experts * (3 * d * self.d_ff) + d * self.num_experts
            elif self.d_ff:
                n += 3 * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        moe_layers = sum(self._layer_is_moe(i) for i in range(self.num_layers))
        expert_params = moe_layers * self.num_experts * 3 * d * self.d_ff
        active_expert = moe_layers * self.top_k * 3 * d * self.d_ff
        return total - expert_params + active_expert

    def _layer_is_attention(self, layer: int) -> bool:
        if self.family in ("ssm",):
            return False
        if self.attn_every:
            return (layer % self.attn_every) == (self.attn_every - 1)
        return True

    def _layer_is_moe(self, layer: int) -> bool:
        if not self.num_experts:
            return False
        return (layer % self.moe_every) == (self.moe_every - 1)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class FedConfig:
    """Federated-learning run settings (paper's Table I symbols)."""
    num_clients: int = 100       # K
    participation: float = 0.2   # q (paper uses C)
    local_epochs: int = 5        # E
    batch_size: int = 15         # B
    lbfgs_m: int = 10            # m
    learning_rate: float = 0.05  # eta (first-order / local SGD)
    second_order_lr: float = 1.0 # eta for the Newton-type step (Alg. 1)
    max_step_norm: float = 1.0   # trust-region clip on ||eta p_t||
    fim_damping: float = 1e-2    # lambda in  y = (Gamma + lambda I) s
    fim_ema: float = 0.95
    rounds: int = 50             # T
    noniid_l: int = 0            # 0 = IID, else labels per client
    compress: str = "none"       # upload codec spec (repro.fed.codecs):
                                 # "none" | "int8" | "topk[:ratio]" |
                                 # "randk[:ratio]" — or any registered name
    fim_mode: str = "per_example"  # Eq. 9 diagonal: "per_example" (exact)
                                   # | "microbatch" (squared-grad proxy)
    kernels: str = "auto"        # Pallas fast path for codec encode and
                                 # the quasi-Newton core (repro.kernels):
                                 # "auto" (native on TPU, jnp oracle
                                 # elsewhere) | "on" (kernel everywhere,
                                 # interpret off-TPU) | "off" (oracle)
    prox_mu: float = 0.1         # FedProx proximal coefficient
    seed: int = 0
    # Optional resource-constrained edge simulation (repro.edge): wireless
    # channels, heterogeneous devices, scheduling, async aggregation.
    # None = the paper's cost-free instantaneous clients (default).
    edge: Optional["EdgeConfig"] = None

    def __post_init__(self) -> None:
        # late import: repro.fed.codecs pulls in jax-heavy modules and
        # imports this module back — validate at construction, not import
        from repro.fed import codecs
        try:
            codecs.make(self.compress)
        except ValueError as e:
            raise ValueError(f"FedConfig.compress: {e}") from None
        if self.kernels not in ("auto", "on", "off"):
            raise ValueError(
                f"FedConfig.kernels must be 'auto', 'on' or 'off', "
                f"got {self.kernels!r}")
        if self.fim_mode not in ("per_example", "microbatch"):
            raise ValueError(
                f"FedConfig.fim_mode must be 'per_example' or 'microbatch', "
                f"got {self.fim_mode!r}")
        if not 0.0 < self.participation <= 1.0:
            raise ValueError(
                f"FedConfig.participation must be in (0, 1], "
                f"got {self.participation}")
        if self.prox_mu < 0.0:
            raise ValueError(
                f"FedConfig.prox_mu must be >= 0, got {self.prox_mu}")


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        _load_all()  # idempotent; a direct config import may have run first
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


ASSIGNED = [
    "dbrx-132b", "phi4-mini-3.8b", "granite-20b", "jamba-v0.1-52b",
    "qwen3-32b", "mamba2-370m", "qwen3-moe-235b-a22b", "granite-8b",
    "hubert-xlarge", "chameleon-34b",
]


def _load_all() -> None:
    # Import for registration side effects.
    from repro.configs import (  # noqa: F401
        dbrx_132b, phi4_mini, granite_20b, jamba_52b, qwen3_32b,
        mamba2_370m, qwen3_moe_235b, granite_8b, hubert_xlarge,
        chameleon_34b, paper_models,
    )
