"""Jamba-v0.1 52B — hybrid Mamba + attention (1:7), MoE 16e top-2.
[arXiv:2403.19887]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    top_k=2,
    moe_every=2,     # MoE FFN on every other layer (Jamba e=2)
    attn_every=8,    # one attention layer per 8 (1:7 Mamba ratio)
    ssm_state=16,    # Mamba-1 state size used by Jamba
    ssm_head_dim=64,
    lbfgs_m=4,
))


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        name="jamba-smoke", num_layers=8, d_model=256, num_heads=8,
        num_kv_heads=4, head_dim=32, d_ff=384, vocab_size=512,
        num_experts=4, top_k=2, ssm_state=16, ssm_head_dim=32,
        ssm_chunk=32, dtype="float32", moe_group=64, attn_q_chunk=64,
        remat=False,
    )
