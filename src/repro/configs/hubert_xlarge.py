"""HuBERT-XLarge — encoder-only audio transformer (w2v2 arch).
[arXiv:2106.07447]  Frontend (conv feature extractor) is a stub: the model
consumes precomputed frame embeddings; see DESIGN.md carve-outs."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    source="arXiv:2106.07447",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,  # full MHA
    head_dim=80,
    d_ff=5120,
    vocab_size=504,   # masked-unit prediction targets
    is_encoder=True,
    frontend="audio_embed",
))


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        name="hubert-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=8, head_dim=32, d_ff=512, vocab_size=64,
        dtype="float32", attn_q_chunk=64, remat=False,
    )
