"""Granite-20B (code) — llama-arch dense, MQA (kv=1). [arXiv:2405.04324]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-20b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,  # MQA
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    lbfgs_m=4,
))


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        name="granite20b-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=1, head_dim=32, d_ff=512, vocab_size=512,
        dtype="float32", attn_q_chunk=64, remat=False,
    )
