"""Granite-8B (code) — llama-arch dense, GQA kv=8. [arXiv:2405.04324]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-8b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
))


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        name="granite8b-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=4, head_dim=32, d_ff=512, vocab_size=512,
        dtype="float32", attn_q_chunk=64, remat=False,
    )
