"""Phi-4-mini 3.8B — dense, RoPE + SwiGLU + GQA. [arXiv:2412.08905]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    source="arXiv:2412.08905",
    num_layers=32,
    d_model=3072,
    num_heads=24,   # 24 % 16 != 0 -> heads replicate on the 16-way model
    num_kv_heads=8, # axis; mlp/vocab still shard (see utils/sharding.py)
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
))


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        name="phi4-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=4, head_dim=32, d_ff=512, vocab_size=512,
        dtype="float32", attn_q_chunk=64, remat=False,
    )
