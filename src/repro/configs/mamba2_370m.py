"""Mamba2-370M — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-370m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,           # mamba blocks only, no separate FFN
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
))


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        name="mamba2-smoke", num_layers=2, d_model=256, vocab_size=512,
        ssm_state=32, ssm_head_dim=32, ssm_chunk=32, dtype="float32",
        remat=False,
    )
