"""Qwen3-MoE 235B-A22B — 128 experts top-8, fine-grained.
[hf:Qwen/Qwen3-30B-A3B family card]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,        # per-expert ffn (fine-grained)
    vocab_size=151936,
    num_experts=128,
    top_k=8,
    qk_norm=True,
    rope_theta=1_000_000.0,
    lbfgs_m=2,  # 235B: 2 pairs bf16 = 7.3GB/chip ZeRO-sharded
    fsdp=True,
    grad_accum_dtype="bfloat16",
    train_n_micro=8,
))


def smoke_config() -> ArchConfig:
    return CONFIG.replace(
        name="qwen3moe-smoke", num_layers=2, d_model=256, num_heads=8,
        num_kv_heads=4, head_dim=32, d_ff=128, vocab_size=512,
        num_experts=4, top_k=2, dtype="float32", moe_group=64,
        attn_q_chunk=64, remat=False,
    )
