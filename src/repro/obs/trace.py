"""Typed span/event tracing on the simulated ``EventClock`` timeline.

One traced ``FederatedRun`` answers "where did round 37's time, energy,
and bytes go, and why was client 12 dropped?" without a debugger: every
round is a span, every selected client gets child spans for
allocate → compute → uplink → deadline-verdict → aggregate, and the
async path emits dispatch / land / expiry events.  Span times are
*simulated seconds* (the edge clock); wall-clock measurements (codec
encode time, optional ``wall_span`` blocks) live on a separate timeline
so replays of the same seed stay bit-identical on the sim tracks.

The default everywhere is :data:`NULL_TRACER` — a shared no-op whose
methods early-out and whose ``metrics`` / ``audit`` are the no-op twins
from :mod:`repro.obs.metrics` — so the instrumented hot path costs one
attribute check when tracing is off, and ``tests/test_determinism.py``
replays are unchanged.

Exports live in :mod:`repro.obs.export`: JSONL event log, CSV metric
summaries, and Chrome trace-event JSON loadable in Perfetto.
"""
from __future__ import annotations

import math
import time
from contextlib import contextmanager
from dataclasses import dataclass, field

from repro.obs import metrics as _metrics

# span/event categories (Chrome trace "cat", JSONL "cat")
CAT_ROUND = "round"     # round-level phases on the sim timeline
CAT_CLIENT = "client"   # per-client phases on the sim timeline
CAT_ASYNC = "async"     # buffered-async dispatch / land / expiry
CAT_WALL = "wall"       # host wall-clock measurements (non-deterministic)

# canonical span / event names
ALLOCATE = "allocate"
COMPUTE = "compute"
UPLINK = "uplink"
VERDICT = "deadline_verdict"
AGGREGATE = "aggregate"
DOWNLINK = "downlink"
ROUND = "round"
DISPATCH = "dispatch"
LAND = "land"
EXPIRE = "expire"
FAULT = "scenario_fault"
REALLOC = "reallocate"


@dataclass(frozen=True)
class Span:
    """A closed interval on a timeline (simulated seconds unless
    ``cat == CAT_WALL``)."""
    name: str
    cat: str
    t0: float
    t1: float
    round_id: int = -1
    client: int = -1
    args: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0


@dataclass(frozen=True)
class TraceEvent:
    """An instant on a timeline."""
    name: str
    cat: str
    t: float
    round_id: int = -1
    client: int = -1
    args: dict = field(default_factory=dict)


def render_round(rec: dict) -> str:
    """The console form of one per-round log record — byte-compatible
    with the pre-tracer ``FederatedRun.run`` progress print."""
    return (f"round {rec.get('round', 0):4d} "
            f"loss {rec.get('loss', float('nan')):.4f} "
            f"acc {rec.get('accuracy', float('nan')):.4f}")


class NullTracer:
    """The no-op default: every hook early-outs, the console sink still
    renders per-round progress when asked (so ``verbose=`` keeps working
    without a real tracer attached)."""

    enabled = False

    def __init__(self):
        self.metrics = _metrics.NULL_METRICS
        self.audit = _metrics.NULL_AUDIT

    # -- recording hooks (all no-ops here) -------------------------------
    def span(self, name: str, cat: str, t0: float, t1: float,
             round_id: int = -1, client: int = -1, **args) -> None:
        pass

    def event(self, name: str, cat: str, t: float,
              round_id: int = -1, client: int = -1, **args) -> None:
        pass

    def record_round(self, rec: dict) -> None:
        pass

    def log_round(self, rec: dict, render: bool = False) -> None:
        if render:
            print(render_round(rec))

    @contextmanager
    def wall_span(self, name: str, round_id: int = -1, client: int = -1,
                  **args):
        yield


NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """Records spans, events, per-round records, and structured logs;
    owns a live :class:`~repro.obs.metrics.MetricsRegistry` and
    :class:`~repro.obs.metrics.PlanAudit`.

    ``sink`` is the console sink for rendered per-round log lines
    (default: ``print``); pass a list's ``append`` or any callable to
    capture them.  ``wall=True`` additionally records ``wall_span``
    context blocks on the host wall-clock timeline (category
    ``CAT_WALL`` — excluded from determinism comparisons by
    construction, since sim and wall categories never mix).
    ``audit_max_rows`` caps :class:`~repro.obs.metrics.PlanAudit` row
    retention for fleet-scale runs (None = exhaustive; totals stay
    exact and shortfall rows are always kept either way)."""

    enabled = True

    def __init__(self, wall: bool = False, sink=None, audit_max_rows=None):
        self.metrics = _metrics.MetricsRegistry()
        self.audit = _metrics.PlanAudit(max_rows=audit_max_rows)
        self.spans: list[Span] = []
        self.events: list[TraceEvent] = []
        self.records: list[dict] = []   # per-round edge runtime records
        self.logs: list[dict] = []      # per-round driver log records
        self.wall = bool(wall)
        self._sink = print if sink is None else sink
        # CAT_WALL epoch: wall measurement is the opt-in exception to
        # the sim-determinism contract
        self._wall_epoch = time.perf_counter()  # repro: allow[RPL001]

    # -- recording -------------------------------------------------------
    def span(self, name: str, cat: str, t0: float, t1: float,
             round_id: int = -1, client: int = -1, **args) -> None:
        self.spans.append(Span(name, cat, float(t0), float(t1),
                               int(round_id), int(client), args))

    def event(self, name: str, cat: str, t: float,
              round_id: int = -1, client: int = -1, **args) -> None:
        self.events.append(TraceEvent(name, cat, float(t),
                                      int(round_id), int(client), args))

    def record_round(self, rec: dict) -> None:
        self.records.append(dict(rec))

    def log_round(self, rec: dict, render: bool = False) -> None:
        self.logs.append({k: v for k, v in rec.items()
                          if _jsonable(v)})
        if render:
            self._sink(render_round(rec))

    @contextmanager
    def wall_span(self, name: str, round_id: int = -1, client: int = -1,
                  **args):
        t0 = time.perf_counter() - self._wall_epoch  # repro: allow[RPL001]
        try:
            yield
        finally:
            if self.wall:
                t1 = time.perf_counter() - self._wall_epoch  # repro: allow[RPL001]
                self.span(name, CAT_WALL, t0, t1, round_id=round_id,
                          client=client, **args)

    # -- views -----------------------------------------------------------
    def spans_for(self, round_id: int, cat: str = None,
                  client: int = None) -> list[Span]:
        return [s for s in self.spans
                if s.round_id == round_id
                and (cat is None or s.cat == cat)
                and (client is None or s.client == client)]

    def events_named(self, name: str) -> list[TraceEvent]:
        return [e for e in self.events if e.name == name]


def _jsonable(v) -> bool:
    if isinstance(v, float):
        return True  # NaN handled at export time
    return isinstance(v, (int, str, bool, type(None)))


def sanitize_float(v):
    """NaN/Inf are not valid JSON scalars; stringify them for export."""
    if isinstance(v, float) and not math.isfinite(v):
        return repr(v)
    return v
