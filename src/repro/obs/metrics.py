"""Metrics registry for the edge runtime: counters / gauges / histograms.

Mirrors the strategies / codecs / allocation-policy registries in shape:
metrics are named, self-describing objects looked up (and lazily
created) through one :class:`MetricsRegistry`, so the driver, the edge
runtime, the codecs, and the async aggregator all report into a single
place without threading dozens of attributes around.  A metric point is
``(name, labels, value)``; labels are free-form keyword strings
(``direction="up", topology="star", codec="int8"``).

Standard metric names emitted by the instrumented runtime (see the
README "Observability" table):

  * ``bytes_wire_total``   counter  — direction × topology × codec × phase
  * ``drops_total``        counter  — runtime deadline cutoffs, by reason
  * ``excluded_total``     counter  — a-priori policy exclusions, by reason
  * ``phase_s_total``      counter  — simulated seconds by round phase
  * ``energy_j_total``     counter  — Σ joules drained across the fleet
  * ``barrier_s``          histogram — per-round sync barrier
  * ``cohort_size``        histogram — landed cohort per round
  * ``async_staleness``    histogram — server-version lag of landed updates
  * ``codec_encode_s``     histogram — wall-clock encode time, by codec
  * ``codec_ratio``        gauge    — achieved wire/raw compression ratio
  * ``battery_j``          gauge    — per-client remaining battery
  * ``ef_residual_norm``   gauge    — per-client error-feedback residual

The module also owns :class:`PlanAudit` — the plan == ledger invariant
as a *runtime audit*: every metered upload adds a (round, client, phase,
planned, billed) row, and ``verify(ledger)`` asserts the billed total
equals the ledger's star-uplink actuals, so tests and benchmarks assert
one object instead of each re-deriving the invariant.

``NULL_METRICS`` / ``NULL_AUDIT`` are shared no-op instances: the
default ``NullTracer`` carries them so the instrumented hot path costs a
single attribute load when tracing is off.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Optional


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def reason_key(reason: str) -> str:
    """Collapse a prose drop/exclusion reason into a stable label bucket
    (metrics labels must have low cardinality; the full prose stays on
    the RoundDecision)."""
    r = reason.lower()
    # scenario buckets first: their prose mentions "blackout"/"battery"
    # etc., which must not leak into the policy-exclusion buckets below
    if "unavailable" in r or "availability" in r:
        return "unavailable"
    if "fault" in r or "blackout" in r or "battery-gated" in r:
        return "fault"
    if "battery" in r:
        return "battery"
    if "energy" in r:
        return "energy_budget"
    if "hz" in r or "bandwidth" in r:
        return "bandwidth_infeasible"
    if "deadline" in r or "finish" in r:
        return "deadline"
    return (r.split() or ["other"])[0]


# ---------------------------------------------------------------------------
# Metric kinds
# ---------------------------------------------------------------------------
class Metric:
    kind = ""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._v: dict[tuple, object] = {}

    def labelsets(self) -> list[dict]:
        return [dict(k) for k in self._v]

    def items(self):
        """-> [(labels_dict, value)] in insertion order."""
        return [(dict(k), v) for k, v in self._v.items()]


class Counter(Metric):
    """Monotone accumulator per labelset."""
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        k = _label_key(labels)
        self._v[k] = self._v.get(k, 0.0) + float(value)

    def value(self, **labels) -> float:
        return float(self._v.get(_label_key(labels), 0.0))

    def total(self) -> float:
        return float(sum(self._v.values()))


class Gauge(Metric):
    """Last-written value per labelset."""
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._v[_label_key(labels)] = float(value)

    def value(self, **labels) -> Optional[float]:
        v = self._v.get(_label_key(labels))
        return None if v is None else float(v)


class Histogram(Metric):
    """Streaming count/sum/min/max per labelset (no buckets: the trace
    itself is the full-resolution record; the histogram is the cheap
    always-on aggregate)."""
    kind = "histogram"

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        k = _label_key(labels)
        s = self._v.get(k)
        if s is None:
            self._v[k] = {"count": 1, "sum": v, "min": v, "max": v}
        else:
            s["count"] += 1
            s["sum"] += v
            s["min"] = min(s["min"], v)
            s["max"] = max(s["max"], v)

    def stats(self, **labels) -> dict:
        return dict(self._v.get(_label_key(labels),
                                {"count": 0, "sum": 0.0,
                                 "min": float("nan"), "max": float("nan")}))

    def total_count(self) -> int:
        return int(sum(s["count"] for s in self._v.values()))

    def total_sum(self) -> float:
        return float(sum(s["sum"] for s in self._v.values()))


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Named metrics, created lazily on first use (get-or-create, like
    the strategy/codec registries resolve by name)."""

    enabled = True

    def __init__(self):
        self._metrics: dict[str, Metric] = {}

    def _get(self, cls, name: str, help: str):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested as {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get(Histogram, name, help)

    def get(self, name: str) -> Metric:
        if name not in self._metrics:
            raise KeyError(f"unknown metric {name!r}; "
                           f"known: {sorted(self._metrics)}")
        return self._metrics[name]

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def to_rows(self) -> list[list]:
        """Flatten to CSV-able rows: [name, kind, labels-json, field,
        value] — histograms expand to count/sum/min/max rows."""
        rows = []
        for name in self.names():
            m = self._metrics[name]
            for labels, v in m.items():
                lbl = json.dumps(labels, sort_keys=True)
                if m.kind == "histogram":
                    for f in ("count", "sum", "min", "max"):
                        rows.append([name, m.kind, lbl, f, v[f]])
                else:
                    rows.append([name, m.kind, lbl, "value", v])
        return rows

    def as_dict(self) -> dict:
        return {name: {"kind": m.kind,
                       "points": [[labels, v] for labels, v in m.items()]}
                for name, m in sorted(self._metrics.items())}


# ---------------------------------------------------------------------------
# PlanAudit: plan == ledger as a runtime invariant
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class PlanAuditRow:
    round_id: int
    client: int
    phase: str
    planned_bytes: float      # the plan's wire bytes under this client's codec
    billed_bytes: float       # what the ledger actually metered (tx_frac cut)


class PlanAudit:
    """Planned vs billed upload bytes, per (round, client, phase).

    billed == planned for every landed client; billed < planned exactly
    for deadline-dropped clients (only on-air bytes billed), so
    Σ billed == ``CommLedger.up_star_bytes`` always — the PR-3/4/5
    "ledger ≤ plan, equality iff no drops" contract as one assertable
    object instead of per-test re-derivations.

    ``max_rows`` (None = exhaustive, the default) bounds row retention
    for fleet-scale runs: once the cap is reached, clean (billed ==
    planned) rows are counted but not stored (``dropped_rows``), while
    *shortfall* rows — the interesting ones, billed < planned — are
    ALWAYS retained.  The running ``planned_total`` / ``billed_total``
    cover every ``add`` regardless of retention, so :meth:`verify`
    still checks the full invariant; only :meth:`per_client` is limited
    to the retained rows."""

    enabled = True

    def __init__(self, max_rows: Optional[int] = None):
        self.rows: list[PlanAuditRow] = []
        self.max_rows = None if max_rows is None else int(max_rows)
        self.dropped_rows = 0           # clean rows counted but not stored
        self._planned_total = 0.0
        self._billed_total = 0.0

    def add(self, round_id: int, client: int, phase: str,
            planned_bytes: float, billed_bytes: float) -> None:
        planned_bytes = float(planned_bytes)
        billed_bytes = float(billed_bytes)
        self._planned_total += planned_bytes
        self._billed_total += billed_bytes
        # only CLEAN rows are droppable: a mismatch in either direction
        # (shortfall, or an over-billing bug verify must see) is retained
        if (self.max_rows is not None and len(self.rows) >= self.max_rows
                and billed_bytes == planned_bytes):
            self.dropped_rows += 1
            return
        self.rows.append(PlanAuditRow(int(round_id), int(client), str(phase),
                                      planned_bytes, billed_bytes))

    def planned_total(self) -> float:
        return self._planned_total

    def billed_total(self) -> float:
        return self._billed_total

    def shortfall_rows(self) -> list[PlanAuditRow]:
        """Rows billed under plan — exactly the deadline-dropped uploads."""
        return [r for r in self.rows if r.billed_bytes < r.planned_bytes]

    def per_client(self) -> dict[int, dict[str, float]]:
        out: dict[int, dict[str, float]] = {}
        for r in self.rows:
            d = out.setdefault(r.client, {"planned": 0.0, "billed": 0.0})
            d["planned"] += r.planned_bytes
            d["billed"] += r.billed_bytes
        return out

    def verify(self, ledger, tol: float = 1e-6) -> None:
        """Assert the audit's billed total equals the ledger's star-uplink
        actuals (and billed ≤ planned row-wise).  Raises ValueError with
        the decomposition on mismatch."""
        billed = self.billed_total()
        actual = float(ledger.up_star_bytes)
        if abs(billed - actual) > tol * max(actual, 1.0):
            raise ValueError(
                f"PlanAudit billed {billed:.6g}B != CommLedger star uplink "
                f"{actual:.6g}B (planned {self.planned_total():.6g}B over "
                f"{len(self.rows)} rows)")
        bad = [r for r in self.rows
               if r.billed_bytes > r.planned_bytes * (1 + 1e-9)]
        if bad:
            raise ValueError(
                f"{len(bad)} audit rows billed ABOVE plan, e.g. {bad[0]}")


# ---------------------------------------------------------------------------
# No-op twins for the untraced hot path
# ---------------------------------------------------------------------------
class _NullMetric:
    def inc(self, value: float = 1.0, **labels) -> None:
        pass

    def set(self, value: float, **labels) -> None:
        pass

    def observe(self, value: float, **labels) -> None:
        pass

    def value(self, **labels) -> float:
        return 0.0

    def total(self) -> float:
        return 0.0

    def items(self):
        return []


_NULL_METRIC = _NullMetric()


class NullMetricsRegistry(MetricsRegistry):
    enabled = False

    def __init__(self):
        super().__init__()

    def counter(self, name: str, help: str = ""):
        return _NULL_METRIC

    gauge = counter
    histogram = counter


class NullPlanAudit(PlanAudit):
    enabled = False

    def add(self, round_id, client, phase, planned_bytes, billed_bytes):
        pass


NULL_METRICS = NullMetricsRegistry()
NULL_AUDIT = NullPlanAudit()
