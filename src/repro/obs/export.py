"""Trace / metrics exporters: JSONL, CSV, Chrome trace-event JSON, and
the ``BENCH_*.json`` perf-trajectory emitter.

  * :func:`to_jsonl` / :func:`parse_jsonl` — a line-per-record log of
    every span, event, per-round record, and structured log entry.  The
    export contains *only simulated-timeline data by default* (wall
    category excluded), so two same-seed replays serialize to identical
    strings — the determinism lock for the tracer itself.
  * :func:`to_chrome` — Chrome trace-event JSON (the ``traceEvents``
    envelope) loadable in Perfetto / ``chrome://tracing``: one process
    for the simulated edge timeline with a thread per client (thread 0
    carries round-level phases), plus an optional wall-clock process.
  * :func:`metrics_to_csv` — the flattened metric points.
  * :func:`write_bench_json` — one ``BENCH_<name>.json`` per benchmark
    entrypoint: name, git rev, timestamp, and metric rows, the unit of
    the tracked perf trajectory (compare files across commits).
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
from typing import Optional

from repro.obs.trace import CAT_WALL, Span, TraceEvent, Tracer, sanitize_float


# ---------------------------------------------------------------------------
# JSONL
# ---------------------------------------------------------------------------
def _clean(d: dict) -> dict:
    return {k: sanitize_float(v) for k, v in d.items()}


def to_jsonl(tracer: Tracer, include_wall: bool = False) -> str:
    """One JSON object per line, in recording order per section."""
    lines = []
    for s in tracer.spans:
        if s.cat == CAT_WALL and not include_wall:
            continue
        lines.append({"type": "span", "name": s.name, "cat": s.cat,
                      "t0": sanitize_float(s.t0), "t1": sanitize_float(s.t1),
                      "round": s.round_id, "client": s.client,
                      "args": _clean(s.args)})
    for e in tracer.events:
        if e.cat == CAT_WALL and not include_wall:
            continue
        lines.append({"type": "event", "name": e.name, "cat": e.cat,
                      "t": sanitize_float(e.t), "round": e.round_id,
                      "client": e.client, "args": _clean(e.args)})
    for r in tracer.records:
        lines.append({"type": "round", **_clean(r)})
    for r in tracer.logs:
        lines.append({"type": "log", **_clean(r)})
    return "\n".join(json.dumps(ln, sort_keys=True) for ln in lines)


def write_jsonl(tracer: Tracer, path: str, include_wall: bool = False) -> str:
    with open(path, "w") as f:
        f.write(to_jsonl(tracer, include_wall=include_wall) + "\n")
    return path


def parse_jsonl(text: str) -> dict:
    """-> {"spans": [Span], "events": [TraceEvent], "records": [dict],
    "logs": [dict]} — the inverse of :func:`to_jsonl` (wall-free)."""
    out = {"spans": [], "events": [], "records": [], "logs": []}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        d = json.loads(line)
        t = d.pop("type")
        if t == "span":
            out["spans"].append(Span(d["name"], d["cat"], d["t0"], d["t1"],
                                     d["round"], d["client"], d["args"]))
        elif t == "event":
            out["events"].append(TraceEvent(d["name"], d["cat"], d["t"],
                                            d["round"], d["client"],
                                            d["args"]))
        elif t == "round":
            out["records"].append(d)
        elif t == "log":
            out["logs"].append(d)
        else:
            raise ValueError(f"unknown trace record type {t!r}")
    return out


# ---------------------------------------------------------------------------
# Chrome trace-event JSON (Perfetto / chrome://tracing)
# ---------------------------------------------------------------------------
_SIM_PID = 1
_WALL_PID = 2


def _tid(client: int) -> int:
    # thread 0 = round-level track; client k = thread k+1
    return 0 if client < 0 else int(client) + 1


def _top_clients(tracer: Tracer, k: int) -> set:
    """The ``k`` clients with the latest span end (slowest finish first)
    on the simulated timeline — the stragglers a fleet-scale trace is
    usually opened to find."""
    latest: dict[int, float] = {}
    for s in tracer.spans:
        if s.cat == CAT_WALL or s.client < 0:
            continue
        latest[s.client] = max(latest.get(s.client, float("-inf")), s.t1)
    ranked = sorted(latest, key=lambda c: (-latest[c], c))
    return set(ranked[:max(int(k), 0)])


def to_chrome(tracer: Tracer, include_wall: bool = True,
              top_k_clients: Optional[int] = None) -> dict:
    """The ``traceEvents`` envelope: complete ("X") events for spans,
    instant ("i") events for point events, metadata ("M") rows naming
    the processes and per-client threads.  Simulated seconds map to
    trace microseconds 1:1 (1 sim second == 1s on the Perfetto ruler).

    ``top_k_clients`` (None = everyone) bounds the per-client tracks for
    fleet-scale traces: only the k slowest-finishing clients keep their
    threads; the round-level track (thread 0) is always complete."""
    ev: list[dict] = []
    ev.append({"name": "process_name", "ph": "M", "pid": _SIM_PID, "tid": 0,
               "args": {"name": "edge-sim"}})
    keep = (None if top_k_clients is None
            else _top_clients(tracer, top_k_clients))
    tids = {0}
    for s in tracer.spans:
        if s.cat == CAT_WALL:
            continue
        if keep is not None and s.client >= 0 and s.client not in keep:
            continue
        tids.add(_tid(s.client))
        ev.append({"name": s.name, "cat": s.cat, "ph": "X",
                   "ts": s.t0 * 1e6, "dur": max(s.dur, 0.0) * 1e6,
                   "pid": _SIM_PID, "tid": _tid(s.client),
                   "args": _clean({"round": s.round_id, **s.args})})
    for e in tracer.events:
        if e.cat == CAT_WALL:
            continue
        if keep is not None and e.client >= 0 and e.client not in keep:
            continue
        tids.add(_tid(e.client))
        ev.append({"name": e.name, "cat": e.cat, "ph": "i", "s": "t",
                   "ts": e.t * 1e6, "pid": _SIM_PID, "tid": _tid(e.client),
                   "args": _clean({"round": e.round_id, **e.args})})
    for tid in sorted(tids):
        ev.append({"name": "thread_name", "ph": "M", "pid": _SIM_PID,
                   "tid": tid,
                   "args": {"name": "rounds" if tid == 0
                            else f"client {tid - 1}"}})
    wall = [s for s in tracer.spans if s.cat == CAT_WALL]
    if wall and include_wall:
        ev.append({"name": "process_name", "ph": "M", "pid": _WALL_PID,
                   "tid": 0, "args": {"name": "host-wall"}})
        for s in wall:
            ev.append({"name": s.name, "cat": s.cat, "ph": "X",
                       "ts": s.t0 * 1e6, "dur": max(s.dur, 0.0) * 1e6,
                       "pid": _WALL_PID, "tid": _tid(s.client),
                       "args": _clean({"round": s.round_id, **s.args})})
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def write_chrome(tracer: Tracer, path: str, include_wall: bool = True,
                 top_k_clients: Optional[int] = None) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome(tracer, include_wall=include_wall,
                            top_k_clients=top_k_clients), f)
    return path


# ---------------------------------------------------------------------------
# Metrics CSV
# ---------------------------------------------------------------------------
def metrics_to_csv(registry) -> str:
    lines = ["metric,kind,labels,field,value"]
    for name, kind, labels, fld, v in registry.to_rows():
        lbl = labels.replace('"', '""')
        lines.append(f'{name},{kind},"{lbl}",{fld},{v}')
    return "\n".join(lines)


def write_metrics_csv(registry, path: str) -> str:
    with open(path, "w") as f:
        f.write(metrics_to_csv(registry) + "\n")
    return path


# ---------------------------------------------------------------------------
# BENCH_*.json: the tracked perf trajectory
# ---------------------------------------------------------------------------
def _json_default(o):
    """numpy scalars / arrays and other oddballs -> JSON scalars."""
    try:
        import numpy as np
        if isinstance(o, np.ndarray):
            return o.tolist()
        if isinstance(o, np.generic):
            return o.item()
    except ImportError:  # pragma: no cover
        pass
    try:
        return float(o)
    except (TypeError, ValueError):
        return str(o)


def _finite_tree(o):
    """Recursively stringify non-finite floats (same convention as the
    JSONL export) so the emitted file is strict JSON — no ``NaN`` /
    ``Infinity`` literals."""
    if isinstance(o, dict):
        return {k: _finite_tree(v) for k, v in o.items()}
    if isinstance(o, list):
        return [_finite_tree(v) for v in o]
    return sanitize_float(o)


def git_rev(root: str = ".") -> str:
    # without this guard, `git rev-parse` walks up from ``root`` and can
    # report an enclosing checkout's rev for an exported/tarball tree
    if not os.path.exists(os.path.join(root, ".git")):  # a worktree's .git is a file
        return "unknown"
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=root,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def write_bench_json(name: str, rows, header=None, meta: Optional[dict] = None,
                     root: str = ".") -> str:
    """Emit ``<root>/BENCH_<name>.json``: the perf-trajectory point for
    this commit.  ``rows`` is any JSON-serializable list of metric rows
    (lists paired with ``header``, or self-describing dicts)."""
    payload = {
        "name": name,
        "git_rev": git_rev(root),
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "header": list(header) if header is not None else None,
        "rows": _finite_tree(json.loads(json.dumps(rows,
                                                   default=_json_default))),
    }
    if meta:
        payload["meta"] = _finite_tree(
            json.loads(json.dumps(meta, default=_json_default)))
    path = os.path.join(root, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
