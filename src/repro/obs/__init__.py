"""repro.obs — observability for the federated edge runtime.

Span tracing on the simulated clock (:mod:`repro.obs.trace`), a
counters/gauges/histograms registry plus the plan==ledger
:class:`PlanAudit` (:mod:`repro.obs.metrics`), and exporters — JSONL,
CSV, Perfetto-loadable Chrome trace JSON, ``BENCH_*.json``
(:mod:`repro.obs.export`).

Attach a :class:`Tracer` to a run::

    from repro import obs
    tracer = obs.Tracer()
    run = FederatedRun(mcfg, fcfg, train, test, "fim_lbfgs", tracer=tracer)
    run.run(rounds=8)
    obs.write_chrome(tracer, "trace.json")       # load in ui.perfetto.dev
    obs.write_jsonl(tracer, "trace.jsonl")
    tracer.audit.verify(run.ledger)              # plan == ledger, audited

The default is :data:`NULL_TRACER` — a shared no-op — so the
instrumented hot path costs nothing when tracing is off.
"""
from repro.obs.export import (metrics_to_csv, parse_jsonl, to_chrome,
                              to_jsonl, write_bench_json, write_chrome,
                              write_jsonl, write_metrics_csv)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               NULL_AUDIT, NULL_METRICS, PlanAudit,
                               reason_key)
from repro.obs.trace import (AGGREGATE, ALLOCATE, CAT_ASYNC, CAT_CLIENT,
                             CAT_ROUND, CAT_WALL, COMPUTE, DISPATCH, DOWNLINK,
                             EXPIRE, FAULT, LAND, NULL_TRACER, REALLOC, ROUND,
                             UPLINK, VERDICT, NullTracer, Span, TraceEvent,
                             Tracer, render_round)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "PlanAudit",
    "NULL_AUDIT", "NULL_METRICS", "NULL_TRACER", "NullTracer", "Span",
    "TraceEvent", "Tracer", "render_round", "reason_key",
    "metrics_to_csv", "parse_jsonl", "to_chrome", "to_jsonl",
    "write_bench_json", "write_chrome", "write_jsonl", "write_metrics_csv",
    "AGGREGATE", "ALLOCATE", "CAT_ASYNC", "CAT_CLIENT", "CAT_ROUND",
    "CAT_WALL", "COMPUTE", "DISPATCH", "DOWNLINK", "EXPIRE", "FAULT",
    "LAND", "REALLOC", "ROUND", "UPLINK", "VERDICT",
]
