"""Seeded-random fallback for the ``hypothesis`` API surface these tests
use (``given`` / ``settings`` / ``strategies.integers``).

The real dependency is declared in the ``test`` extra
(``pip install -e .[test]``); in hermetic environments where it is not
installed, property tests degrade to deterministic random sampling —
``max_examples`` draws from a fixed-seed PRNG per test — instead of
erroring at collection.  No shrinking, no database, same assertions.
"""
from __future__ import annotations

import random


class _IntegersStrategy:
    def __init__(self, min_value: int, max_value: int):
        self.min_value = int(min_value)
        self.max_value = int(max_value)

    def example(self, rng: random.Random) -> int:
        # edge values first: hypothesis-style boundary bias
        return rng.randint(self.min_value, self.max_value)

    def boundary(self):
        return [self.min_value, self.max_value]


class strategies:  # noqa: N801 — mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value: int, max_value: int) -> _IntegersStrategy:
        return _IntegersStrategy(min_value, max_value)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        # NOTE: no functools.wraps — copying fn's signature would make
        # pytest treat the strategy parameters as fixtures.  The runner
        # must present a zero-argument signature.
        def runner():
            n = getattr(runner, "_compat_max_examples", 20)
            rng = random.Random(0xFEE1)
            examples = [[s.boundary()[0] for s in strats],
                        [s.boundary()[1] for s in strats]]
            while len(examples) < n:
                examples.append([s.example(rng) for s in strats])
            for vals in examples[:n]:
                fn(*vals)
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco
