"""Checkpoint/resume (repro.checkpoint.run_state): bit-identical tails.

A sync-mode ``FederatedRun`` saved at a round boundary and restored into
a freshly constructed run must replay the remaining rounds bit-for-bit:
same ledger totals, same cohorts/drops, same simulated clock and energy
— with and without an ``EdgeConfig.scenario`` attached, so the
availability/fault RNG stream, per-process state (markov chains, trace
cursors), and the re-allocation counters all round-trip through the
``.npz`` + sidecar format.
"""
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.edge import ChannelConfig, DeviceConfig, EdgeConfig
from repro.fed.server import FederatedRun

MCFG = reduced(FMNIST_CNN)
UPLINK = ChannelConfig(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=3.0,
                       fading="rayleigh", server_rate_bps=50e6)
HETERO = DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=1.0)
TRAIN, TEST = make_classification(MCFG, n_train=300, n_test=100, seed=0,
                                  noise=0.5)

SCENARIOS = [
    None,
    ("diurnal:period=20,amp=0.4,base=0.7|"
     "snr_burst:prob=0.3,scale=0.1"),
    "markov:p_drop=0.2,p_join=0.4|data_exclusion:0.7",
]


def _mk(scenario):
    edge = EdgeConfig(channel=UPLINK, device=HETERO, scheduler="deadline",
                      deadline_s=5.0, min_clients=1,
                      enforce_deadline_s=1.5, scenario=scenario,
                      reallocate=True)
    fcfg = FedConfig(num_clients=8, participation=1.0, local_epochs=1,
                     batch_size=32, rounds=6, noniid_l=2, seed=0, edge=edge)
    return FederatedRun(MCFG, fcfg, TRAIN, TEST, "fedavg_sgd")


def _tail_fp(run, tail=3):
    """Everything the resumed run must reproduce over its last rounds."""
    h = run.edge.history[-tail:]
    return {
        "ledger": {f: getattr(run.ledger, f)
                   for f in ("down_bytes", "up_star_bytes", "up_tree_bytes",
                             "scalar_bytes", "rounds")},
        "cohorts": [tuple(sorted(d.selected))
                    for d in run.edge.decisions[-tail:]],
        "drops": [tuple(sorted(d.dropped))
                  for d in run.edge.decisions[-tail:]],
        "wall": [r["wall_s"] for r in h],
        "cohort_sizes": [r["cohort"] for r in h],
        "clock_s": run.edge.clock.now,
        "energy_j": run.edge.energy_j,
        "params": [np.asarray(p) for p in
                   (run.params if run.params is not None else [])],
        "unavailable": run.edge.unavailable_total,
        "realloc_rounds": run.edge.realloc_rounds,
    }


def _eq(a, b):
    pa, pb = a.pop("params"), b.pop("params")
    assert a == b
    assert len(pa) == len(pb)
    for x, y in zip(pa, pb):
        assert np.array_equal(x, y)


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_resume_tail_bit_identical(scenario, tmp_path):
    straight = _mk(scenario)
    straight.run(rounds=6, eval_every=6)

    head = _mk(scenario)
    head.run(rounds=3, eval_every=3)
    ckpt = str(tmp_path / "ckpt.npz")
    head.save(ckpt)

    resumed = _mk(scenario).restore_from(ckpt)
    resumed.run(rounds=3, eval_every=3)

    _eq(_tail_fp(straight), _tail_fp(resumed))


def test_resume_restores_counters(tmp_path):
    run = _mk(SCENARIOS[1])
    run.run(rounds=4, eval_every=4)
    ckpt = str(tmp_path / "c.npz")
    run.save(ckpt)
    fresh = _mk(SCENARIOS[1]).restore_from(ckpt)
    assert fresh.edge.clock.now == run.edge.clock.now
    assert fresh.edge.energy_j == run.edge.energy_j
    assert fresh.edge.unavailable_total == run.edge.unavailable_total
    assert fresh.edge.dropped_total == run.edge.dropped_total
    assert fresh.ledger.up_star_bytes == run.ledger.up_star_bytes


def test_resume_rejects_scenario_mismatch(tmp_path):
    run = _mk(SCENARIOS[1])
    run.run(rounds=2, eval_every=2)
    ckpt = str(tmp_path / "c.npz")
    run.save(ckpt)
    with pytest.raises(ValueError, match="spec mismatch"):
        _mk(SCENARIOS[2]).restore_from(ckpt)
