"""End-to-end federated training (reduced scale): the paper's headline
behavioural claims must hold directionally."""
import numpy as np

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.fed.server import FederatedRun

MCFG = reduced(FMNIST_CNN)


def _data(noise=0.35, seed=0):
    return make_classification(MCFG, n_train=1200, n_test=300, seed=seed,
                               noise=noise)


def test_fedova_beats_fedavg_on_noniid2():
    """Fig. 3: under non-IID-2, FedOVA's accuracy dominates FedAvg's."""
    train, test = _data()
    fcfg = FedConfig(num_clients=16, participation=0.25, local_epochs=2,
                     batch_size=16, rounds=6, noniid_l=2, learning_rate=0.05,
                     seed=0)
    acc = {}
    for alg in ("fedavg_sgd", "fedova"):
        run = FederatedRun(MCFG, fcfg, train, test, alg)
        hist = run.run(rounds=6, eval_every=6)
        acc[alg] = max(h.get("accuracy", 0) for h in hist)
    assert acc["fedova"] > acc["fedavg_sgd"], acc


def test_fim_lbfgs_converges_faster_per_round():
    """Table II: under the one-update-per-round protocol, Alg. 1 reaches the
    target accuracy in fewer rounds than first-order FedAvg.

    Two sources of flake removed (validated over seeds 0-9): full
    participation makes the protocol deterministic — with q=0.25 the
    5-client cohorts make the aggregated gradient/Fisher jump across
    rounds and the quasi-Newton step oscillates through the target — and
    a tighter trust region (0.5), heavier damping (0.05) and shorter
    Fisher EMA (0.9) stop the second-order step from overshooting near
    the optimum.  eval_every=1 so the hit round is exact, not quantized
    to the eval grid.  (Across seeds 0-9 this config gives 7 strict wins
    and 3 ties for Alg. 1, never a loss; the test pins seed 0.  The
    multi-seed comparison lives in benchmarks/table2_optimizers.py.)"""
    train, test = make_classification(MCFG, n_train=1500, n_test=400,
                                      seed=0, noise=1.2)
    fcfg = FedConfig(num_clients=20, participation=1.0, local_epochs=1,
                     batch_size=10_000, rounds=16, noniid_l=3,
                     learning_rate=0.05, seed=0, max_step_norm=0.5,
                     fim_damping=0.05, fim_ema=0.9)
    target = 0.55
    rounds = {}
    for alg in ("fim_lbfgs", "fedavg_sgd"):
        run = FederatedRun(MCFG, fcfg, train, test, alg)
        hist = run.run(rounds=16, eval_every=1, target_accuracy=target)
        hit = [h["round"] for h in hist if h.get("accuracy", 0) >= target]
        rounds[alg] = hit[0] if hit else 99
    assert rounds["fim_lbfgs"] < rounds["fedavg_sgd"], rounds


def test_feddane_round_runs_and_learns():
    train, test = _data()
    fcfg = FedConfig(num_clients=12, participation=0.3, local_epochs=2,
                     batch_size=16, rounds=4, noniid_l=0, learning_rate=0.05,
                     seed=0)
    run = FederatedRun(MCFG, fcfg, train, test, "feddane")
    hist = run.run(rounds=4, eval_every=4)
    assert hist[-1]["accuracy"] > 0.5


def test_fedova_lbfgs_composition():
    """The paper's integration claim: FedOVA driven by the FIM-L-BFGS server
    step trains (loss finite, accuracy above chance)."""
    train, test = _data()
    fcfg = FedConfig(num_clients=10, participation=0.3, local_epochs=1,
                     batch_size=32, rounds=3, noniid_l=2, seed=0)
    run = FederatedRun(MCFG, fcfg, train, test, "fedova_lbfgs")
    hist = run.run(rounds=3, eval_every=3)
    assert hist[-1]["accuracy"] > 0.15  # 10 classes -> chance is 0.1


def test_simulator_round_step_improves_loss():
    """The mesh-parallel cohort path (vmap clients + one aggregation)."""
    import jax
    import jax.numpy as jnp
    from repro.core import fim_lbfgs
    from repro.fed.simulator import make_round_step
    from repro.models import cnn

    params, _ = cnn.init(MCFG, jax.random.PRNGKey(0))
    def loss_fn(p, b):
        return cnn.softmax_loss(p, MCFG, b)
    ocfg = fim_lbfgs.FimLbfgsConfig(learning_rate=1.0, m=5, damping=1e-2,
                                    max_step_norm=1.0)
    step = make_round_step(loss_fn, cnn.per_example_loss_fn(MCFG), ocfg)
    opt = fim_lbfgs.init(params, ocfg)
    train, _ = _data()
    K, B = 8, 32
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(5):
        idx = rng.integers(0, len(train.x), size=(K, B))
        cohort = {"x": jnp.asarray(train.x[idx]), "y": jnp.asarray(train.y[idx])}
        params, opt, stats = step(params, opt, cohort, jnp.ones(K))
        losses.append(float(stats["loss"]))
    assert losses[-1] < losses[0], losses
