"""Per-architecture smoke tests (deliverable f).

Each of the 10 assigned architectures is instantiated as a REDUCED variant
of the same family (2-8 layers, d_model<=512, <=4 experts) and runs one
forward/train step on CPU asserting output shapes + no NaNs; decodable
families also run two serve steps.  The FULL configs are exercised only via
the dry run (ShapeDtypeStruct, no allocation).
"""
import importlib

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ShapeConfig
from repro.models import model

ARCH_MODULES = {
    "dbrx-132b": "dbrx_132b",
    "phi4-mini-3.8b": "phi4_mini",
    "granite-20b": "granite_20b",
    "jamba-v0.1-52b": "jamba_52b",
    "qwen3-32b": "qwen3_32b",
    "mamba2-370m": "mamba2_370m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "granite-8b": "granite_8b",
    "hubert-xlarge": "hubert_xlarge",
    "chameleon-34b": "chameleon_34b",
}


def smoke_cfg(name):
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}").smoke_config()


@pytest.mark.parametrize("arch", sorted(ARCH_MODULES))
def test_full_config_is_faithful(arch):
    """The registered CONFIG must carry the exact published numbers."""
    from repro.configs import get
    cfg = get(arch)
    expected = {
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352, 16, 4),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064, 0, 0),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152, 0, 0),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536, 16, 2),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936, 0, 0),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280, 0, 0),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936, 128, 8),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152, 0, 0),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504, 0, 0),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536, 0, 0),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size, cfg.num_experts, cfg.top_k)
    assert got == expected
    if arch == "mamba2-370m":
        assert cfg.ssm_state == 128


@pytest.mark.parametrize("arch", sorted(ARCH_MODULES))
def test_reduced_train_step(arch):
    cfg = smoke_cfg(arch)
    assert cfg.d_model <= 512 and cfg.num_layers <= 8
    assert cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params, axes = model.init(cfg, key)
    jax.tree.map(lambda p, a: None, params, axes)  # structures must match

    shp = ShapeConfig("smoke", 64, 2, "train")
    batch = model.synth_batch(cfg, shp, key)
    if cfg.is_encoder:
        batch["labels"] = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)

    def loss_of(p):
        return model.loss_fn(p, cfg, batch)[0]

    loss, grad = jax.value_and_grad(loss_of)(params)
    assert jnp.isfinite(loss)
    for leaf in jax.tree.leaves(grad):
        assert jnp.all(jnp.isfinite(leaf))
    # a gradient step changes the loss (training signal exists)
    p2 = jax.tree.map(lambda w, g: w - 0.1 * g, params, grad)
    assert float(loss_of(p2)) < float(loss)


@pytest.mark.parametrize("arch", sorted(a for a in ARCH_MODULES
                                        if a != "hubert-xlarge"))
def test_reduced_decode_steps(arch):
    cfg = smoke_cfg(arch)
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    cache, cache_axes = model.init_cache(cfg, batch=2, context=32)
    jax.tree.map(lambda c, a: None, cache, cache_axes)
    tok = jnp.ones((2, 1), jnp.int32)
    logits, cache = model.decode_fn(params, cfg, cache, tok)
    logits2, _ = model.decode_fn(params, cfg, cache, tok)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits)) and jnp.all(jnp.isfinite(logits2))


def test_hubert_is_encoder_only():
    cfg = smoke_cfg("hubert-xlarge")
    assert cfg.is_encoder and cfg.frontend == "audio_embed"
    from repro.configs.base import INPUT_SHAPES
    ok, reason = model.supports_shape(cfg, INPUT_SHAPES["decode_32k"])
    assert not ok and "encoder" in reason
