import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint


def test_roundtrip(tmp_path):
    tree = {
        "layers": {"w": jnp.arange(12.0).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }
    path = os.path.join(tmp_path, "ck.npz")
    checkpoint.save(path, tree)
    template = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = checkpoint.restore(path, template)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out), strict=True):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_missing_key_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    checkpoint.save(path, {"a": jnp.ones(3)})
    try:
        checkpoint.restore(path, {"a": jnp.ones(3), "b": jnp.ones(2)})
    except KeyError:
        return
    raise AssertionError("expected KeyError")
