"""Sharding rule unit tests + an 8-device host-platform integration test of
the dry-run machinery (subprocess: device count must not leak into this
process)."""
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

from repro.utils import sharding as shd


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_spec_divisible():
    mesh = FakeMesh({"data": 16, "model": 16})
    assert shd.spec_for((4096, 8192), "embed,mlp", mesh) == P(None, "model")
    assert shd.spec_for((49152, 4096), "vocab,embed", mesh) == P("model", None)


def test_spec_divisibility_fallback():
    """phi4's 24 heads don't divide 16 -> replicate that dim only."""
    mesh = FakeMesh({"data": 16, "model": 16})
    assert shd.spec_for((2, 24, 128), "layers,heads,head_dim", mesh) == P(None, None, None)
    assert shd.spec_for((2, 48, 128), "layers,heads,head_dim", mesh) == P(None, "model", None)


def test_missing_mesh_axis_dropped():
    mesh = FakeMesh({"data": 4, "model": 2})
    spec = shd.spec_for((8, 16), "batch,embed", mesh)  # batch maps (pod,data)
    assert spec == P("data", None)


def test_axis_not_reused():
    mesh = FakeMesh({"data": 2, "model": 2})
    spec = shd.spec_for((4, 4), "mlp,qkv", mesh)  # both map to model
    assert spec == P("model", None)


def test_opt_rules_shard_embed_over_data():
    mesh = FakeMesh({"data": 16, "model": 16})
    spec = shd.spec_for((10, 36, 4096, 14336), "history,layers,embed,mlp",
                        mesh, shd.OPT_RULES)
    assert spec == P(None, None, "data", "model")


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.launch.dryrun import build_step
from repro.configs.granite_8b import smoke_config
from repro.configs.base import ShapeConfig
from repro.models import model as zoo
from repro.utils import sharding as shd
from repro.models.layers import use_mesh

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = smoke_config().replace(dtype="float32")
shape = ShapeConfig("t", 64, 8, "train")
step, arg_shapes, arg_axes, donate = build_step(cfg, shape, "fim_lbfgs", 2)
in_sh = [shd.shardings_for_tree(s, a, mesh, shd.OPT_RULES if i == 1 else None)
         for i, (s, a) in enumerate(zip(arg_shapes, arg_axes))]
with use_mesh(mesh):
    compiled = jax.jit(step, in_shardings=tuple(in_sh)).lower(*arg_shapes).compile()
assert compiled.memory_analysis() is not None
# ALSO run it for real on the 8 fake devices: numerics must hold sharded
import numpy as np
from repro.launch import train as trainlib
ocfg = trainlib.opt_config(cfg)
params, axes, opt, opt_axes = trainlib.init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
batch = zoo.synth_batch(cfg, shape, jax.random.PRNGKey(1))
with use_mesh(mesh):
    p2, o2, stats = jax.jit(step, in_shardings=tuple(in_sh))(params, opt, batch)
assert np.isfinite(float(stats["loss"])), stats
print("MINI_DRYRUN_OK", float(stats["loss"]))
"""


@pytest.mark.slow
def test_mini_dryrun_on_8_host_devices():
    """End-to-end pjit of the federated train step on an 8-device host mesh:
    lowers, compiles AND executes with finite loss."""
    proc = subprocess.run(
        [sys.executable, "-c", MINI_DRYRUN],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert "MINI_DRYRUN_OK" in proc.stdout, proc.stderr[-2000:]
