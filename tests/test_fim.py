"""Diagonal-Fisher estimation (paper Eq. 9 + diagonalization)."""
import jax.numpy as jnp
import numpy as np

from repro.core import fim


def _quadratic_per_example(params, x, y):
    return 0.5 * jnp.sum((params["w"] * x - y) ** 2)


def test_per_example_diag_matches_manual():
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=5))}
    xs = jnp.asarray(rng.normal(size=(16, 5)))
    ys = jnp.asarray(rng.normal(size=(16, 5)))
    diag = fim.per_example_diag(_quadratic_per_example, params, xs, ys)
    # manual: grad_i = (w*x_i - y_i) * x_i; diag = mean_i grad_i^2
    g = (np.asarray(params["w"]) * np.asarray(xs) - np.asarray(ys)) * np.asarray(xs)
    np.testing.assert_allclose(np.asarray(diag["w"]), (g ** 2).mean(0), rtol=1e-5)


def test_microbatch_diag_is_squared_grad():
    g = {"a": jnp.asarray([-2.0, 3.0])}
    d = fim.microbatch_diag(g)
    np.testing.assert_allclose(np.asarray(d["a"]), [4.0, 9.0])


def test_ema_update_and_warmup():
    params = {"a": jnp.zeros(3)}
    st = fim.init(params)
    d1 = {"a": jnp.asarray([1.0, 2.0, 3.0])}
    st = fim.update(st, d1, ema=0.9)
    np.testing.assert_allclose(np.asarray(st.diag["a"]), [1, 2, 3])  # warmup: copy
    d2 = {"a": jnp.asarray([2.0, 2.0, 2.0])}
    st = fim.update(st, d2, ema=0.5)
    np.testing.assert_allclose(np.asarray(st.diag["a"]), [1.5, 2.0, 2.5])


def test_smooth_y_lower_bound():
    """y = (Γ + λI)s must satisfy <s, y> >= λ'||s||² (Assumption 1 / Lemma 1)."""
    params = {"a": jnp.zeros(4)}
    st = fim.init(params)
    st = fim.update(st, {"a": jnp.asarray([0.0, 0.0, 1.0, 4.0])}, ema=0.9)
    s = {"a": jnp.asarray([1.0, -1.0, 2.0, 0.5])}
    lam_abs = 1e-3
    y = fim.smooth_y(st, s, damping=lam_abs, rel_damping=0.1)
    sy = float(jnp.vdot(s["a"], y["a"]))
    ss = float(jnp.vdot(s["a"], s["a"]))
    lam_eff = lam_abs + 0.1 * float(fim.mean_diag(st))
    assert sy >= lam_eff * ss - 1e-6
