"""Unit tests for the vector-free L-BFGS core (paper Alg. 1 line 6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: seeded-random fallback, same assertions
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import lbfgs


def _push_pairs(params, m, pairs):
    h = lbfgs.init(params, m)
    for s, y in pairs:
        h = lbfgs.push(h, s, y)
    return h


def _flat(tree):
    return np.concatenate(
        [np.asarray(leaf).ravel() for leaf in jax.tree.leaves(tree)])


def _random_pd_pairs(rng, shapes, n):
    out = []
    for _ in range(n):
        s = {k: jnp.asarray(rng.normal(size=shp)) for k, shp in shapes.items()}
        y = jax.tree.map(lambda x: x * jnp.asarray(rng.uniform(0.5, 2.0, x.shape)), s)
        out.append((s, y))
    return out


SHAPES = {"w": (6, 7), "b": (11,)}


@pytest.mark.parametrize("n_pairs", [0, 1, 3, 5, 9])
def test_matches_reference_two_loop(n_pairs):
    rng = np.random.default_rng(n_pairs)
    m = 5
    params = {k: jnp.zeros(s) for k, s in SHAPES.items()}
    pairs = _random_pd_pairs(rng, SHAPES, n_pairs)
    h = _push_pairs(params, m, pairs)
    g = {k: jnp.asarray(rng.normal(size=s)) for k, s in SHAPES.items()}
    p = lbfgs.direction(h, g)

    live = pairs[-m:]
    ref = lbfgs.reference_two_loop(
        [_flat(s) for s, _ in live], [_flat(y) for _, y in live], _flat(g))
    np.testing.assert_allclose(_flat(p), ref, rtol=2e-5, atol=1e-6)


def test_empty_history_is_steepest_descent():
    params = {"w": jnp.zeros((4,))}
    h = lbfgs.init(params, 3)
    g = {"w": jnp.asarray([1.0, -2.0, 3.0, 0.5])}
    p = lbfgs.direction(h, g)
    np.testing.assert_allclose(np.asarray(p["w"]), -np.asarray(g["w"]), atol=1e-6)


def test_circular_wrap_uses_only_last_m():
    rng = np.random.default_rng(0)
    m = 3
    params = {"w": jnp.zeros((20,))}
    pairs = _random_pd_pairs(rng, {"w": (20,)}, 8)
    h_all = _push_pairs(params, m, pairs)
    h_tail = _push_pairs(params, m, pairs[-m:])
    g = {"w": jnp.asarray(rng.normal(size=20))}
    np.testing.assert_allclose(
        np.asarray(lbfgs.direction(h_all, g)["w"]),
        np.asarray(lbfgs.direction(h_tail, g)["w"]), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_descent_direction_property(n_pairs, seed):
    """With PD curvature pairs, p must be a descent direction: <p, g> < 0."""
    rng = np.random.default_rng(seed)
    params = {"w": jnp.zeros((12,))}
    pairs = _random_pd_pairs(rng, {"w": (12,)}, n_pairs)
    h = _push_pairs(params, 4, pairs)
    g_np = rng.normal(size=12)
    if np.linalg.norm(g_np) < 1e-6:
        return
    g = {"w": jnp.asarray(g_np)}
    p = lbfgs.direction(h, g)
    assert float(np.dot(_flat(p), _flat(g))) < 0.0


def test_gram_matrix_symmetry_and_blocks():
    rng = np.random.default_rng(1)
    params = {"w": jnp.zeros((9,))}
    pairs = _random_pd_pairs(rng, {"w": (9,)}, 4)
    h = _push_pairs(params, 4, pairs)
    g = {"w": jnp.asarray(rng.normal(size=9))}
    M = np.asarray(lbfgs.gram_matrix(h, g))
    np.testing.assert_allclose(M, M.T, rtol=1e-5, atol=1e-6)
    # diag of the s-block equals ||s_i||^2 for the slot each pair landed in
    for slot in range(4):
        s_i = _flat(jax.tree.map(lambda b, s=slot: b[s], h.s))
        np.testing.assert_allclose(M[slot, slot], s_i @ s_i, rtol=1e-5)
