"""Property tests for the non-IID-l partitioner (paper Sec. VI-A Remark)."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: seeded-random fallback, same assertions
    from _hypothesis_compat import given, settings, strategies as st

from repro.data.partition import labels_per_client, noniid_partition


@settings(max_examples=25, deadline=None)
@given(
    st.integers(2, 10),     # n_classes
    st.integers(4, 30),     # num_clients
    st.integers(1, 5),      # l
    st.integers(0, 10_000), # seed
)
def test_partition_is_exact_cover(n_classes, num_clients, ell, seed):
    rng = np.random.default_rng(seed)
    n = n_classes * 40
    labels = rng.integers(0, n_classes, size=n)
    parts = noniid_partition(labels, num_clients, min(ell, n_classes), n_classes, seed)
    allidx = np.concatenate([p for p in parts if len(p)]) if parts else np.array([])
    # every sample assigned exactly once
    assert len(allidx) == n
    assert len(np.unique(allidx)) == n


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(0, 10_000))
def test_label_diversity_bounded_by_l(ell, seed):
    n_classes, num_clients = 10, 20
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=2000)
    parts = noniid_partition(labels, num_clients, ell, n_classes, seed)
    per_client = labels_per_client(labels, parts)
    # the vast majority of clients hold exactly l labels; the dealing
    # fallback may slightly exceed for a few stragglers
    counts = [len(s) for s in per_client if s]
    assert np.median(counts) <= ell
    assert max(counts) <= ell + 2


def test_iid_mode_splits_evenly():
    labels = np.arange(1000) % 10
    parts = noniid_partition(labels, 10, 0, 10, seed=0)
    sizes = [len(p) for p in parts]
    assert max(sizes) - min(sizes) <= 1
