"""Regression tests for the trip-count-aware HLO cost analyzer — the
roofline's foundation.  XLA's own cost_analysis counts while bodies once;
these fixtures pin the corrected behaviour."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze, xla_cost_analysis


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile().as_text()


def test_xla_cost_analysis_undercounts_scans():
    """Documents the bug we correct: XLA reports ONE body's flops."""
    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=10)
        return y

    comp = jax.jit(f).lower(jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    xla_flops = xla_cost_analysis(comp).get("flops", 0)
    one_matmul = 2 * 256 ** 3
    assert xla_flops <= 1.5 * one_matmul  # ~1 matmul, not 10


@pytest.mark.parametrize("length", [1, 7, 10])
def test_flat_scan_flops(length):
    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=length)
        return y

    txt = _compile(f, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    r = analyze(txt)
    expect = length * 2 * 256 ** 3
    assert abs(r["flops"] - expect) / expect < 0.02


def test_nested_scan_flops():
    def body(c, _):
        return c @ c, None

    def f(x):
        def outer(c, _):
            d, _ = jax.lax.scan(body, c, None, length=5)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    txt = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    r = analyze(txt)
    expect = 20 * 2 * 128 ** 3
    assert abs(r["flops"] - expect) / expect < 0.02


def test_collectives_weighted_by_trip_count():
    import subprocess, sys
    # needs >1 device: run in a subprocess with 4 host devices
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_cost import analyze
mesh = jax.make_mesh((4,), ("model",))
def f(x, w):
    def step(c, _):
        return jnp.einsum("bd,df->bf", c, w), None   # TP AR per iteration
    y, _ = jax.lax.scan(step, x, None, length=6)
    return y
x = jax.ShapeDtypeStruct((8, 256), jnp.float32)
w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
comp = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, None)),
                                NamedSharding(mesh, P("model", None)))).lower(x, w).compile()
r = analyze(comp.as_text())
per_ar = 8 * 256 * 4  # result bytes f32
assert r["collective_total"] >= 5 * per_ar, r  # ~6 iterations, not 1
print("COLL_TRIP_OK", r["collective_total"] / per_ar)
"""
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=600, cwd=__file__.rsplit("/tests/", 1)[0],
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu"})
    assert "COLL_TRIP_OK" in proc.stdout, proc.stderr[-1500:]
