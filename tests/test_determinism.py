"""Determinism regression: same seed ⇒ bit-identical runs.

Two ``FederatedRun``s built from the same config must produce identical
CommLedger totals, identical per-round drop/exclusion sets, and an
identical simulated clock — for every registered strategy × the three
bandwidth allocation policies {uniform, bandwidth_opt, energy_opt},
under an enforced runtime deadline (so the deadline/expiry path is
exercised: hidden RNG in the new cutoff/event code would show up here).
A dedicated async case covers the per-client expiry events.

The full strategy matrix is marked ``slow``; the fast lane
(``-m "not slow"``) keeps one strategy per payload family so PR feedback
stays quick while the cron/full runs sweep everything.
"""
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.edge import ChannelConfig, DeviceConfig, EdgeConfig
from repro.fed import strategies
from repro.fed.server import FederatedRun

MCFG = reduced(FMNIST_CNN)
POLICIES = ["uniform", "bandwidth_opt", "energy_opt", "deadline"]
ALL_ALGS = sorted(strategies.names())
# fast lane: one strategy per payload family (summable delta, 2-phase
# mixed, component/mask) across all four policies
FAST = {("fedavg_sgd", p) for p in POLICIES} | {
    ("fim_lbfgs", "energy_opt"), ("feddane", "uniform"),
    ("fedova", "uniform")}

UPLINK = ChannelConfig(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=3.0,
                       fading="rayleigh", server_rate_bps=50e6)
HETERO = DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=1.0)
TRAIN, TEST = make_classification(MCFG, n_train=300, n_test=100, seed=0,
                                  noise=0.5)

LEDGER_FIELDS = ("down_bytes", "up_star_bytes", "up_tree_bytes",
                 "scalar_bytes", "rounds")


def _run(alg, policy, seed=0, rounds=2, **edge_kw):
    edge = EdgeConfig(channel=UPLINK, device=HETERO, scheduler=policy,
                      deadline_s=5.0, min_clients=1,
                      enforce_deadline_s=1.5, **edge_kw)
    fcfg = FedConfig(num_clients=8, participation=1.0, local_epochs=1,
                     batch_size=32, rounds=rounds, noniid_l=2, seed=seed,
                     edge=edge)
    run = FederatedRun(MCFG, fcfg, TRAIN, TEST, alg)
    run.run(rounds=rounds, eval_every=rounds)
    return run


def _fingerprint(run):
    """Everything that must be bit-identical across same-seed runs."""
    return {
        "ledger": {f: getattr(run.ledger, f) for f in LEDGER_FIELDS},
        "drops": [tuple(sorted(d.dropped)) for d in run.edge.decisions],
        "excluded": [tuple(sorted(d.excluded)) for d in run.edge.decisions],
        "cohorts": [tuple(sorted(d.selected)) for d in run.edge.decisions],
        "clock_s": run.edge.clock.now,
        "energy_j": run.edge.energy_j,
        "bandwidths": [tuple(np.asarray(d.bandwidth()).tolist())
                       for d in run.edge.decisions],
    }


MATRIX = [pytest.param(a, p,
                       marks=([] if (a, p) in FAST
                              else [pytest.mark.slow]))
          for a in ALL_ALGS for p in POLICIES]


@pytest.mark.parametrize("alg,policy", MATRIX)
def test_same_seed_bit_identical(alg, policy):
    a = _fingerprint(_run(alg, policy))
    b = _fingerprint(_run(alg, policy))
    assert a == b, (alg, policy)


@pytest.mark.parametrize("alg,policy", MATRIX)
def test_fleet_fast_path_bit_identical(alg, policy):
    """The struct-of-arrays fleet fast path (``EdgeConfig.fleet="on"``)
    must be a pure optimization: at small n it produces bit-identical
    ledgers, drop/exclusion sets, cohorts, bandwidths, and clocks vs the
    per-client dict path — the correctness contract that lets the
    10⁵–10⁶-client engine inherit this whole suite."""
    a = _fingerprint(_run(alg, policy, fleet="off"))
    b = _fingerprint(_run(alg, policy, fleet="on"))
    assert a == b, (alg, policy)


# churn scenarios (repro.edge.scenario) the fleet fast path must replay
# bit-identically: sticky markov sessions, a round-unit diurnal wave, and
# a composite with realized-side faults + workload shedding — all with
# mid-round re-allocation on, so the freed-spectrum path is covered too
SCENARIOS = [
    "markov:p_drop=0.2,p_join=0.4",
    "diurnal:period=6,amp=0.5,base=0.6,unit=round",
    ("markov:p_drop=0.2,p_join=0.4|snr_burst:prob=0.4,scale=0.1|"
     "data_exclusion:0.7"),
]


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_fleet_fast_path_bit_identical_under_churn(scenario):
    """The PR-8 contract extended to ISSUE-9: with availability churn,
    fault injection, and opt-in re-allocation in play, the fleet fast
    path must still reproduce the dict path bit-for-bit — scenario draws
    come from one stream (seed+4) consumed identically by both."""
    kw = dict(scenario=scenario, reallocate=True, rounds=3)
    a = _fingerprint(_run("fedavg_sgd", "deadline", fleet="off", **kw))
    b = _fingerprint(_run("fedavg_sgd", "deadline", fleet="on", **kw))
    assert a == b
    # the scenario must actually bite, or the assertion is vacuous
    c = _fingerprint(_run("fedavg_sgd", "deadline", fleet="off", rounds=3))
    assert a != c, scenario


def test_same_seed_bit_identical_async_expiry_path():
    """The buffered-async dispatch with enforced deadlines: expiry
    events, spectrum holds, and staleness buffers must all replay
    identically — hidden RNG in the event path would diverge here."""
    def one():
        run = _run("fedavg_sgd", "uniform", rounds=4, mode="async",
                   buffer_size=2)
        fp = _fingerprint(run)
        fp["expiry"] = sorted(run.edge._expiry.items())
        fp["held"] = sorted(run.edge._held_hz.items())
        fp["aggregated"] = [h.get("cohort") for h in run.edge.history]
        return fp

    a, b = one(), one()
    assert a == b
    # the scenario must actually exercise the expiry path
    assert any(a["drops"])


def test_different_seeds_diverge():
    """Sanity for the fingerprint itself: distinct seeds must not
    collide (otherwise the identity assertions above are vacuous)."""
    a = _fingerprint(_run("fedavg_sgd", "uniform", seed=0))
    b = _fingerprint(_run("fedavg_sgd", "uniform", seed=1))
    assert a != b
