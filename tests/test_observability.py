"""repro.obs: tracing, metrics, exporters, and the plan==ledger audit.

Locks the PR-6 observability contracts:

  * trace round-trip — record -> JSONL -> parse reproduces every span /
    event / record / log;
  * metrics mirror the authorities — ``bytes_wire_total`` sums equal the
    CommLedger fields exactly (the counters are fed from the ledger's
    own return deltas), energy/barrier/cohort metrics reconcile with
    ``EdgeRuntime.history``;
  * drop accounting reconciles — drops_total == Σ RoundDecision.dropped
    == deadline_dropped_total == Σ history drops, and the audit's
    shortfall rows are exactly the dropped clients' uploads;
  * the Chrome trace export is schema-valid trace-event JSON, and under
    star topology the slowest client's compute+uplink span durations sum
    to the recorded round barrier;
  * determinism — two traced same-seed runs serialize to bit-identical
    JSONL, and a traced run's sim fingerprint equals the untraced one
    (tracing reads no RNG and perturbs nothing);
  * the structured per-round log renders byte-compatibly with the old
    ``FederatedRun.run`` progress print.

Reuses the config constants from ``test_determinism`` so the tracer is
exercised on exactly the harness whose replays it must not perturb.
"""
import json
import re

import numpy as np
import pytest

from repro import obs
from repro.configs.base import FedConfig
from repro.edge import EdgeConfig
from repro.fed.server import FederatedRun
from repro.obs.export import write_bench_json

from test_determinism import HETERO, MCFG, TEST, TRAIN, UPLINK, _fingerprint

ROUNDS = 2


def _build(tracer=None, alg="fedavg_sgd", policy="uniform", seed=0,
           compress="none", **edge_kw):
    kw = dict(channel=UPLINK, device=HETERO, scheduler=policy,
              deadline_s=5.0, min_clients=1, enforce_deadline_s=1.5)
    kw.update(edge_kw)
    edge = EdgeConfig(**kw)
    fcfg = FedConfig(num_clients=8, participation=1.0, local_epochs=1,
                     batch_size=32, rounds=ROUNDS, noniid_l=2, seed=seed,
                     compress=compress, edge=edge)
    return FederatedRun(MCFG, fcfg, TRAIN, TEST, alg, tracer=tracer)


def _traced(**kw):
    tracer = obs.Tracer(sink=lambda line: None)
    run = _build(tracer=tracer, **kw)
    run.run(rounds=ROUNDS, eval_every=ROUNDS)
    return run, tracer


@pytest.fixture(scope="module")
def sync_run():
    """One traced sync run under an enforced deadline (drops occur)."""
    return _traced()


@pytest.fixture(scope="module")
def async_run():
    return _traced(mode="async", buffer_size=2)


# ---------------------------------------------------------------------------
# trace round-trip
# ---------------------------------------------------------------------------
def test_jsonl_roundtrip(sync_run):
    _, tracer = sync_run
    text = obs.to_jsonl(tracer)
    parsed = obs.parse_jsonl(text)
    assert len(parsed["spans"]) == len(
        [s for s in tracer.spans if s.cat != obs.CAT_WALL])
    assert len(parsed["events"]) == len(tracer.events)
    assert len(parsed["records"]) == len(tracer.records) == ROUNDS
    assert len(parsed["logs"]) == len(tracer.logs) == ROUNDS
    # spot-check full-fidelity round-trip of one span and one event
    s0, p0 = tracer.spans[0], parsed["spans"][0]
    assert (s0.name, s0.cat, s0.round_id, s0.client) == \
        (p0.name, p0.cat, p0.round_id, p0.client)
    assert s0.t0 == p0.t0 and s0.t1 == p0.t1 and s0.args == p0.args
    e0, q0 = tracer.events[0], parsed["events"][0]
    assert (e0.name, e0.cat, e0.t, e0.round_id, e0.client, e0.args) == \
        (q0.name, q0.cat, q0.t, q0.round_id, q0.client, q0.args)


# ---------------------------------------------------------------------------
# metrics mirror the authorities
# ---------------------------------------------------------------------------
def _counter_sum(tracer, name, **match):
    c = tracer.metrics.get(name)
    return sum(v for labels, v in c.items()
               if all(labels.get(k) == w for k, w in match.items()))


def test_bytes_metric_equals_ledger(sync_run):
    run, tracer = sync_run
    led = run.ledger
    tol = 1e-6 * max(led.up_star_bytes, 1.0)
    assert abs(_counter_sum(tracer, "bytes_wire_total", direction="up",
                            topology="star") - led.up_star_bytes) < tol
    assert abs(_counter_sum(tracer, "bytes_wire_total", direction="up",
                            topology="tree") - led.up_tree_bytes) < tol
    assert abs(_counter_sum(tracer, "bytes_wire_total", direction="down")
               - led.down_bytes) < tol
    assert abs(_counter_sum(tracer, "bytes_wire_total", direction="scalar")
               - led.scalar_bytes) < tol


def test_energy_and_round_metrics_match_history(sync_run):
    run, tracer = sync_run
    hist = run.edge.history
    energy = tracer.metrics.get("energy_j_total").total()
    assert abs(energy - run.edge.energy_j) < 1e-9 * max(run.edge.energy_j, 1)
    assert tracer.metrics.get("cohort_size").total_count() == len(hist)
    barriers = [h["barrier_s"] for h in hist if "barrier_s" in h]
    bh = tracer.metrics.get("barrier_s")
    assert bh.total_count() == len(barriers)
    assert abs(bh.total_sum() - sum(barriers)) < 1e-9
    # phase seconds mirror the runtime's unconditional breakdown
    for phase, secs in run.edge.phase_s.items():
        assert abs(tracer.metrics.get("phase_s_total").value(phase=phase)
                   - secs) < 1e-9
    # per-round records match history one-to-one
    for rid, (rec, h) in enumerate(zip(tracer.records, hist, strict=True)):
        assert rec["round_id"] == rid
        assert rec["cohort"] == h["cohort"]
        assert rec["clock_s"] == h["clock_s"]


def test_battery_gauge_matches_fleet(sync_run):
    run, tracer = sync_run
    g = tracer.metrics.get("battery_j")
    for labels, v in g.items():
        assert v == pytest.approx(
            float(run.edge.fleet.battery_j[labels["client"]]))


# ---------------------------------------------------------------------------
# drop accounting reconciles end to end
# ---------------------------------------------------------------------------
def test_drop_counts_reconcile(sync_run):
    run, tracer = sync_run
    decision_drops = sum(len(d.dropped) for d in run.edge.decisions)
    assert decision_drops > 0, "harness must exercise the cutoff path"
    assert run.edge.deadline_dropped_total == decision_drops
    assert sum(h["dropped"] for h in run.edge.history) == decision_drops
    assert tracer.metrics.get("drops_total").total() == decision_drops
    assert run.edge.drop_reasons.get("deadline_cutoff", 0) == decision_drops
    assert run.edge.summary()["drop_reasons"] == run.edge.drop_reasons
    # every dropped client carries a VERDICT event with dropped=True
    dropped_events = [e for e in tracer.events_named(obs.VERDICT)
                      if e.args["dropped"]]
    assert len(dropped_events) == decision_drops
    for e in dropped_events:
        assert 0.0 <= e.args["tx_frac"] < 1.0
        assert e.args["finish_s"] > e.args["deadline_s"]


def test_excluded_counter_matches_policy(sync_run):
    run, tracer = sync_run
    excluded = sum(len(d.excluded) for d in run.edge.decisions)
    if excluded:
        assert tracer.metrics.get("excluded_total").total() == excluded
    # a-priori exclusions and runtime cutoffs live in separate buckets
    assert all(k == "deadline_cutoff" or k.startswith("excluded:")
               for k in run.edge.drop_reasons)


def test_plan_audit_verifies_and_isolates_shortfall(sync_run):
    run, tracer = sync_run
    tracer.audit.verify(run.ledger)  # billed == ledger star actuals
    assert tracer.audit.billed_total() == pytest.approx(
        run.ledger.up_star_bytes)
    # shortfall rows are exactly the dropped clients' uploads
    dropped_by_round = {}
    for rid, d in enumerate(run.edge.decisions):
        for cid in d.dropped:
            dropped_by_round.setdefault(rid, set()).add(int(cid))
    n_phases = sum(1 for ph in run.plan.phases if ph.up_floats)
    short = tracer.audit.shortfall_rows()
    assert len(short) == sum(map(len, dropped_by_round.values())) * n_phases
    for row in short:
        assert row.client in dropped_by_round[row.round_id]
        assert row.billed_bytes < row.planned_bytes


# ---------------------------------------------------------------------------
# Chrome export: schema + the span-sum == barrier acceptance invariant
# ---------------------------------------------------------------------------
def test_chrome_trace_schema(sync_run):
    _, tracer = sync_run
    doc = obs.to_chrome(tracer)
    json.dumps(doc)  # JSON-serializable end to end
    evs = doc["traceEvents"]
    assert evs, "empty trace"
    names = set()
    for e in evs:
        assert e["ph"] in ("X", "i", "M")
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        if e["ph"] == "X":
            assert e["dur"] >= 0.0 and "ts" in e
        elif e["ph"] == "i":
            assert e["s"] == "t" and "ts" in e
        else:
            names.add((e["name"], e["pid"], e["tid"]))
    # process + per-client thread metadata for the Perfetto track names
    assert ("process_name", 1, 0) in names
    assert ("thread_name", 1, 0) in names


def test_client_span_sums_equal_barrier(sync_run):
    """Star topology: barrier == max_k min(finish_k, deadline_k), and a
    client's compute+uplink spans tile exactly [round_start+t_down,
    +active_k] — so the slowest client's span durations sum to the
    recorded barrier (the PR's acceptance criterion)."""
    run, tracer = sync_run
    checked = 0
    for rec in tracer.records:
        if "barrier_s" not in rec:
            continue
        rid = rec["round_id"]
        clients = {s.client for s in tracer.spans_for(rid, obs.CAT_CLIENT)
                   if s.client >= 0}
        assert clients
        per_client = [sum(s.dur
                          for s in tracer.spans_for(rid, obs.CAT_CLIENT, k)
                          if s.name in (obs.COMPUTE, obs.UPLINK))
                      for k in clients]
        assert max(per_client) == pytest.approx(rec["barrier_s"], rel=1e-9)
        checked += 1
    assert checked == ROUNDS


def test_round_span_tiles_phases(sync_run):
    """round span == downlink + barrier + drain; child spans nest."""
    _, tracer = sync_run
    for rid in range(ROUNDS):
        round_spans = [s for s in tracer.spans_for(rid, obs.CAT_ROUND)
                       if s.name == obs.ROUND]
        assert len(round_spans) == 1
        env = round_spans[0]
        for s in tracer.spans_for(rid):
            if s.cat == obs.CAT_WALL:
                continue
            assert s.t0 >= env.t0 - 1e-12 and s.t1 <= env.t1 + 1e-9


# ---------------------------------------------------------------------------
# determinism: traced replays identical; tracing perturbs nothing
# ---------------------------------------------------------------------------
def test_traced_replays_bit_identical():
    _, ta = _traced()
    _, tb = _traced()
    assert obs.to_jsonl(ta) == obs.to_jsonl(tb)


def test_tracing_does_not_perturb_the_sim(sync_run):
    traced_run, _ = sync_run
    untraced = _build()
    untraced.run(rounds=ROUNDS, eval_every=ROUNDS)
    assert _fingerprint(traced_run) == _fingerprint(untraced)


# ---------------------------------------------------------------------------
# structured per-round log
# ---------------------------------------------------------------------------
def test_console_render_matches_legacy_print(capsys):
    run = _build()  # NULL_TRACER: verbose must still print, same bytes
    run.run(rounds=ROUNDS, eval_every=ROUNDS, verbose=True)
    out = capsys.readouterr().out.splitlines()
    assert len(out) == 1
    assert re.fullmatch(
        r"round +\d+ loss (\d+\.\d{4}|nan) acc \d\.\d{4}", out[0]), out[0]


def test_tracer_sink_and_log_records():
    lines = []
    tracer = obs.Tracer(sink=lines.append)
    run = _build(tracer=tracer)
    run.run(rounds=ROUNDS, eval_every=ROUNDS, verbose=True)
    assert len(lines) == 1 and lines[0].startswith(f"round    {ROUNDS} ")
    assert [rec["round"] for rec in tracer.logs] == [1, 2]
    assert "accuracy" in tracer.logs[-1]


# ---------------------------------------------------------------------------
# async events
# ---------------------------------------------------------------------------
def test_async_dispatch_land_expire(async_run):
    run, tracer = async_run
    dispatches = tracer.events_named(obs.DISPATCH)
    lands = tracer.events_named(obs.LAND)
    expires = tracer.events_named(obs.EXPIRE)
    assert dispatches, "async run must dispatch"
    # a DISPATCH is emitted per surviving submit; each either LANDs or is
    # still in flight.  Verdict-dropped submits get an EXPIRE instead.
    assert len(dispatches) == len(lands) + run.edge.async_agg.in_flight
    assert len(expires) == run.edge.deadline_dropped_total
    staleness = tracer.metrics.get("async_staleness")
    assert staleness.total_count() == len(lands)
    for e in lands:
        assert e.args["staleness"] >= 0
    for e in expires:
        assert 0.0 <= e.args["tx_frac"] < 1.0
    tracer.audit.verify(run.ledger)


# ---------------------------------------------------------------------------
# codec metrics
# ---------------------------------------------------------------------------
def test_codec_metrics_recorded():
    run, tracer = _traced(compress="topk:0.1")
    enc = tracer.metrics.get("codec_encode_s")
    assert enc.total_count() > 0
    ratio = tracer.metrics.get("codec_ratio").value(codec="topk:0.1")
    assert ratio == pytest.approx(0.2, rel=0.01)  # 8B per kept of 40B raw
    norms = tracer.metrics.get("ef_residual_norm").items()
    assert norms and all(v >= 0 for _, v in norms)
    tracer.audit.verify(run.ledger)


# ---------------------------------------------------------------------------
# BENCH_*.json emitter
# ---------------------------------------------------------------------------
def test_bench_json_schema(tmp_path):
    rows = [["fim_diag", 12.5, np.float64(3.25)], ["gram", 40.0, "1.1GB/s"]]
    path = write_bench_json("unit", rows, header=["name", "us", "derived"],
                            meta={"quick": True}, root=str(tmp_path))
    with open(path) as f:
        doc = json.load(f)
    assert path.endswith("BENCH_unit.json")
    assert doc["name"] == "unit"
    assert doc["header"] == ["name", "us", "derived"]
    assert doc["rows"][0] == ["fim_diag", 12.5, 3.25]  # numpy -> JSON scalar
    assert doc["meta"] == {"quick": True}
    assert isinstance(doc["git_rev"], str) and doc["git_rev"]
    assert "T" in doc["timestamp"]
    # tmp_path is no checkout: the rev degrades to "unknown" instead of
    # silently reporting an enclosing repository's HEAD
    assert doc["git_rev"] == "unknown"


def test_git_rev_degrades_outside_a_checkout(tmp_path):
    from repro.obs.export import git_rev
    assert git_rev(str(tmp_path)) == "unknown"
    # a .git dir alone (not a valid repo) must not raise either
    (tmp_path / ".git").mkdir()
    assert git_rev(str(tmp_path)) == "unknown"


# ---------------------------------------------------------------------------
# NullTracer is inert
# ---------------------------------------------------------------------------
def test_null_tracer_records_nothing():
    t = obs.NULL_TRACER
    t.span("x", obs.CAT_ROUND, 0.0, 1.0)
    t.event("y", obs.CAT_CLIENT, 0.5)
    t.record_round({"cohort": 3})
    t.metrics.counter("anything").inc(5.0)
    t.audit.add(0, 1, "p", 10.0, 10.0)
    with t.wall_span("w"):
        pass
    assert not t.enabled
    assert t.metrics.counter("anything").value() == 0.0
    assert t.audit.rows == []
