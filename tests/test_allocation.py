"""The allocation-policy × strategy matrix (repro.edge.allocation).

Invariants every policy must keep, checked end-to-end through
``FederatedRun`` for all seven registered strategies:

  * per-round allocated bandwidth sums to ≤ the shared round budget,
  * every transmitting client holds a strictly positive allocation,
  * plan == ledger per client — also under per-client heterogeneous
    codecs (the adaptive_codec policy), where each client is billed its
    own ``wire_bytes``,
  * bandwidth-only policies never change WHAT is counted: CommLedger
    bytes match ``uniform`` exactly at equal cohorts.

Plus the registry surface (drop-in third-party policies, knob
filtering), the RoundDecision validator, and the vmapped-simulator
coupling (``with_edge`` allocates over the fixed cohort and rejects
per-client codec overrides it cannot round-trip).
"""
import math

import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.edge import (Allocation, AllocationPolicy, ChannelConfig,
                        DeviceConfig, EdgeConfig, RoundDecision, allocation)
from repro.fed.server import FederatedRun

MCFG = reduced(FMNIST_CNN)
ALL_ALGS = ["fim_lbfgs", "fedavg_sgd", "fedavg_adam", "fedprox", "feddane",
            "fedova", "fedova_lbfgs"]
SUMMABLE_ALGS = ["fim_lbfgs", "fedavg_sgd", "fedavg_adam", "fedprox"]
BANDWIDTH_POLICIES = ["uniform", "deadline", "energy_threshold",
                      "capacity_proportional", "bandwidth_opt", "energy_opt"]

UPLINK = ChannelConfig(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=3.0,
                       fading="rayleigh", server_rate_bps=50e6)
HETERO = DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=1.0)


def _data(n_train=300, n_test=100, noise=0.5, seed=0):
    return make_classification(MCFG, n_train=n_train, n_test=n_test,
                               seed=seed, noise=noise)


def _run(alg, policy, rounds=2, seed=0, **edge_kw):
    train, test = _data(seed=seed)
    edge = EdgeConfig(channel=UPLINK, device=HETERO, scheduler=policy,
                      deadline_s=5.0, min_clients=2, **edge_kw)
    fcfg = FedConfig(num_clients=8, participation=1.0, local_epochs=1,
                     batch_size=32, rounds=rounds, noniid_l=2, seed=seed,
                     edge=edge)
    run = FederatedRun(MCFG, fcfg, train, test, alg)
    run.run(rounds=rounds, eval_every=rounds)
    return run


def _expected_ledger(run):
    """Recompute the ledger from the decisions + the plan — per client,
    per phase, under each client's own codec."""
    star = tree = 0.0
    for dec in run.edge.decisions:
        k = len(dec.selected)
        if k == 0:
            continue
        depth = max(1, math.ceil(math.log2(max(k, 2))))
        for ph in run.plan.phases:
            if not ph.up_floats:
                continue
            wire = [(dec.codec_for(i) or ph.codec).wire_bytes(ph.up_floats)
                    for i in dec.selected]
            star += sum(wire)
            tree += depth * max(wire) if ph.aggregatable else sum(wire)
    return star, tree


MATRIX = ([(a, p) for a in ALL_ALGS for p in BANDWIDTH_POLICIES]
          + [(a, "adaptive_codec") for a in SUMMABLE_ALGS])


@pytest.mark.parametrize("alg,policy", MATRIX)
def test_allocation_invariants_and_plan_equals_ledger(alg, policy):
    run = _run(alg, policy)
    assert len(run.edge.decisions) == 2
    for dec in run.edge.decisions:
        # budget: never hand out more than the shared round bandwidth
        assert dec.total_bandwidth_hz() <= dec.budget_hz * (1 + 1e-9), \
            (alg, policy)
        # every transmitting client holds a strictly positive subchannel
        assert all(a.bandwidth_hz > 0 for a in dec.allocations.values()), \
            (alg, policy)
        # selected and excluded are disjoint
        assert not set(dec.selected) & set(dec.excluded), (alg, policy)
    star, tree = _expected_ledger(run)
    assert run.ledger.up_star_bytes == pytest.approx(star), (alg, policy)
    assert run.ledger.up_tree_bytes == pytest.approx(tree), (alg, policy)


@pytest.mark.parametrize("alg", ["feddane", "fedova"])
def test_adaptive_codec_rejected_for_nonsummable(alg):
    train, test = _data()
    edge = EdgeConfig(channel=UPLINK, device=HETERO,
                      scheduler="adaptive_codec")
    fcfg = FedConfig(num_clients=8, participation=1.0, rounds=1,
                     noniid_l=2, seed=0, edge=edge)
    with pytest.raises(ValueError, match="summable"):
        FederatedRun(MCFG, fcfg, train, test, alg)


def test_bandwidth_opt_beats_uniform_at_equal_bytes():
    """The acceptance invariant: allocation changes who/when/how-fast,
    never what is counted — bandwidth_opt must land the same cohorts and
    the exact same CommLedger bytes as uniform (same seed, same budget),
    at strictly lower simulated wall time."""
    uni = _run("fedavg_sgd", "uniform", rounds=3)
    opt = _run("fedavg_sgd", "bandwidth_opt", rounds=3)
    for f in ("down_bytes", "up_star_bytes", "up_tree_bytes",
              "scalar_bytes", "rounds"):
        assert getattr(uni.ledger, f) == getattr(opt.ledger, f), f
    assert (opt.edge.summary()["wall_clock_s"]
            < uni.edge.summary()["wall_clock_s"])
    # and both spend the full budget
    for dec in opt.edge.decisions:
        assert dec.total_bandwidth_hz() == pytest.approx(dec.budget_hz)


def test_adaptive_codec_error_feedback_stays_per_client():
    """Per-client top-k ratios change round to round, but the error-
    feedback residual follows the true client id — exactly the clients
    whose uploads were actually sparsified accumulate one.  A scheduled
    format that would cost >= the dense payload falls back to the base
    codec, so every override is strictly a wire-byte discount."""
    run = _run("fedavg_sgd", "adaptive_codec", rounds=2)
    base_bytes = sum(run._wire_fn(None))
    sparsified = set()
    for dec in run.edge.decisions:
        for i in dec.selected:
            codec = dec.codec_for(i)
            if codec is not None:
                sparsified.add(i)
                assert sum(run._wire_fn(codec)) < base_bytes
    # channel heterogeneity guarantees sub-median links got sparse codecs
    assert sparsified
    assert set(run._ef_residual) == sparsified


def test_bandwidth_budget_knob_scales_round_time():
    """EdgeConfig.bandwidth_budget_hz is the shared pool: halving it
    halves every subchannel under the equal split, so uplink-dominated
    rounds take ~2x longer; bytes stay identical."""
    wide = _run("fedavg_sgd", "uniform", rounds=2,
                bandwidth_budget_hz=8 * 2e5)
    narrow = _run("fedavg_sgd", "uniform", rounds=2,
                  bandwidth_budget_hz=4 * 2e5)
    assert narrow.ledger.up_star_bytes == wide.ledger.up_star_bytes
    assert (narrow.edge.summary()["wall_clock_s"]
            > wide.edge.summary()["wall_clock_s"])
    for dec in narrow.edge.decisions:
        assert dec.budget_hz == pytest.approx(4 * 2e5)


# ------------------------------------------------------------- registry
def test_registry_surface_and_knob_filtering():
    assert {"uniform", "deadline", "energy_threshold",
            "capacity_proportional", "bandwidth_opt", "energy_opt",
            "adaptive_codec"} <= set(allocation.names())
    # make_policy drops knobs a policy does not accept (EdgeConfig passes
    # every knob it carries unconditionally)
    pol = allocation.make_policy("deadline", deadline_s=3.0,
                                 battery_floor_j=1.0, ratio=0.5)
    assert pol.deadline_s == 3.0
    pol = allocation.make_policy("adaptive_codec", ratio=0.5,
                                 deadline_s=3.0)
    assert pol.ratio == 0.5
    with pytest.raises(ValueError, match="unknown allocation policy"):
        allocation.make_policy("waterfilling")


def test_third_party_policy_drop_in():
    """A policy registered from outside the package drives a run end to
    end through EdgeConfig — the registry mirror of strategies/codecs."""
    @allocation.register("_test_greedy")
    class GreedyPolicy(AllocationPolicy):
        """All budget to the fastest k clients, split by rank."""
        def select(self, state):
            order = np.argsort(state.est.time_s)[:state.k]
            return [int(state.est.clients[i]) for i in order], {}

        def allocate(self, ids, state):
            share = state.budget_hz / max(len(ids), 1)
            return {int(i): Allocation(bandwidth_hz=share) for i in ids}

    try:
        run = _run("fedavg_sgd", "_test_greedy", rounds=1)
        assert len(run.edge.decisions) == 1
        dec = run.edge.decisions[0]
        assert dec.total_bandwidth_hz() <= dec.budget_hz * (1 + 1e-9)
        star, tree = _expected_ledger(run)
        assert run.ledger.up_star_bytes == pytest.approx(star)
    finally:
        allocation._REGISTRY.pop("_test_greedy", None)


def test_round_decision_validator():
    with pytest.raises(ValueError, match="non-positive"):
        RoundDecision({1: Allocation(bandwidth_hz=0.0)},
                      budget_hz=1e6).validate()
    with pytest.raises(ValueError, match="exceeds the round budget"):
        RoundDecision({1: Allocation(2e6), 2: Allocation(2e6)},
                      budget_hz=3e6).validate()
    dec = RoundDecision({1: Allocation(1e6, deadline_s=2.0)},
                        budget_hz=1e6).validate()
    assert dec.selected == [1] and not dec.heterogeneous_codecs


def test_policy_selecting_unknown_id_raises_clear_valueerror():
    """A third-party policy returning an id outside the eligible set must
    fail with a named error, not an opaque KeyError from the runtime's
    position lookup (the for_ids satellite fix, at the runtime layer)."""
    from repro.edge.runtime import EdgeRuntime

    @allocation.register("_test_stale")
    class StalePolicy(AllocationPolicy):
        def select(self, state):
            return [int(state.est.clients[0]), 99], {}

    try:
        rt = EdgeRuntime(EdgeConfig(channel=UPLINK, device=HETERO,
                                    scheduler="_test_stale"), 8)
        with pytest.raises(ValueError, match=r"\[99\] outside the round"):
            rt.decide(4, np.arange(8), lambda c: (1e5, 0.0), 1e9)
    finally:
        allocation._REGISTRY.pop("_test_stale", None)


def test_allocate_for_prices_duplicate_cohort_slots():
    """The with_edge mod fallback can repeat a fleet entry: the device
    gets ONE subchannel but carries one payload per slot — the whole
    budget is still granted and nothing is silently dropped."""
    from repro.edge.runtime import EdgeRuntime

    chan = ChannelConfig(bandwidth_hz=2e5, fading="none", snr_db_std=0.0)
    flat = DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=0.0)
    def wire(c):
        return (1e5, 0.0)

    def alloc(cohort):
        rt = EdgeRuntime(EdgeConfig(channel=chan, device=flat,
                                    bandwidth_budget_hz=8e5), 4, seed=0)
        return rt.allocate_for(cohort, wire, 1e9)

    est1, dec1 = alloc([0, 1, 2, 3])
    est2, dec2 = alloc([0, 1, 2, 3, 0, 1, 2, 3])
    for dec in (dec1, dec2):
        assert sorted(dec.selected) == [0, 1, 2, 3]
        # the full pool is granted either way (the bug: duplicates
        # collapsed, splitting the budget over phantom slots)
        assert dec.total_bandwidth_hz() == pytest.approx(8e5)
    # same budget, same subchannels, twice the payloads -> uplink share
    # of the round doubles (compute share is per-device and also doubles:
    # the device runs both slots' local work)
    np.testing.assert_allclose(est2.time_s, 2 * est1.time_s)
    # and the optimizer sees the multiplicity: a device carrying two
    # payloads (and both slots' compute) needs a wider subchannel than
    # its single-payload peers to hit the same barrier
    rt = EdgeRuntime(EdgeConfig(channel=chan, device=flat,
                                scheduler="bandwidth_opt",
                                bandwidth_budget_hz=8e5), 4, seed=0)
    est3, dec3 = rt.allocate_for([0, 1, 2, 3, 0], wire, 1e9)
    assert dec3.allocations[0].bandwidth_hz > dec3.allocations[1].bandwidth_hz
    # the optimum still equalizes finish times across devices
    assert est3.time_s.max() - est3.time_s.min() < 1e-3 * est3.time_s.max()


def test_async_runtime_through_allocate_for_does_not_starve():
    """Spectrum holds belong to the buffered-async dispatch path only;
    repeated allocate_for rounds (with_edge) on an async-configured
    runtime must keep the full budget available."""
    from repro.edge.runtime import EdgeRuntime

    rt = EdgeRuntime(EdgeConfig(channel=UPLINK, device=HETERO,
                                mode="async", buffer_size=2), 8)
    def wire(c):
        return (1e5, 0.0)
    _, dec1 = rt.allocate_for(np.arange(4), wire, 1e9)
    _, dec2 = rt.allocate_for(np.arange(4), wire, 1e9)  # used to raise
    assert dec2.budget_hz == pytest.approx(dec1.budget_hz)
    assert dec2.total_bandwidth_hz() > 0


def test_async_in_flight_uploads_hold_their_spectrum():
    """The driver path: a straggler keeps its granted subchannel until
    its upload lands, so the next dispatch is carved from what is free —
    the pool is never oversubscribed across overlapping rounds."""
    run = _run("fedavg_sgd", "uniform", rounds=3, mode="async",
               buffer_size=3)
    budgets = [d.budget_hz for d in run.edge.decisions]
    for dec in run.edge.decisions:
        assert dec.total_bandwidth_hz() <= dec.budget_hz * (1 + 1e-9)
    # once stragglers are in flight, later rounds see a smaller pool
    assert min(budgets[1:]) < budgets[0]
    # and the holds match the clients actually still busy
    assert set(run.edge._held_hz) == run.edge.busy


# ----------------------------------------------- vmapped simulator coupling
def test_with_edge_allocates_over_the_fixed_cohort():
    """simulator.with_edge runs only the policy's allocate stage over the
    caller's cohort: bandwidth_opt shrinks the barrier versus uniform at
    identical budget, cohort, and billed bytes."""
    import jax.numpy as jnp
    from repro.edge.runtime import EdgeRuntime
    from repro.fed import simulator, strategies

    train, _ = _data()
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(train.x), size=(6, 32))
    cohort = {"x": jnp.asarray(train.x[idx]), "y": jnp.asarray(train.y[idx])}
    walls = {}
    for policy in ("uniform", "bandwidth_opt"):
        s = strategies.get("fim_lbfgs")(MCFG, FedConfig(num_clients=8,
                                                        seed=0), 10)
        step = simulator.from_strategy(s)
        edge = EdgeRuntime(EdgeConfig(channel=UPLINK, device=HETERO,
                                      scheduler=policy), 8)
        estep = simulator.with_edge(step, edge, s.n_params())
        _, _, stats = estep(s.params, s.opt_state, cohort, jnp.ones(6),
                            clients=np.arange(6))
        walls[policy] = stats["wall_s"]
        dec = edge.decisions[-1]
        assert sorted(dec.selected) == list(range(6))
        assert dec.total_bandwidth_hz() <= dec.budget_hz * (1 + 1e-9)
    assert walls["bandwidth_opt"] < walls["uniform"]


def test_with_edge_rejects_per_client_codecs():
    """Billing per-client wire formats the vmapped path never round-trips
    would pair compressed cost with uncompressed accuracy — refused."""
    import jax.numpy as jnp
    from repro.edge.runtime import EdgeRuntime
    from repro.fed import simulator, strategies

    train, _ = _data()
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(train.x), size=(4, 32))
    cohort = {"x": jnp.asarray(train.x[idx]), "y": jnp.asarray(train.y[idx])}
    s = strategies.get("fim_lbfgs")(MCFG, FedConfig(num_clients=8, seed=0), 10)
    step = simulator.from_strategy(s)
    edge = EdgeRuntime(EdgeConfig(channel=UPLINK, device=HETERO,
                                  scheduler="adaptive_codec"), 8)
    estep = simulator.with_edge(step, edge, s.n_params())
    with pytest.raises(ValueError, match="per-client upload codecs"):
        estep(s.params, s.opt_state, cohort, jnp.ones(4),
              clients=np.arange(4))
