"""repro.edge.fleet — the struct-of-arrays mega-scale engine.

The fleet engine's contract has three layers, tested bottom-up:

  * state/sampling — cohorts are drawn without replacement from the
    alive (charged, non-busy) population only, on both backends;
  * backend agreement — ``backend="exact"`` wraps a real EdgeRuntime
    (bit-identical to the dict path by construction, asserted here
    end-to-end at engine level); ``backend="jit"`` reruns the same
    rounds through the fused x64 lax kernels and must agree to float
    tolerance with IDENTICAL discrete decisions (cohorts, drop counts);
  * round contracts — the PR-3/PR-5 edge cases (empty cohort records
    cohort=0 and leaves the clock alone; an all-dropped round records
    cohort=0 while the clock still advances to the barrier and partial
    energy is billed) hold under the fleet path, including through a
    full ``FederatedRun`` with ``EdgeConfig.fleet="on"``.

The two observability satellites ride along: PlanAudit ``max_rows``
(exact totals, shortfall rows always retained) and the Chrome exporter's
``top_k_clients`` (slowest-finishing clients keep their tracks, the
round track stays complete).
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.edge import (ChannelConfig, DeviceConfig, EdgeConfig,
                        EdgeRuntime, FleetEngine)
from repro.edge.fleet import FleetState
from repro.edge.fleet.kernel import HAVE_JAX
from repro.obs.export import to_chrome
from repro.obs.metrics import PlanAudit
from repro.obs.trace import CAT_CLIENT, CAT_ROUND, Tracer

UPLINK = ChannelConfig(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=3.0,
                       fading="rayleigh", server_rate_bps=50e6)
HETERO = DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=1.0)
UP, DOWN, FLOPS = 80_000.0, 40_000.0, 1e9
POLICIES = ["uniform", "bandwidth_opt", "energy_opt"]

needs_jax = pytest.mark.skipif(not HAVE_JAX, reason="jax unavailable")


def _cfg(policy="uniform", **kw):
    kw.setdefault("deadline_s", 5.0)
    kw.setdefault("min_clients", 1)
    kw.setdefault("enforce_deadline_s", 1.5)
    return EdgeConfig(channel=UPLINK, device=HETERO, scheduler=policy, **kw)


def _engine(policy="uniform", pop=300, backend="exact", seed=0, **kw):
    return FleetEngine(_cfg(policy, **kw), pop, up_bytes=UP, flops=FLOPS,
                       down_bytes=DOWN, seed=seed, backend=backend)


# ------------------------------------------------------------- state layer
def test_fleet_state_draw_and_alive_mask():
    st = FleetState.draw(UPLINK, HETERO, 64, seed=0)
    assert st.population == 64
    assert st.alive_mask().all()          # fresh fleet: charged, not busy
    st.fleet.battery_j[3] = 0.0
    st.busy[5] = True
    mask = st.alive_mask()
    assert not mask[3] and not mask[5] and mask.sum() == 62


@pytest.mark.parametrize("backend", ["exact", pytest.param(
    "jit", marks=needs_jax)])
def test_cohort_without_replacement_from_alive_only(backend):
    eng = _engine("uniform", pop=100, backend=backend)
    eng.state.fleet.battery_j[:20] = 0.0    # shared with the runtime view
    for _ in range(3):
        eng.run_round(50)
        ids = np.asarray(eng.last_decision.selected)
        assert len(ids) == 50
        assert len(np.unique(ids)) == len(ids)          # no replacement
        assert ids.min() >= 20                          # depleted excluded


@needs_jax
def test_busy_mask_respected_on_jit_backend():
    eng = _engine("uniform", pop=40, backend="jit")
    eng.state.busy[:30] = True
    eng.run_round(20)                      # only 10 alive -> short cohort
    ids = np.asarray(eng.last_decision.selected)
    assert set(ids) <= set(range(30, 40)) and len(ids) == 10


# -------------------------------------------------------- backend agreement
@pytest.mark.parametrize("policy", POLICIES)
def test_engine_exact_is_bit_identical_to_dict_runtime(policy):
    """backend='exact' forces the fleet fast path inside its runtime;
    replaying the same rounds on a fleet='off' runtime must land the
    SAME floats — the engine-level version of the determinism lock."""
    eng = _engine(policy, pop=200, backend="exact")
    for _ in range(3):
        eng.run_round(60)

    rt = EdgeRuntime(dataclasses.replace(_cfg(policy), fleet="off"), 200,
                     seed=0)
    for _ in range(3):
        _, est, _ = rt.decide(60, np.arange(200), lambda c=None: (UP, 0.0),
                              FLOPS, summable=True)
        rt.finish_round_sync(est, UP, DOWN, aggregatable=True)
    assert eng.clock_s == rt.clock.now
    assert eng.energy_j == rt.energy_j
    assert eng.deadline_dropped_total == rt.deadline_dropped_total
    assert np.array_equal(eng.state.battery_j, rt.fleet.battery_j)


@needs_jax
@pytest.mark.parametrize("policy", POLICIES)
def test_jit_backend_matches_exact(policy):
    """Same seed, same rounds: the jit backend must draw the SAME
    cohorts and drop the SAME count (discrete decisions identical),
    with clock/energy/battery agreeing to float tolerance (XLA
    reassociation only)."""
    ex = _engine(policy, pop=300, backend="exact")
    jt = _engine(policy, pop=300, backend="jit")
    for _ in range(4):
        ra = ex.run_round(80)
        rb = jt.run_round(80)
        assert (np.asarray(ex.last_decision.selected)
                == np.asarray(jt.last_decision.selected)).all()
        assert ra["dropped"] == rb["dropped"]
        assert np.isclose(ra["wall_s"], rb["wall_s"], rtol=1e-9)
    assert np.isclose(ex.clock_s, jt.clock_s, rtol=1e-9)
    assert np.isclose(ex.energy_j, jt.energy_j, rtol=1e-9)
    assert np.allclose(ex.state.battery_j, jt.state.battery_j, rtol=1e-9)


# -------------------------------------------------------- round contracts
@pytest.mark.parametrize("backend", ["exact", pytest.param(
    "jit", marks=needs_jax)])
def test_empty_cohort_round_records_zero_and_clock_unchanged(backend):
    """All batteries depleted: the round records cohort=0 / dropped=0
    and the clock does not advance (nobody transmitted) — the PR-3
    empty-cohort contract under the fleet path."""
    eng = _engine("uniform", pop=30, backend=backend)
    eng.state.fleet.battery_j[:] = 0.0
    rec = eng.run_round(10)
    assert rec["cohort"] == 0 and rec["dropped"] == 0
    assert eng.clock_s == 0.0 and eng.energy_j == 0.0
    assert eng.last_decision is None or eng.last_decision.n_selected == 0


@pytest.mark.parametrize("backend", ["exact", pytest.param(
    "jit", marks=needs_jax)])
def test_all_dropped_round_bills_partials_and_advances_clock(backend):
    """An infeasibly tight hard deadline drops the whole cohort: the
    record shows cohort=0 with every selected client dropped, the
    barrier is cut at the deadline, and the partial uploads still cost
    energy + clock — the PR-5 all-dropped contract under the fleet
    path."""
    eng = _engine("uniform", pop=50, backend=backend,
                  enforce_deadline_s=0.01)
    rec = eng.run_round(20)
    assert rec["cohort"] == 0 and rec["dropped"] == 20
    assert rec["barrier_s"] <= 0.01 + 1e-6
    assert eng.clock_s > 0.0 and eng.energy_j > 0.0
    assert eng.deadline_dropped_total == 20


def test_fleet_federated_all_dropped_preserves_pr3_contract():
    """Through a full FederatedRun with the fleet path forced on: the
    all-dropped round records cohort=0 with no loss/server step while
    the partial uploads are still billed (tests/test_deadline_
    enforcement.py's contract, fleet edition)."""
    mcfg = reduced(FMNIST_CNN)
    train, test = make_classification(mcfg, n_train=120, n_test=40, seed=0,
                                      noise=0.5)
    edge = _cfg("uniform", enforce_deadline_s=0.01, fleet="on")
    fcfg = FedConfig(num_clients=8, participation=1.0, local_epochs=1,
                     batch_size=32, rounds=2, noniid_l=2, seed=0, edge=edge)
    from repro.fed.server import FederatedRun
    run = FederatedRun(mcfg, fcfg, train, test, "fedavg_sgd")
    hist = run.run(rounds=2, eval_every=2)
    for h in hist:
        assert h["cohort"] == 0
        assert "loss" not in h
        assert h["dropped"] > 0
    assert run.ledger.up_star_bytes > 0.0


# ------------------------------------------------- observability satellites
def test_plan_audit_max_rows_keeps_totals_and_shortfalls():
    a = PlanAudit(max_rows=4)
    for i in range(10):
        a.add(0, i, "up", 100.0, 100.0)       # clean rows
    a.add(1, 99, "up", 100.0, 40.0)           # shortfall: always retained
    assert len(a.rows) == 5                   # 4 clean + the shortfall
    assert a.dropped_rows == 6
    assert a.planned_total() == 1100.0        # totals cover every add
    assert a.billed_total() == 1040.0
    assert any(r.client == 99 and r.billed_bytes == 40.0 for r in a.rows)

    exhaustive = PlanAudit()                  # default: keep everything
    for i in range(10):
        exhaustive.add(0, i, "up", 100.0, 100.0)
    assert len(exhaustive.rows) == 10 and exhaustive.dropped_rows == 0


def test_plan_audit_max_rows_retains_overbilled_rows_for_verify():
    """Over-billing is a bug verify() must still see — those rows are
    never dropped either, even past the cap."""
    a = PlanAudit(max_rows=1)
    a.add(0, 0, "up", 100.0, 100.0)
    a.add(0, 1, "up", 100.0, 150.0)           # above plan: retained
    assert any(r.billed_bytes > r.planned_bytes for r in a.rows)

    class _Ledger:
        up_star_bytes = 250.0

    with pytest.raises(ValueError, match="ABOVE plan"):
        a.verify(_Ledger())


def test_chrome_export_top_k_clients_keeps_stragglers_and_round_track():
    tr = Tracer()
    tr.span("round", CAT_ROUND, 0.0, 10.0, round_id=0)
    finishes = {0: 2.0, 1: 9.0, 2: 7.0, 3: 4.0}
    for c, t1 in finishes.items():
        tr.span("uplink", CAT_CLIENT, 0.0, t1, round_id=0, client=c)

    full = to_chrome(tr, top_k_clients=None)
    capped = to_chrome(tr, top_k_clients=2)
    clients = {e["tid"] - 1 for e in capped["traceEvents"]
               if e.get("ph") == "X" and e["tid"] > 0}
    assert clients == {1, 2}                  # the two slowest finishers
    # the round-level track survives the cap intact
    rounds_full = [e for e in full["traceEvents"]
                   if e.get("ph") == "X" and e["tid"] == 0]
    rounds_capped = [e for e in capped["traceEvents"]
                     if e.get("ph") == "X" and e["tid"] == 0]
    assert rounds_capped == rounds_full and len(rounds_capped) == 1
    # k=0 leaves only the round track
    none_kept = to_chrome(tr, top_k_clients=0)
    assert all(e["tid"] == 0 for e in none_kept["traceEvents"]
               if e.get("ph") == "X")
