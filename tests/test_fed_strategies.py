"""The FedStrategy protocol + registry (repro.fed.strategies): registry
round-trips, RoundPlan == CommLedger actuals for every registered
algorithm, plan-derived async eligibility, FedProx convergence, and
third-party drop-in registration through the generic driver."""
import math

import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.edge import ChannelConfig, DeviceConfig, EdgeConfig
from repro.fed import comm, strategies
from repro.fed.server import FederatedRun

MCFG = reduced(FMNIST_CNN)
ALL_ALGS = ["fim_lbfgs", "fedavg_sgd", "fedavg_adam", "fedprox", "feddane",
            "fedova", "fedova_lbfgs"]


def _data(n_train=300, n_test=100, noise=0.5, seed=0):
    return make_classification(MCFG, n_train=n_train, n_test=n_test,
                               seed=seed, noise=noise)


def _fcfg(**kw):
    base = dict(num_clients=8, participation=1.0, local_epochs=1,
                batch_size=32, rounds=2, noniid_l=2, learning_rate=0.05,
                seed=0)
    base.update(kw)
    return FedConfig(**base)


# ------------------------------------------------------------------ registry
def test_registry_roundtrip():
    assert set(ALL_ALGS) <= set(strategies.names())
    factory = strategies.get("fim_lbfgs")
    s = factory(MCFG, _fcfg(), 10)
    assert isinstance(s, strategies.FedStrategy)
    assert s.name == "fim_lbfgs"


def test_registry_unknown_name_errors():
    with pytest.raises(ValueError, match="unknown federated strategy"):
        strategies.get("fedsgd_typo")
    with pytest.raises(ValueError, match="fedsgd_typo"):
        FederatedRun(MCFG, _fcfg(), *_data(), "fedsgd_typo")


def test_third_party_strategy_drops_in():
    """A strategy registered from outside the package runs through the
    generic driver with zero driver changes (the README example's shape:
    signSGD-style sign-compressed gradient aggregation)."""
    import jax
    import jax.numpy as jnp
    from repro.fed import client as fed_client
    from repro.fed import codecs
    from repro.fed.strategies import (FedStrategy, PhasePlan, RoundPlan,
                                      register)
    from repro.models import cnn

    # n_params / aggregate / evaluate come from the base-class defaults
    @register("_test_signsgd")
    class SignSgdStrategy(FedStrategy):
        def _build(self, key):
            self.params, _ = cnn.init(self.mcfg, key)
            def _loss(p, b):
                return cnn.softmax_loss(p, self.mcfg, b)
            self._loss = _loss
            self._grad = fed_client.make_grad_fim_fn(
                self._loss, None, "microbatch")
            self._eval = jax.jit(
                lambda p, x, y: cnn.accuracy(p, self.mcfg, x, y))

        def _make_plan(self):
            d = self.n_params()
            return RoundPlan(
                # sign payloads are 1 byte/element on the wire: declare the
                # int8 wire format through the codec registry
                phases=(PhasePlan("sign_grad", down_floats=d, up_floats=d,
                                  codec=codecs.make("int8")),),
                flops=lambda n: float(6 * d * n), summable=True)

        def client_step(self, data, rng, context=None):
            xs, ys = data
            g, _, loss = self._grad(self.params,
                                    {"x": jnp.asarray(xs),
                                     "y": jnp.asarray(ys)})
            return jax.tree.map(jnp.sign, g), float(loss)

        def server_step(self, agg):
            self.params = jax.tree.map(
                lambda p, g: p - 0.01 * jnp.sign(g).astype(p.dtype),
                self.params, agg)

    try:
        train, test = _data()
        run = FederatedRun(MCFG, _fcfg(rounds=3), train, test,
                           "_test_signsgd")
        hist = run.run(rounds=3, eval_every=3)
        assert np.isfinite(hist[-1]["loss"])
        assert hist[-1]["accuracy"] >= 0.0
        # int8-width uploads reach the ledger via the plan
        k = sum(len(run.partition[i]) > 0
                for i in range(run.fcfg.num_clients))
        assert run.ledger.up_star_bytes == pytest.approx(
            run.plan.upload_bytes() * k * 3)
    finally:
        strategies.base._REGISTRY.pop("_test_signsgd", None)


# ------------------------------------------------- plan == ledger actuals
def _expected_ledger(plan, k, rounds):
    """Independently re-derive CommLedger fields from a RoundPlan."""
    down = up_star = up_tree = scalars = 0.0
    for ph in plan.phases:
        wire = ph.codec.wire_bytes(ph.up_floats)
        down += ph.down_floats * comm.BYTES_F32 * k
        up_star += wire * k
        if ph.aggregatable:
            depth = max(1, math.ceil(math.log2(max(k, 2))))
            up_tree += wire * depth
        else:
            up_tree += wire * k
    scalars = (plan.round_scalars + plan.scalars_per_client * k) * comm.BYTES_F32
    return {f: v * rounds for f, v in zip(
        ("down_bytes", "up_star_bytes", "up_tree_bytes", "scalar_bytes"),
        (down, up_star, up_tree, scalars), strict=True)}


@pytest.mark.parametrize("alg", ALL_ALGS)
def test_roundplan_matches_ledger_actuals(alg):
    train, test = _data()
    rounds = 2
    run = FederatedRun(MCFG, _fcfg(rounds=rounds), train, test, alg)
    run.run(rounds=rounds, eval_every=rounds)
    # participation=1.0: the cohort is every client with a non-empty shard
    k = sum(len(run.partition[i]) > 0 for i in range(run.fcfg.num_clients))
    expect = _expected_ledger(run.plan, k, rounds)
    for f, v in expect.items():
        assert getattr(run.ledger, f) == pytest.approx(v), (alg, f)
    assert run.ledger.rounds == rounds


def test_roundplan_int8_width_reaches_ledger():
    train, test = _data()
    run = FederatedRun(MCFG, _fcfg(compress="int8"), train, test,
                       "fim_lbfgs")
    run.run(rounds=1, eval_every=1)
    d = run.strategy.n_params()
    k = sum(len(run.partition[i]) > 0 for i in range(run.fcfg.num_clients))
    assert run.plan.upload_bytes() == 2 * d * comm.BYTES_INT8
    assert run.ledger.up_star_bytes == pytest.approx(2 * d * comm.BYTES_INT8 * k)


# --------------------------------------------- async eligibility from plan
def test_async_eligibility_is_plan_derived():
    summable = {a: strategies.get(a)(MCFG, _fcfg(), 10).round_plan().summable
                for a in ALL_ALGS}
    assert summable == {"fim_lbfgs": True, "fedavg_sgd": True,
                        "fedavg_adam": True, "fedprox": True,
                        "feddane": False, "fedova": False,
                        "fedova_lbfgs": False}


@pytest.mark.parametrize("alg", ["feddane", "fedova"])
def test_async_rejected_for_nonsummable_plans(alg):
    train, test = _data()
    with pytest.raises(ValueError, match="summable"):
        FederatedRun(MCFG, _fcfg(edge=EdgeConfig(mode="async")),
                     train, test, alg)


def test_async_accepted_for_fedprox():
    """FedProx never existed when the async check was written — async
    eligibility now falls out of its plan, not an algorithm-name list."""
    train, test = _data()
    edge = EdgeConfig(channel=ChannelConfig(bandwidth_hz=2e5, fading="none"),
                      device=DeviceConfig(flops_per_s_mean=2e9,
                                          flops_per_s_sigma=1.2),
                      mode="async", buffer_size=4)
    run = FederatedRun(MCFG, _fcfg(rounds=3, edge=edge), train, test,
                       "fedprox")
    hist = run.run(rounds=3, eval_every=3)
    assert np.isfinite([h["loss"] for h in hist]).all()
    assert run.edge.summary()["wall_clock_s"] > 0


# ----------------------------------------------------------------- fedprox
def test_fedprox_converges():
    """Smoke convergence through the generic round loop: well above chance
    (10 classes) after a few rounds on low-noise data."""
    train, test = _data(n_train=800, n_test=200, noise=0.35)
    fcfg = _fcfg(num_clients=10, participation=0.5, local_epochs=2,
                 batch_size=16, rounds=6, prox_mu=0.1)
    run = FederatedRun(MCFG, fcfg, train, test, "fedprox")
    hist = run.run(rounds=6, eval_every=6)
    assert hist[-1]["accuracy"] > 0.4, hist[-1]


def test_fedprox_mu_zero_matches_fedavg():
    """With mu=0 the proximal term vanishes: FedProx == FedAvg-SGD."""
    train, test = _data()
    out = {}
    for alg in ("fedprox", "fedavg_sgd"):
        run = FederatedRun(MCFG, _fcfg(rounds=2, prox_mu=0.0), train, test, alg)
        hist = run.run(rounds=2, eval_every=2)
        out[alg] = (hist[-1]["loss"], hist[-1]["accuracy"])
    assert out["fedprox"][0] == pytest.approx(out["fedavg_sgd"][0], rel=1e-4)
    assert out["fedprox"][1] == pytest.approx(out["fedavg_sgd"][1], abs=0.02)


# ---------------------------------------------------------- config fields
def test_fedconfig_validates_promoted_fields():
    with pytest.raises(ValueError, match="compress"):
        FedConfig(compress="int4")
    with pytest.raises(ValueError, match="fim_mode"):
        FedConfig(fim_mode="kfac")
    with pytest.raises(ValueError, match="participation"):
        FedConfig(participation=0.0)
    with pytest.raises(ValueError, match="prox_mu"):
        FedConfig(prox_mu=-1.0)
    cfg = FedConfig(compress="int8", fim_mode="microbatch")
    assert cfg.compress == "int8" and cfg.fim_mode == "microbatch"


def test_fim_mode_threads_through_strategy():
    train, test = _data()
    run = FederatedRun(MCFG, _fcfg(fim_mode="microbatch"), train, test,
                       "fim_lbfgs")
    hist = run.run(rounds=2, eval_every=2)
    assert np.isfinite(hist[-1]["loss"])


# ----------------------------------------------------- simulator coupling
def test_simulator_round_step_from_strategy():
    """The vmapped cohort path derives from the same strategy object the
    sequential driver uses (no copy-pasted client_fn)."""
    import jax
    import jax.numpy as jnp
    from repro.fed import simulator

    s = strategies.get("fim_lbfgs")(MCFG, _fcfg(), 10)
    step = simulator.from_strategy(s)
    train, _ = _data()
    rng = np.random.default_rng(0)
    params, opt = s.params, s.opt_state
    losses = []
    for _ in range(3):
        idx = rng.integers(0, len(train.x), size=(6, 32))
        cohort = {"x": jnp.asarray(train.x[idx]),
                  "y": jnp.asarray(train.y[idx])}
        params, opt, stats = step(params, opt, cohort, jnp.ones(6))
        losses.append(float(stats["loss"]))
    assert losses[-1] < losses[0], losses

    sgd = strategies.get("fedavg_sgd")(MCFG, _fcfg(), 10)
    with pytest.raises(NotImplementedError, match="cohort"):
        simulator.from_strategy(sgd)
