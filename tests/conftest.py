import os
import sys

# Tests must see exactly ONE CPU device (the dry run manages its own
# 512-device flag inside a subprocess) and deterministic platform choice.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
