"""repro.edge.scenario: churn, fault injection, mid-round re-allocation.

The ISSUE-9 acceptance surface:

  * availability masks are honored by EVERY registered allocation
    policy — an off client can neither be selected nor policy-excluded
    (it never reaches the policy at all);
  * the fleet fast path stays bit-identical to the per-client dict path
    under ``diurnal``/``markov`` churn (the test_determinism.py matrix,
    extended here to the standalone FleetEngine exact↔jit pair);
  * opt-in re-allocation strictly shrinks the realized barrier on a
    seeded straggler case — drops, billing, and ``PlanAudit.verify``
    untouched;
  * an all-unavailable round satisfies the empty-cohort contract
    (zero-byte, zero-time round; the run never raises);
  * the spec-string grammar and the process/fault registries.
"""
import json

import numpy as np
import pytest

from repro import obs
from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.edge import ChannelConfig, DeviceConfig, EdgeConfig, allocation
from repro.edge.fleet.engine import FleetEngine
from repro.edge.runtime import EdgeRuntime
from repro.edge.scenario import (Diurnal, RoundEffects, Scenario,
                                 fault_names, make_scenario, parse_spec,
                                 process_names)
from repro.fed.server import FederatedRun

MCFG = reduced(FMNIST_CNN)
UPLINK = ChannelConfig(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=3.0,
                       fading="rayleigh", server_rate_bps=50e6)
HETERO = DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=1.0)

# the seeded straggler case every re-allocation assertion runs on:
# a tight deadline admits few clients (grant = deadline), min_clients
# force-keeps the rest (grant = inf), and realized-side SNR bursts cut
# admitted clients mid-flight — freeing width while force-kept
# stragglers are still on the air
STRAGGLER = dict(scheduler="deadline", deadline_s=0.2, min_clients=6,
                 scenario="snr_burst:prob=0.6,scale=0.05")
STRAGGLER_FLEET = dict(population=16, up_bytes=4000.0, flops=2e8, seed=0)


def _rt(population=12, seed=0, **edge_kw):
    kw = dict(channel=UPLINK, device=HETERO)
    kw.update(edge_kw)
    return EdgeRuntime(EdgeConfig(**kw), population, seed=seed)


def _decide(rt, k=6):
    return rt.decide(k, np.arange(rt.num_clients),
                     lambda codec=None: (4000.0, 0.0), 2e8)


# ---------------------------------------------------------------------------
# availability masks reach every registered policy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", sorted(allocation.names()))
def test_masks_honored_by_every_policy(policy, tmp_path):
    """An unavailable client must never appear in a decision — selected
    OR excluded — no matter which policy runs: availability filters the
    eligible set before the policy sees it."""
    off = {1, 4, 7, 9}
    trace = tmp_path / "avail.jsonl"
    trace.write_text(json.dumps({"t": 0.0, "off": sorted(off)}) + "\n")
    rt = _rt(scheduler=policy, deadline_s=5.0, min_clients=1,
             battery_floor_j=1.0, adaptive_ratio=0.25,
             scenario=f"trace:{trace}")
    for _ in range(3):
        _, est, dec = _decide(rt)
        touched = set(dec.selected) | set(dec.excluded)
        assert touched.isdisjoint(off), (policy, sorted(touched & off))
        rt.finish_round_sync(est, 4000.0, 0.0)
    assert rt.unavailable_total == 3 * len(off)
    assert rt.drop_reasons.get("unavailable") == 3 * len(off)


def test_shedding_scales_allocation_visible_workload():
    """data_exclusion shrinks the FLOPs/payload the policy sizes
    against, and the estimate's air time with it — nothing is billed
    differently (the ledger invariant is about the committed plan)."""
    base = _rt(scheduler="uniform", seed=3)
    shed = _rt(scheduler="uniform", seed=3, scenario="data_exclusion:0.4")
    _, est_b, dec_b = _decide(base)
    _, est_s, dec_s = _decide(shed)
    assert list(dec_b.selected) == list(dec_s.selected)
    assert np.all(est_s.time_s <= est_b.time_s)
    assert np.any(est_s.time_s < est_b.time_s)


# ---------------------------------------------------------------------------
# fleet engine: exact (dict-path) vs jit under churn
# ---------------------------------------------------------------------------
CHURN_SPECS = [
    "markov:p_drop=0.2,p_join=0.4",
    "diurnal:period=6,amp=0.5,base=0.6,unit=round",
    ("markov:p_drop=0.2,p_join=0.4|snr_burst:prob=0.6,scale=0.05|"
     "data_exclusion:0.7"),
]


@pytest.mark.parametrize("spec", CHURN_SPECS)
@pytest.mark.parametrize("reallocate", [False, True])
def test_fleet_jit_matches_exact_under_churn(spec, reallocate):
    """The x64 jit kernel path must agree with the exact (EdgeRuntime)
    backend under churn + faults + re-allocation: identical cohorts,
    drop counts, and reason buckets; clocks equal to float tolerance.
    (Clock-reading processes are pinned to round units here — the
    bit-exact subset; test_determinism.py covers the dict path.)"""
    hists, sums = [], []
    for backend in ("exact", "jit"):
        cfg = EdgeConfig(channel=UPLINK, device=HETERO, reallocate=reallocate,
                         scenario=spec, **{k: v for k, v in STRAGGLER.items()
                                           if k != "scenario"})
        eng = FleetEngine(cfg, STRAGGLER_FLEET["population"],
                          up_bytes=STRAGGLER_FLEET["up_bytes"],
                          flops=STRAGGLER_FLEET["flops"],
                          seed=STRAGGLER_FLEET["seed"], backend=backend)
        eng.run(6, 8)
        hists.append(eng.history)
        sums.append(eng.summary())
    for a, b in zip(hists[0], hists[1], strict=True):
        assert a["cohort"] == b["cohort"]
        assert a["dropped"] == b["dropped"]
        assert a["clock_s"] == pytest.approx(b["clock_s"], rel=1e-9)
    assert sums[0]["drop_reasons"] == sums[1]["drop_reasons"]
    assert sums[0]["unavailable_total"] == sums[1]["unavailable_total"]
    assert sums[0]["realloc_rounds"] == sums[1]["realloc_rounds"]


# ---------------------------------------------------------------------------
# re-allocation: strictly smaller realized barrier, same everything else
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["exact", "jit"])
def test_reallocation_shrinks_barrier(backend):
    res = {}
    for realloc in (False, True):
        cfg = EdgeConfig(channel=UPLINK, device=HETERO, reallocate=realloc,
                         **STRAGGLER)
        eng = FleetEngine(cfg, STRAGGLER_FLEET["population"],
                          up_bytes=STRAGGLER_FLEET["up_bytes"],
                          flops=STRAGGLER_FLEET["flops"],
                          seed=STRAGGLER_FLEET["seed"], backend=backend)
        eng.run(8, 8)
        res[realloc] = eng
    off, on = res[False], res[True]
    # the drop/cohort story is untouched — re-allocation is realized-
    # side only
    assert off.dropped_total == on.dropped_total
    assert off.deadline_dropped_total == on.deadline_dropped_total
    assert [h["cohort"] for h in off.history] == \
        [h["cohort"] for h in on.history]
    bar_off = [h["barrier_s"] for h in off.history if "barrier_s" in h]
    bar_on = [h["barrier_s"] for h in on.history if "barrier_s" in h]
    assert all(b <= a + 1e-12 for a, b in zip(bar_off, bar_on, strict=True))
    assert any(b < a for a, b in zip(bar_off, bar_on))
    assert on.clock_s < off.clock_s
    assert on.summary()["realloc_rounds"] > 0


def test_reallocation_audit_and_billing_hold():
    """Through a full traced FederatedRun: PlanAudit.verify still passes
    with re-allocation on, and billed bytes match the run without it."""
    train, test = make_classification(MCFG, n_train=300, n_test=100,
                                      seed=0, noise=0.5)
    led = {}
    for realloc in (False, True):
        edge = EdgeConfig(channel=UPLINK, device=HETERO, reallocate=realloc,
                          scheduler="deadline", deadline_s=1.0,
                          min_clients=4,
                          scenario="snr_burst:prob=0.5,scale=0.05")
        fcfg = FedConfig(num_clients=8, participation=1.0, local_epochs=1,
                         batch_size=32, rounds=3, noniid_l=2, seed=0,
                         edge=edge)
        tracer = obs.Tracer(sink=lambda line: None)
        run = FederatedRun(MCFG, fcfg, train, test, "fedavg_sgd",
                           tracer=tracer)
        run.run(rounds=3, eval_every=3)
        tracer.audit.verify(run.ledger)
        led[realloc] = run.ledger.up_star_bytes
    assert led[False] == led[True]


# ---------------------------------------------------------------------------
# all-unavailable rounds: the empty-cohort contract
# ---------------------------------------------------------------------------
def test_all_unavailable_round_is_empty_cohort():
    rt = _rt(scheduler="uniform", scenario="blackout:start=0,end=1e9")
    cohort, est, dec = _decide(rt)
    assert cohort == [] and dec.n_selected == 0 and est.clients.size == 0
    rec = rt.finish_round_sync(est, 4000.0, 0.0)
    assert rec["cohort"] == 0 and rec["wall_s"] == 0.0
    assert rt.clock.now == 0.0 and rt.energy_j == 0.0
    assert rt.drop_reasons.get("fault") == 12


def test_all_unavailable_round_fleet_jit():
    cfg = EdgeConfig(channel=UPLINK, device=HETERO, scheduler="uniform",
                     scenario="blackout:start=0,end=1e9")
    eng = FleetEngine(cfg, 32, up_bytes=4000.0, flops=2e8, seed=0,
                      backend="jit")
    rec = eng.run_round(8)
    assert rec["cohort"] == 0 and rec["wall_s"] == 0.0
    assert eng.clock_s == 0.0 and eng.energy_j == 0.0


def test_empty_cohort_federated_run_survives():
    """A FederatedRun whose every round is all-off must complete with a
    zero-byte ledger (the PR-3/PR-5 empty-cohort contract)."""
    train, test = make_classification(MCFG, n_train=200, n_test=50,
                                      seed=0, noise=0.5)
    edge = EdgeConfig(channel=UPLINK, device=HETERO,
                      scenario="blackout:start=0,end=1e9")
    fcfg = FedConfig(num_clients=6, participation=1.0, local_epochs=1,
                     batch_size=32, rounds=2, noniid_l=2, seed=0, edge=edge)
    run = FederatedRun(MCFG, fcfg, train, test, "fedavg_sgd")
    run.run(rounds=2, eval_every=2)
    assert run.ledger.up_star_bytes == 0.0
    assert run.edge.clock.now == 0.0


# ---------------------------------------------------------------------------
# spec grammar + registries
# ---------------------------------------------------------------------------
def test_registries_list_builtins():
    assert {"always_on", "diurnal", "markov", "trace"} <= \
        set(process_names())
    assert {"blackout", "snr_burst", "straggler", "battery_gate",
            "data_exclusion"} <= set(fault_names())


def test_parse_spec_components():
    avail, faults = parse_spec(
        "diurnal:period=600,amp=0.3,base=0.7,unit=round|"
        "snr_burst:prob=0.2,scale=0.5|data_exclusion:0.5")
    assert avail.name == "diurnal" and avail.period == 600.0
    assert avail.unit == "round"
    assert [f.name for f in faults] == ["snr_burst", "data_exclusion"]
    assert faults[1].thresh == 0.5          # positional form
    # default process when the spec names only faults
    avail, _ = parse_spec("snr_burst:prob=0.1")
    assert avail.name == "always_on"


@pytest.mark.parametrize("bad,match", [
    ("diurnal|markov", "two"),
    ("waterfilling", "unknown scenario component"),
    ("snr_burst:prob=0.1,nope=2", "does not accept"),
    ("snr_burst:prob=0.1,x", "key=val"),
    ("diurnal:unit=hours", "unit"),
    ("data_exclusion:0", "threshold"),
])
def test_parse_spec_rejects(bad, match):
    with pytest.raises(ValueError, match=match):
        parse_spec(bad)


def test_make_scenario_population_checks():
    sc = make_scenario("markov:p_drop=0.1,p_join=0.3", 16, seed=1)
    assert isinstance(sc, Scenario)
    assert make_scenario(sc, 16) is sc
    with pytest.raises(ValueError, match="population"):
        make_scenario(sc, 32)


def test_trace_process_requires_sorted_records(tmp_path):
    p = tmp_path / "t.jsonl"
    p.write_text(json.dumps({"t": 5.0, "off": [0]}) + "\n"
                 + json.dumps({"t": 1.0, "on": [0]}) + "\n")
    with pytest.raises(ValueError, match="sorted"):
        parse_spec(f"trace:{p}")


def test_diurnal_round_unit_ignores_clock():
    """unit='round' must be invariant to the simulated time handed in —
    the property Part F's A/B comparison and jit parity rely on."""
    pop = 64
    masks = []
    for t in (0.0, 1234.5):
        d = Diurnal(period=8, amp=0.5, base=0.6, unit="round")
        rng = np.random.default_rng(7)
        d.reset(pop, rng)
        masks.append([d.mask(i, t * (i + 1), rng) for i in range(5)])
    for a, b in zip(*masks, strict=True):
        assert np.array_equal(a, b)


def test_scenario_rng_stream_is_isolated():
    """Enabling a scenario must not perturb the channel/fleet/cohort
    draws: the same seed with and without a scenario yields the same
    selected cohorts whenever everyone happens to be available."""
    a = _rt(scheduler="uniform", seed=5)
    b = _rt(scheduler="uniform", seed=5, scenario="always_on")
    for _ in range(3):
        _, est_a, dec_a = _decide(a)
        _, est_b, dec_b = _decide(b)
        assert list(dec_a.selected) == list(dec_b.selected)
        assert np.array_equal(est_a.time_s, est_b.time_s)
        a.finish_round_sync(est_a, 4000.0, 0.0)
        b.finish_round_sync(est_b, 4000.0, 0.0)


def test_round_effects_composition():
    eff = RoundEffects(proc_off=np.array([True, False, False]),
                       fault_off=np.array([False, True, False]),
                       snr_scale=np.ones(3), compute_scale=np.ones(3),
                       workload_frac=np.ones(3))
    assert list(eff.available) == [False, False, True]
    assert not eff.has_channel_fault and not eff.has_shedding
