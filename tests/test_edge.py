"""Unit + integration tests for repro.edge (the resource-constrained
wireless runtime): channel/device cost monotonicity, allocation policies,
staleness weighting, the event clock, and sync-vs-async end-to-end."""
import numpy as np
import pytest

from repro.edge import (AsyncAggregator, CapacityProportionalPolicy, Channel,
                        ChannelConfig, ClientEstimate, DeadlinePolicy,
                        DeviceConfig, DeviceFleet, EdgeConfig,
                        EnergyThresholdPolicy, EventClock, RoundState,
                        UniformPolicy, staleness_weights)
from repro.edge.device import flops_grad_fim, flops_local_sgd


# ---------------------------------------------------------------- channel
def test_uplink_time_monotone_in_bytes():
    ch = Channel(ChannelConfig(fading="none"), num_clients=8, seed=0)
    t1 = ch.uplink_time_s(1e6, range(8))
    t2 = ch.uplink_time_s(2e6, range(8))
    assert (t2 > t1).all()
    np.testing.assert_allclose(t2, 2 * t1, rtol=1e-12)


def test_uplink_time_monotone_in_snr():
    slow = Channel(ChannelConfig(snr_db_mean=0.0, snr_db_std=0.0,
                                 fading="none"), 8, seed=0)
    fast = Channel(ChannelConfig(snr_db_mean=20.0, snr_db_std=0.0,
                                 fading="none"), 8, seed=0)
    assert (fast.uplink_time_s(1e6, range(8))
            < slow.uplink_time_s(1e6, range(8))).all()


def test_uplink_energy_is_power_times_time():
    cfg = ChannelConfig(tx_power_w=0.25, fading="none")
    ch = Channel(cfg, 4, seed=1)
    t = ch.uplink_time_s(5e5, range(4))
    np.testing.assert_allclose(ch.uplink_energy_j(5e5, range(4)), 0.25 * t)


def test_tree_round_time_scales_with_depth():
    cfg = ChannelConfig(fading="none", snr_db_std=0.0, topology="tree")
    ch = Channel(cfg, 16, seed=0)
    hop = float(ch.uplink_time_s(1e6, range(16)).max())  # homogeneous fleet
    drain = 8e6 / cfg.server_rate_bps
    # aggregatable: ceil(log2 16) = 4 hops + ONE payload over the server slice
    assert ch.comm_round_time_s(1e6, range(16)) == pytest.approx(4 * hop + drain)
    # non-aggregatable: all 16 payloads still cross the root link
    assert (ch.comm_round_time_s(1e6, range(16), aggregatable=False)
            == pytest.approx(4 * hop + 16 * drain))


def test_star_round_time_bottlenecks_on_server_slice():
    cfg = ChannelConfig(fading="none", snr_db_std=0.0, server_rate_bps=1e6)
    ch = Channel(cfg, 8, seed=0)
    air = float(ch.uplink_time_s(1e6, range(8)).max())
    assert ch.comm_round_time_s(1e6, range(8)) == pytest.approx(
        max(air, 8 * 8e6 / 1e6))
    # doubling the cohort doubles the shared-slice drain
    ch16 = Channel(cfg, 16, seed=0)
    assert (ch16.comm_round_time_s(1e6, range(16))
            > ch.comm_round_time_s(1e6, range(8)))


def test_fading_redraws_rates():
    ch = Channel(ChannelConfig(fading="rayleigh"), 32, seed=0)
    r1 = ch.rates_bps.copy()
    r2 = ch.sample()
    assert not np.allclose(r1, r2)


# ----------------------------------------------------------------- device
def test_compute_time_monotone_in_flops():
    fleet = DeviceFleet(DeviceConfig(), 8, seed=0)
    assert (fleet.compute_time_s(2e9, range(8))
            > fleet.compute_time_s(1e9, range(8))).all()
    assert flops_grad_fim(1000, 50) > flops_local_sgd(1000, 50, 1) / 6 * 2
    assert flops_local_sgd(1000, 50, 4) == 4 * flops_local_sgd(1000, 50, 1)


def test_battery_drains_and_floors_at_zero():
    fleet = DeviceFleet(DeviceConfig(battery_j=10.0), 4, seed=0)
    fleet.spend([0, 1], [4.0, 25.0])
    assert fleet.battery_j[0] == pytest.approx(6.0)
    assert fleet.battery_j[1] == 0.0
    assert list(fleet.alive([0, 1, 2])) == [0, 2]


def test_fleet_heterogeneity():
    fleet = DeviceFleet(DeviceConfig(flops_per_s_sigma=1.0), 64, seed=0)
    assert fleet.flops_per_s.max() / fleet.flops_per_s.min() > 3.0
    homog = DeviceFleet(DeviceConfig(flops_per_s_sigma=0.0), 64, seed=0)
    assert np.ptp(homog.flops_per_s) == 0.0


# ----------------------------------------------------- allocation policies
def _est(times, energies=None, batteries=None):
    n = len(times)
    return ClientEstimate(
        clients=np.arange(n), time_s=np.asarray(times, float),
        energy_j=np.asarray(energies if energies is not None else [1.0] * n),
        battery_j=np.asarray(batteries if batteries is not None
                             else [np.inf] * n))


def _state(times, energies=None, batteries=None, k=None, budget_hz=8e5,
           t_comp=None, spectral_eff=None, up_bytes=0.0, summable=True,
           seed=0):
    n = len(times)

    def wire_fn(codec=None):
        # base format: dense float32 (up_bytes); overrides bill their own
        if codec is None:
            return float(up_bytes), 0.0
        return float(codec.wire_bytes(up_bytes / 4.0)), 0.0

    return RoundState(
        k=n if k is None else k,
        est=_est(times, energies, batteries),
        t_comp_s=np.asarray(t_comp if t_comp is not None else [0.0] * n,
                            dtype=float),
        spectral_eff=np.asarray(spectral_eff if spectral_eff is not None
                                else [1.0] * n, dtype=float),
        budget_hz=budget_hz, rng=np.random.default_rng(seed),
        summable=summable, wire_fn=wire_fn)


def test_uniform_policy_selects_k_and_splits_budget():
    dec = UniformPolicy().decide(_state([1.0] * 10, k=3, budget_hz=9e5))
    assert len(dec.selected) == 3 and dec.excluded == {}
    np.testing.assert_allclose(dec.bandwidth(), 3e5)
    assert dec.total_bandwidth_hz() <= dec.budget_hz * (1 + 1e-9)


def test_deadline_policy_drops_stragglers_with_reasons():
    dec = DeadlinePolicy(deadline_s=1.0).decide(
        _state([0.1, 0.2, 10.0, 0.3, 20.0]))
    assert sorted(dec.selected) == [0, 1, 3]
    assert sorted(dec.excluded) == [2, 4]
    assert all("deadline" in why for why in dec.excluded.values())
    # survivors inherit the dropped clients' budget share and the deadline
    np.testing.assert_allclose(dec.bandwidth(), dec.budget_hz / 3)
    assert all(a.deadline_s == 1.0 for a in dec.allocations.values())


def test_deadline_policy_keeps_min_clients():
    dec = DeadlinePolicy(deadline_s=1.0, min_clients=2).decide(
        _state([5.0, 9.0, 7.0]))
    assert sorted(dec.selected) == [0, 2]  # two fastest despite the deadline


def test_energy_threshold_excludes_depleted_and_expensive():
    dec = EnergyThresholdPolicy(battery_floor_j=0.1, round_budget_j=2.0
                                ).decide(_state([1.0] * 4,
                                                energies=[0.5, 0.5, 5.0, 0.5],
                                                batteries=[10.0, 0.05,
                                                           10.0, 10.0]))
    assert sorted(dec.selected) == [0, 3]
    assert sorted(dec.excluded) == [1, 2]  # 1 depleted, 2 over budget
    assert "floor" in dec.excluded[1] and "budget" in dec.excluded[2]


def test_capacity_proportional_prefers_fast_clients():
    hits = 0
    for trial in range(50):
        dec = CapacityProportionalPolicy().decide(
            _state([0.01] + [10.0] * 9, k=1, seed=trial))
        hits += 0 in dec.selected
    assert hits > 45  # fast client ~1000x more likely than any slow one


def test_for_ids_unknown_id_raises_clear_valueerror():
    """Satellite regression: asking for an id outside the eligible set
    used to surface as an opaque KeyError from the position lookup."""
    est = _est([1.0, 2.0, 3.0])
    with pytest.raises(ValueError, match="client id 7"):
        est.for_ids([0, 7])


def test_legacy_scheduler_names_still_work():
    """The make_scheduler-era surface: old names and classes resolve to
    the uniform-split allocation policies."""
    from repro.edge import (CapacityProportionalScheduler, DeadlineScheduler,
                            EnergyThresholdScheduler, UniformScheduler,
                            make_scheduler)

    assert UniformScheduler is UniformPolicy
    assert DeadlineScheduler is DeadlinePolicy
    assert EnergyThresholdScheduler is EnergyThresholdPolicy
    assert CapacityProportionalScheduler is CapacityProportionalPolicy
    sched = make_scheduler("deadline", deadline_s=2.0, min_clients=3)
    assert isinstance(sched, DeadlinePolicy)
    assert sched.deadline_s == 2.0 and sched.min_clients == 3
    with pytest.raises(ValueError, match="unknown allocation policy"):
        make_scheduler("round_robin")


def test_bandwidth_opt_minimizes_the_sync_barrier():
    """The arXiv:1910.13067 convex program: under heterogeneous compute
    times the bisection allocation strictly beats the equal split's
    barrier max_k (t_comp,k + bits/(s_k W_k)) at the same total budget."""
    from repro.edge import BandwidthOptPolicy

    bits = 8.0 * 1e5
    state = _state([1.0] * 6, t_comp=[0.1, 0.4, 0.9, 0.2, 0.6, 0.05],
                   spectral_eff=[2.0, 1.0, 0.5, 3.0, 1.5, 4.0],
                   up_bytes=1e5, budget_hz=6e5)
    dec = BandwidthOptPolicy().decide(state)
    assert sorted(dec.selected) == list(range(6))
    w = dec.bandwidth(range(6))
    assert (w > 0).all()
    assert dec.total_bandwidth_hz() == pytest.approx(6e5)
    s = np.asarray([2.0, 1.0, 0.5, 3.0, 1.5, 4.0])
    tc = np.asarray([0.1, 0.4, 0.9, 0.2, 0.6, 0.05])
    t_opt = tc + bits / (s * w)
    t_uni = tc + bits / (s * 1e5)
    assert t_opt.max() < t_uni.max()
    # the optimum equalizes finish times (within bisection tolerance)
    assert t_opt.max() - t_opt.min() < 1e-3 * t_opt.max()


def test_adaptive_codec_schedules_ratio_from_rate():
    from repro.edge import AdaptiveCodecPolicy

    pol = AdaptiveCodecPolicy(ratio=0.25, ratio_floor=0.05)
    dec = pol.decide(_state([1.0] * 5,
                            spectral_eff=[4.0, 2.0, 1.0, 0.25, 0.01],
                            up_bytes=1e5, budget_hz=5e5))
    # the two fastest links schedule ratios 1.0 / 0.5, whose 8 B/element
    # top-k wire format costs >= the dense 4 B/element payload — the
    # dominated format falls back to the base codec (sparsifying is only
    # ever a discount)
    assert dec.codec_for(0) is None and dec.codec_for(1) is None
    ratios = {i: dec.codec_for(i).ratio for i in (2, 3, 4)}
    assert ratios[2] == pytest.approx(0.25 * 1.0 / 1.0)  # median rate
    assert ratios[2] > ratios[3] > ratios[4]  # slower links, sparser uploads
    assert ratios[4] == 0.05  # the deep-fade client hits the floor
    n_floats = 1e5 / 4.0
    assert all(dec.codec_for(i).wire_bytes(n_floats) < 1e5 for i in (2, 3, 4))
    with pytest.raises(ValueError, match="summable"):
        pol.decide(_state([1.0] * 5, up_bytes=1e5, summable=False))


# -------------------------------------------------------- async staleness
def test_staleness_weights_sum_to_one_and_discount():
    w = staleness_weights([10, 10, 10], [0, 1, 4], alpha=0.5)
    assert w.sum() == pytest.approx(1.0)
    assert w[0] > w[1] > w[2]
    flat = staleness_weights([2, 1], [3, 3], alpha=0.0)  # alpha=0: n_i only
    np.testing.assert_allclose(flat, [2 / 3, 1 / 3])
    assert staleness_weights([], [], 0.5).size == 0


def test_event_clock_orders_and_advances():
    clk = EventClock()
    clk.push(5.0, "b")
    clk.push(1.0, "a")
    clk.push_after(2.0, "c")
    assert [clk.pop().kind for _ in range(3)] == ["a", "c", "b"]
    assert clk.now == 5.0
    with pytest.raises(ValueError):
        clk.push(1.0)  # in the past
    with pytest.raises(ValueError):
        clk.advance(-1.0)


def test_in_flight_counts_only_pending_client_uploads():
    """Regression: in_flight used to report len(clock) — ANY pending
    event inflated it.  The explicit counter tracks submissions only."""
    clk = EventClock()
    agg = AsyncAggregator(clk, buffer_size=2)
    agg.submit(0, 1.0, 10, "a")
    agg.submit(1, 2.0, 10, "b")
    agg.submit(2, 3.0, 10, "c")
    clk.push(0.5, kind="battery_report")  # unrelated event on the shared clock
    assert len(clk) == 4
    assert agg.in_flight == 3
    entries, _ = agg.pop_buffer()
    assert len(entries) == 2 and agg.in_flight == 1
    entries, _ = agg.pop_buffer()
    assert len(entries) == 1 and agg.in_flight == 0


def test_async_aggregator_buffers_in_arrival_order():
    clk = EventClock()
    agg = AsyncAggregator(clk, buffer_size=2, alpha=0.5)
    agg.submit(0, 3.0, 10, "slow")
    agg.submit(1, 1.0, 10, "fast")
    agg.submit(2, 2.0, 10, "mid")
    entries, w = agg.pop_buffer()
    assert [e.payload for e in entries] == ["fast", "mid"]
    assert clk.now == pytest.approx(2.0)       # waits for 2nd arrival only
    assert w.sum() == pytest.approx(1.0)
    assert agg.version == 1 and agg.in_flight == 1
    # the straggler lands in the next buffer, one version stale
    entries2, w2 = agg.pop_buffer()
    assert [e.payload for e in entries2] == ["slow"]
    assert entries2[0].version == 0 and agg.version == 2


# ------------------------------------------------------------ end-to-end
def _fed_run(edge, alg="fim_lbfgs", rounds=3, seed=0):
    from repro.configs.base import FedConfig
    from repro.configs.paper_models import FMNIST_CNN, reduced
    from repro.data.synthetic import make_classification
    from repro.fed.server import FederatedRun

    mcfg = reduced(FMNIST_CNN)
    train, test = make_classification(mcfg, n_train=400, n_test=100,
                                      seed=seed, noise=0.5)
    fcfg = FedConfig(num_clients=8, participation=1.0, local_epochs=1,
                     batch_size=64, rounds=rounds, noniid_l=2, seed=seed,
                     edge=edge)
    run = FederatedRun(mcfg, fcfg, train, test, alg)
    run.last_history = run.run(rounds=rounds, eval_every=rounds)
    return run


HETERO = DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=1.2)
SLOW_UPLINK = ChannelConfig(bandwidth_hz=2e5, fading="none")


def test_ledger_agrees_between_sync_and_async_for_identical_cohorts():
    """Bytes are scheduler-independent: with full participation and a
    full-cohort buffer, sync and async dispatch identical cohorts, so the
    ledgers must match field for field; only times differ."""
    sync = _fed_run(EdgeConfig(channel=SLOW_UPLINK, device=HETERO))
    asyn = _fed_run(EdgeConfig(channel=SLOW_UPLINK, device=HETERO,
                               mode="async", buffer_size=8))
    for f in ("down_bytes", "up_star_bytes", "up_tree_bytes",
              "scalar_bytes", "rounds"):
        assert getattr(sync.ledger, f) == getattr(asyn.ledger, f), f
    assert sync.ledger.summary() == asyn.ledger.summary()


def test_async_small_buffer_beats_sync_wall_clock():
    """With stragglers, a half-cohort buffer finishes rounds earlier than
    the synchronous barrier at the slowest client."""
    sync = _fed_run(EdgeConfig(channel=SLOW_UPLINK, device=HETERO), rounds=4)
    asyn = _fed_run(EdgeConfig(channel=SLOW_UPLINK, device=HETERO,
                               mode="async", buffer_size=4), rounds=4)
    assert asyn.edge.summary()["wall_clock_s"] < sync.edge.summary()["wall_clock_s"]
    assert np.isfinite([h["loss"] for h in asyn.last_history]).all()


def test_async_rejected_for_nonsummable_algorithms():
    with pytest.raises(ValueError, match="async"):
        _fed_run(EdgeConfig(mode="async"), alg="fedova", rounds=1)


def test_deadline_scheduler_advances_faster_than_uniform():
    """Heterogeneous fleet: dropping predicted stragglers cuts the
    per-round barrier, so simulated time for the same round count shrinks."""
    uni = _fed_run(EdgeConfig(channel=SLOW_UPLINK, device=HETERO), rounds=3)
    ddl = _fed_run(EdgeConfig(channel=SLOW_UPLINK, device=HETERO,
                              scheduler="deadline", deadline_s=2.0,
                              min_clients=2), rounds=3)
    assert ddl.edge.summary()["wall_clock_s"] < uni.edge.summary()["wall_clock_s"]


def test_energy_threshold_run_excludes_depleted_clients():
    edge = EdgeConfig(channel=SLOW_UPLINK,
                      device=DeviceConfig(flops_per_s_mean=2e9,
                                          battery_j=3.0),
                      scheduler="energy_threshold", battery_floor_j=0.5)
    run = _fed_run(edge, rounds=4)
    s = run.edge.summary()
    assert s["dropped_total"] > 0 or s["depleted_clients"] > 0


def test_edge_history_reports_time_and_energy():
    run = _fed_run(EdgeConfig(channel=SLOW_UPLINK, device=HETERO), rounds=2)
    s = run.edge.summary()
    assert s["wall_clock_s"] > 0 and s["energy_j"] > 0 and s["rounds"] == 2


def test_async_in_flight_matches_runtime_summary():
    """EdgeRuntime.summary()['in_flight'] must equal the set of busy
    clients the driver tracks — not the raw pending-event count."""
    run = _fed_run(EdgeConfig(channel=SLOW_UPLINK, device=HETERO,
                              mode="async", buffer_size=3), rounds=4)
    s = run.edge.summary()
    assert s["in_flight"] == len(run.edge.busy)
    dispatched = sum(h["cohort"] for h in run.last_history)
    aggregated = sum(h.get("aggregated", 0) for h in run.last_history)
    assert s["in_flight"] == dispatched - aggregated


def test_idle_power_drains_barrier_waiters():
    """Satellite bugfix: idle_power_w was declared but never drained.
    Fast clients idle at the sync barrier until the slowest finishes —
    their batteries lose idle_power_w * wait on top of the round work."""
    from repro.edge.runtime import EdgeRuntime

    def one_round(idle_w):
        cfg = EdgeConfig(channel=ChannelConfig(fading="none", snr_db_std=0.0),
                         device=DeviceConfig(flops_per_s_mean=1e9,
                                             flops_per_s_sigma=1.0,
                                             battery_j=1e4,
                                             idle_power_w=idle_w))
        rt = EdgeRuntime(cfg, 8, seed=0)
        est = rt.estimate(np.arange(8), up_bytes=1e5, flops=1e9)
        rec = rt.finish_round_sync(est, up_bytes=1e5, down_bytes=1e5)
        return rt, est, rec

    rt0, est0, rec0 = one_round(0.0)
    rt1, est1, rec1 = one_round(0.5)
    np.testing.assert_allclose(est0.time_s, est1.time_s)  # same fleet draw
    assert rec1["energy_j"] > rec0["energy_j"]
    drained0 = 1e4 - rt0.fleet.battery_j
    drained1 = 1e4 - rt1.fleet.battery_j
    # the barrier is the slowest client's finish + the comm drain: every
    # client's extra drain is idle_power_w * its wait for the barrier
    t_round = rec1["wall_s"] - rt1.channel.downlink_time_s(1e5)
    np.testing.assert_allclose(drained1 - drained0,
                               0.5 * np.maximum(t_round - est1.time_s, 0.0),
                               rtol=1e-9)
    # the fastest client idles longest, so it drains the most extra
    extra = drained1 - drained0
    assert extra[np.argmin(est1.time_s)] == pytest.approx(extra.max())


def test_empty_cohort_round_is_recorded_cleanly():
    """Satellite bugfix: a scheduler that excludes everyone (e.g. all
    batteries under the energy floor) must yield a cohort=0 round with no
    server step and no NaN/np.mean([]) — RuntimeWarnings are errors in
    this suite, so any regression trips immediately."""
    import jax

    edge = EdgeConfig(channel=SLOW_UPLINK,
                      device=DeviceConfig(flops_per_s_mean=2e9,
                                          battery_j=0.5),
                      scheduler="energy_threshold", battery_floor_j=1.0)
    run = _fed_run(edge, alg="fedavg_sgd", rounds=2)
    before = jax.tree.map(lambda x: np.asarray(x).copy(),
                          run.strategy.params)
    hist = run.last_history
    assert [h["cohort"] for h in hist] == [0, 0]
    assert all("loss" not in h for h in hist)
    assert "accuracy" in hist[-1]  # evaluation still runs
    # nobody transmitted: rounds tick but no bytes are billed (the tree
    # depth floor of max(1, log2 k) must not charge a phantom payload)
    assert run.ledger.rounds == 2
    for f in ("down_bytes", "up_star_bytes", "up_tree_bytes",
              "scalar_bytes"):
        assert getattr(run.ledger, f) == 0.0, f
    info = run.round()  # one more: the server model must not move
    assert info["cohort"] == 0
    for a, b in zip(jax.tree.leaves(before),
                    jax.tree.leaves(run.strategy.params), strict=True):
        np.testing.assert_array_equal(a, np.asarray(b))
    # and the edge clock agrees with the ledger: no broadcast happened
    assert run.edge.summary()["wall_clock_s"] == 0.0


def test_empty_cohort_async_does_not_advance_clock():
    """The async dispatch path must match the sync fix: an all-excluded
    cohort broadcasts nothing, so the clock stays put."""
    edge = EdgeConfig(channel=SLOW_UPLINK,
                      device=DeviceConfig(flops_per_s_mean=2e9,
                                          battery_j=0.5),
                      scheduler="energy_threshold", battery_floor_j=1.0,
                      mode="async", buffer_size=2)
    run = _fed_run(edge, alg="fedavg_sgd", rounds=2)
    assert [h["cohort"] for h in run.last_history] == [0, 0]
    assert run.edge.summary()["wall_clock_s"] == 0.0
    assert run.ledger.up_star_bytes == 0.0


def test_simulator_with_edge_wrapper():
    import jax
    import jax.numpy as jnp
    from repro.configs.paper_models import FMNIST_CNN, reduced
    from repro.core import fim_lbfgs
    from repro.data.synthetic import make_classification
    from repro.edge.runtime import EdgeRuntime
    from repro.fed.simulator import make_round_step, with_edge
    from repro.models import cnn

    mcfg = reduced(FMNIST_CNN)
    params, _ = cnn.init(mcfg, jax.random.PRNGKey(0))
    ocfg = fim_lbfgs.FimLbfgsConfig(learning_rate=1.0, m=5, damping=1e-2,
                                    max_step_norm=1.0)
    step = make_round_step(lambda p, b: cnn.softmax_loss(p, mcfg, b),
                           cnn.per_example_loss_fn(mcfg), ocfg)
    edge = EdgeRuntime(EdgeConfig(channel=SLOW_UPLINK, device=HETERO), 8)
    n_params = sum(int(leaf.size) for leaf in jax.tree.leaves(params))
    estep = with_edge(step, edge, n_params)
    train, _ = make_classification(mcfg, n_train=256, n_test=64, seed=0)
    rng = np.random.default_rng(0)
    opt = fim_lbfgs.init(params, ocfg)
    for _ in range(2):
        idx = rng.integers(0, len(train.x), size=(8, 32))
        cohort = {"x": jnp.asarray(train.x[idx]),
                  "y": jnp.asarray(train.y[idx])}
        params, opt, stats = estep(params, opt, cohort, jnp.ones(8))
    assert stats["wall_s"] > 0 and stats["sim_time_s"] > stats["wall_s"] / 2
    assert edge.summary()["rounds"] == 2


def test_simulator_with_edge_true_client_ids():
    """The wrapped round_step maps cohort slots to the TRUE selected fleet
    entries: battery drain and device heterogeneity hit those clients, not
    an arbitrary arange(k) prefix."""
    import jax.numpy as jnp
    from repro.configs.base import FedConfig
    from repro.configs.paper_models import FMNIST_CNN, reduced
    from repro.data.synthetic import make_classification
    from repro.edge.runtime import EdgeRuntime
    from repro.fed import simulator, strategies

    mcfg = reduced(FMNIST_CNN)
    fcfg = FedConfig(num_clients=12, seed=0)
    s = strategies.get("fim_lbfgs")(mcfg, fcfg, 10)
    step = simulator.from_strategy(s)
    edge = EdgeRuntime(EdgeConfig(channel=SLOW_UPLINK,
                                  device=DeviceConfig(flops_per_s_mean=2e9,
                                                      battery_j=1e4)),
                       num_clients=12)
    estep = simulator.with_edge(step, edge, s.n_params())
    train, _ = make_classification(mcfg, n_train=256, n_test=64, seed=0)
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(train.x), size=(4, 32))
    cohort = {"x": jnp.asarray(train.x[idx]), "y": jnp.asarray(train.y[idx])}
    selected = np.asarray([9, 2, 11, 5])
    full = edge.fleet.battery_j.copy()
    _, _, stats = estep(s.params, s.opt_state, cohort, jnp.ones(4),
                        clients=selected)
    drained = np.flatnonzero(edge.fleet.battery_j < full)
    assert sorted(drained) == sorted(selected)
    assert stats["wall_s"] > 0
    with pytest.raises(ValueError, match="cohort slots"):
        estep(s.params, s.opt_state, cohort, jnp.ones(4),
              clients=np.arange(3))
    with pytest.raises(ValueError, match="client ids"):
        estep(s.params, s.opt_state, cohort, jnp.ones(4),
              clients=np.asarray([0, 1, 2, 99]))
