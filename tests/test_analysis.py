"""repro.analysis: the contract linter's own test suite.

Per rule: a fixture snippet that must fire (positive), its corrected
twin that must stay quiet (negative), and the suppression layers
(pragma, baseline) + CLI surface (JSON schema, exit codes).  Everything
runs on in-memory sources — no jax, no file tree needed.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (Baseline, Finding, ModuleSource, Rule, all_rules,
                            check_module, get, names, run_paths)
from repro.analysis.cli import main as cli_main

SIM_PATH = "src/repro/edge/some_module.py"


def lint(src: str, path: str = SIM_PATH, rule: str | None = None):
    mod = ModuleSource(path, textwrap.dedent(src))
    rules = [get(rule)()] if rule else None
    return check_module(mod, rules=rules)


def rule_ids(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_has_the_six_contract_rules():
    assert names() == ["RPL001", "RPL002", "RPL003", "RPL004", "RPL005",
                       "RPL006"]
    for r in all_rules():
        assert r.id and r.title and r.description


def test_third_party_rule_registers_like_a_strategy():
    from repro.analysis import core

    class XRule(Rule):
        id = "TST900"
        title = "test"
        description = "fixture"

        def check(self, mod):
            return [self.finding(mod, mod.tree.body[0], "always")]

    core.register(XRule)
    try:
        out = check_module(ModuleSource("x.py", "a = 1\n"), rules=[XRule()])
        assert [f.rule for f in out] == ["TST900"]
    finally:
        core._REGISTRY.pop("TST900")


# ---------------------------------------------------------------------------
# RPL001 sim-determinism
# ---------------------------------------------------------------------------
RPL001_BAD = """\
    import time
    import numpy as np

    def sample():
        t = time.time()
        noise = np.random.randn(4)
        return t, noise
"""

RPL001_GOOD = """\
    import numpy as np

    def sample(clock, rng: np.random.Generator):
        t = clock.now
        noise = rng.standard_normal(4)
        gen = np.random.default_rng(17)
        return t, noise, gen
"""


def test_rpl001_fires_on_wall_clock_and_global_rng():
    out = lint(RPL001_BAD, rule="RPL001")
    assert len(out) == 2
    assert "time.time" in out[0].message
    assert "np.random.randn" in out[1].message


def test_rpl001_quiet_on_seeded_generators_and_clock():
    assert lint(RPL001_GOOD, rule="RPL001") == []


def test_rpl001_scoped_to_sim_paths():
    assert lint(RPL001_BAD, path="benchmarks/common.py") == []
    for p in ("src/repro/fed/x.py", "src/repro/obs/x.py"):
        assert rule_ids(lint(RPL001_BAD, path=p)) == ["RPL001"]


def test_rpl001_datetime_and_random_module():
    src = """\
        import random
        from datetime import datetime

        def stamp():
            return datetime.now(), random.random()
    """
    out = lint(src, rule="RPL001")
    assert len(out) == 2
    # seeded generator objects stay legal
    ok = "import random\nr = random.Random(3)\n"
    assert lint(ok, rule="RPL001") == []


# ---------------------------------------------------------------------------
# RPL002 x64-hygiene
# ---------------------------------------------------------------------------
RPL002_BAD = """\
    import jax
    from functools import partial

    jax.config.update("jax_enable_x64", True)

    @partial(jax.jit, static_argnames=("iters",))
    def _widths(x, iters):
        return x * iters

    def widths(x, iters=5):
        return _widths(x, iters)
"""

RPL002_GOOD = """\
    import jax
    from functools import partial
    from jax.experimental import enable_x64

    @partial(jax.jit, static_argnames=("iters",))
    def _widths(x, iters):
        return x * iters

    def widths(x, iters=5):
        with enable_x64():
            return _widths(x, iters)
"""

FLEET_PATH = "src/repro/edge/fleet/kernel.py"


def test_rpl002_fires_on_global_flip_and_unscoped_kernel_call():
    out = lint(RPL002_BAD, path=FLEET_PATH, rule="RPL002")
    msgs = [f.message for f in out]
    assert len(out) == 2
    assert any("jax.config.update" in m for m in msgs)
    assert any("enable_x64" in m and "_widths" in m for m in msgs)


def test_rpl002_quiet_when_scoped():
    assert lint(RPL002_GOOD, path=FLEET_PATH, rule="RPL002") == []


def test_rpl002_config_update_inside_function_is_fine():
    src = """\
        import jax

        def enable():
            jax.config.update("jax_enable_x64", True)
    """
    assert lint(src, path=FLEET_PATH, rule="RPL002") == []


def test_rpl002_kernel_scoping_only_in_fleet():
    # outside edge/fleet/ only the module-level config flip fires
    out = lint(RPL002_BAD, path="src/repro/kernels/ops.py", rule="RPL002")
    assert len(out) == 1 and "jax.config.update" in out[0].message


# ---------------------------------------------------------------------------
# RPL003 jit-purity
# ---------------------------------------------------------------------------
RPL003_BAD = """\
    import jax
    import jax.numpy as jnp

    @jax.jit
    def step(w, budget):
        if w.sum() > budget:
            w = w * 0.5
        total = float(jnp.sum(w))
        peak = w.max().item()
        return w, total, peak
"""

RPL003_GOOD = """\
    import jax
    import jax.numpy as jnp
    from functools import partial

    @partial(jax.jit, static_argnames=("iters",))
    def step(w, budget, iters):
        if iters > 3:          # static arg: trace-time branching is fine
            w = w * 0.5
        w = jnp.where(jnp.sum(w) > budget, w * 0.5, w)
        B, D = w.shape
        db = min(64, D)        # shape-derived python ints are static
        return w, db
"""


def test_rpl003_fires_on_branch_and_host_syncs():
    out = lint(RPL003_BAD, path=FLEET_PATH, rule="RPL003")
    msgs = " | ".join(f.message for f in out)
    assert len(out) == 3
    assert "Python if" in msgs
    assert "float()" in msgs
    assert ".item()" in msgs


def test_rpl003_quiet_on_static_branching_and_lax_style():
    assert lint(RPL003_GOOD, path=FLEET_PATH, rule="RPL003") == []


def test_rpl003_only_inside_jit_functions():
    src = """\
        def host_side(w):
            if w.sum() > 0:
                return float(w.sum())
            return w.max().item()
    """
    assert lint(src, path=FLEET_PATH, rule="RPL003") == []
    # and only in the kernel files
    assert lint(RPL003_BAD, path="src/repro/edge/runtime.py",
                rule="RPL003") == []


# ---------------------------------------------------------------------------
# RPL004 registry-contract
# ---------------------------------------------------------------------------
RPL004_STRATEGY_BAD = """\
    from repro.fed.strategies.base import FedStrategy, RoundPlan, register

    @register("broken")
    class Broken(FedStrategy):
        def client_step(self, data, rng, context=None):
            return None, 0.0
"""

RPL004_PLAN_INCOMPLETE = """\
    from repro.fed.strategies.base import (FedStrategy, PhasePlan, RoundPlan,
                                           register)

    @register("half")
    class Half(FedStrategy):
        def _make_plan(self):
            return RoundPlan(phases=(PhasePlan("up", up_floats=10.0),))
"""

RPL004_STRATEGY_GOOD = """\
    from repro.fed.strategies.base import (FedStrategy, PhasePlan, RoundPlan,
                                           register)

    @register("ok")
    class Ok(FedStrategy):
        def _make_plan(self):
            return RoundPlan(phases=(PhasePlan("up", up_floats=10.0),),
                             flops=lambda n_k: 6.0 * n_k, summable=True)
"""


def test_rpl004_strategy_without_plan_fires():
    out = lint(RPL004_STRATEGY_BAD, path="src/repro/fed/x.py", rule="RPL004")
    assert len(out) == 1 and "_make_plan" in out[0].message


def test_rpl004_incomplete_roundplan_fires():
    out = lint(RPL004_PLAN_INCOMPLETE, path="src/repro/fed/x.py",
               rule="RPL004")
    assert len(out) == 1 and "flops" in out[0].message


def test_rpl004_complete_strategy_quiet():
    assert lint(RPL004_STRATEGY_GOOD, path="src/repro/fed/x.py",
                rule="RPL004") == []


def test_rpl004_imported_base_is_trusted():
    src = """\
        from repro.fed.strategies.base import register
        from repro.fed.strategies.fedavg import LocalSolveStrategy

        @register("prox")
        class Prox(LocalSolveStrategy):
            pass
    """
    assert lint(src, path="src/repro/fed/x.py", rule="RPL004") == []


def test_rpl004_codec_and_direct_register_call():
    bad = """\
        from repro.fed.codecs import PayloadCodec, register

        class Fp16(PayloadCodec):
            pass

        register("fp16", Fp16)
    """
    out = lint(bad, path="examples/custom_codec.py", rule="RPL004")
    assert len(out) == 1 and "wire_bytes" in out[0].message
    good = """\
        from repro.fed.codecs import PayloadCodec, register

        class Fp16(PayloadCodec):
            def wire_bytes(self, n_floats):
                return 2.0 * n_floats

        register("fp16", Fp16)
    """
    assert lint(good, path="examples/custom_codec.py", rule="RPL004") == []


def test_rpl004_decide_vectorized_signature():
    bad = """\
        class P:
            def decide_vectorized(self, fstate, extra):
                return None
    """
    out = lint(bad, path="src/repro/edge/policies.py", rule="RPL004")
    assert len(out) == 1 and "decide_vectorized" in out[0].message
    good = """\
        class P:
            def decide_vectorized(self, fstate):
                return None
    """
    assert lint(good, path="src/repro/edge/policies.py", rule="RPL004") == []


# ---------------------------------------------------------------------------
# RPL005 tracer-noop
# ---------------------------------------------------------------------------
RPL005_BAD = """\
    def round_end(tracer, t, cohort):
        tracer.event("alloc", "client", t, detail=f"cohort={cohort}")
        tracer.metrics.counter("drops_total").inc(1.0, **{"reason": "x"})
"""

RPL005_GOOD = """\
    def round_end(tracer, t, cohort):
        if tracer.enabled:
            tracer.event("alloc", "client", t, detail=f"cohort={cohort}")
        tracer.event("alloc", "client", t, cohort=cohort)  # lazy: no work
"""


def test_rpl005_fires_on_unguarded_eager_args():
    out = lint(RPL005_BAD, rule="RPL005")
    assert len(out) == 2
    assert all("NULL_TRACER" in f.message for f in out)


def test_rpl005_quiet_under_enabled_guard_or_lazy_args():
    assert lint(RPL005_GOOD, rule="RPL005") == []


def test_rpl005_early_out_guard_counts():
    src = """\
        def trace_round(tracer, rows):
            if not tracer.enabled:
                return
            tracer.record_round({"rows": len(rows)})
    """
    assert lint(src, rule="RPL005") == []


def test_rpl005_metric_alias_receiver_is_tracked():
    src = """\
        def meter(self, x):
            m = self.tracer.metrics
            m.gauge("battery_j").set(x, labels={"client": 1})
    """
    out = lint(src, rule="RPL005")
    assert len(out) == 1
    # non-tracer receivers with the same method names stay out of scope
    quiet = """\
        def collect(seen, items):
            seen.add({"k": 1})
            items.set(0, {"k": 1})
    """
    assert lint(quiet, rule="RPL005") == []


# ---------------------------------------------------------------------------
# RPL006 ledger-discipline
# ---------------------------------------------------------------------------
RPL006_BAD = """\
    def meter(ledger, plan, k):
        ledger.upload(plan.up_floats, k, aggregatable=True)
"""

RPL006_GOOD = """\
    def meter(ledger, ph, k, billed):
        ledger.upload(ph.up_floats, k, aggregatable=True,
                      wire_bytes=ph.wire_up_bytes())
        ledger.upload_per_client(billed, aggregatable=True)
"""


def test_rpl006_fires_without_wire_bytes():
    out = lint(RPL006_BAD, rule="RPL006")
    assert len(out) == 1 and "wire_bytes" in out[0].message


def test_rpl006_quiet_with_explicit_wire_bytes():
    assert lint(RPL006_GOOD, rule="RPL006") == []


# ---------------------------------------------------------------------------
# pragma suppression
# ---------------------------------------------------------------------------
def test_pragma_suppresses_named_rule_on_its_line():
    src = """\
        import time

        def stamp():
            return time.time()  # repro: allow[RPL001]
    """
    assert lint(src, rule="RPL001") == []


def test_pragma_on_comment_line_covers_next_line():
    src = """\
        import time

        def stamp():
            # repro: allow[RPL001]
            return time.time()
    """
    assert lint(src, rule="RPL001") == []


def test_pragma_wrong_rule_does_not_suppress():
    src = """\
        import time

        def stamp():
            return time.time()  # repro: allow[RPL006]
    """
    assert len(lint(src, rule="RPL001")) == 1


def test_pragma_star_suppresses_everything():
    src = """\
        import time

        def stamp():
            return time.time()  # repro: allow[*]
    """
    assert lint(src) == []


# ---------------------------------------------------------------------------
# baseline filtering
# ---------------------------------------------------------------------------
def _one_finding():
    out = lint(RPL006_BAD, rule="RPL006")
    assert len(out) == 1
    return out[0]


def test_baseline_filters_by_fingerprint_not_line(tmp_path):
    f = _one_finding()
    bl = Baseline.from_findings([f])
    # same content on a different line: fingerprint is line-free
    moved = Finding(f.rule, f.path, f.line + 40, f.col, f.message, f.snippet)
    fresh, eaten = bl.filter([moved])
    assert fresh == [] and eaten == 1
    # a different violation is NOT covered
    other = Finding(f.rule, f.path, 3, 0, f.message, "ledger.upload(z, 9)")
    fresh, eaten = bl.filter([other])
    assert fresh == [other] and eaten == 0


def test_baseline_counts_cap_duplicates():
    f = _one_finding()
    bl = Baseline.from_findings([f])          # budget: 1 occurrence
    fresh, eaten = bl.filter([f, f])
    assert eaten == 1 and len(fresh) == 1


def test_baseline_roundtrips_through_disk(tmp_path):
    f = _one_finding()
    path = str(tmp_path / "bl.json")
    Baseline.from_findings([f]).write(path)
    loaded = Baseline.load(path)
    assert loaded.counts == {f.fingerprint(): 1}
    assert Baseline.load(str(tmp_path / "missing.json")).counts == {}


# ---------------------------------------------------------------------------
# CLI: exit codes, JSON schema, parse errors
# ---------------------------------------------------------------------------
def _write(tmp_path, name, src):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return str(p)


def test_cli_exit_codes_and_json_schema(tmp_path, capsys):
    bad = _write(tmp_path, "mod.py",
                 "def f(ledger, d, k):\n    ledger.upload(d, k)\n")
    rc = cli_main(["--format", "json", "--no-baseline", bad])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["version"] == 1
    assert set(payload["rules"]) == set(names())
    (f,) = payload["findings"]
    assert set(f) == {"rule", "path", "line", "col", "message", "snippet",
                      "fingerprint"}
    assert f["rule"] == "RPL006" and f["line"] == 2

    ok = _write(tmp_path, "ok.py", "x = 1\n")
    assert cli_main(["--no-baseline", ok]) == 0
    capsys.readouterr()


def test_cli_baseline_workflow(tmp_path, capsys):
    bad = _write(tmp_path, "mod.py",
                 "def f(ledger, d, k):\n    ledger.upload(d, k)\n")
    bl = str(tmp_path / "baseline.json")
    assert cli_main(["--baseline", bl, "--write-baseline", bad]) == 0
    assert cli_main(["--baseline", bl, bad]) == 0         # grandfathered
    assert cli_main(["--baseline", bl, "--no-baseline", bad]) == 1
    capsys.readouterr()


def test_cli_parse_error_is_a_finding(tmp_path, capsys):
    broken = _write(tmp_path, "broken.py", "def f(:\n")
    rc = cli_main(["--format", "json", "--no-baseline", broken])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["findings"][0]["rule"] == "PARSE"


def test_cli_select_unknown_rule_errors():
    with pytest.raises(SystemExit):
        cli_main(["--select", "NOPE01", "src/repro/analysis"])


def test_run_paths_walks_directories(tmp_path):
    sub = tmp_path / "pkg"
    sub.mkdir()
    (sub / "a.py").write_text("def f(ledger, d, k):\n    ledger.upload(d, k)\n")
    (sub / "b.txt").write_text("not python")
    out = run_paths([str(tmp_path)])
    assert [f.rule for f in out] == ["RPL006"]


# ---------------------------------------------------------------------------
# the analyzer must never import what it lints
# ---------------------------------------------------------------------------
def test_analyzer_is_pure_stdlib():
    code = ("import sys; import repro.analysis.cli; "
            "bad = [m for m in ('jax', 'numpy', 'repro.fed', 'repro.edge', "
            "'repro.obs') if m in sys.modules]; "
            "sys.exit(1 if bad else 0)")
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr


def test_repo_tree_is_clean_under_committed_baseline():
    """The acceptance gate, as a test: src+benchmarks+examples lint
    clean against the committed baseline."""
    import os
    root = os.path.join(os.path.dirname(__file__), "..")
    rc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "benchmarks",
         "examples"],
        cwd=root, capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": os.path.join(root, "src")
             + os.pathsep + os.environ.get("PYTHONPATH", "")})
    assert rc.returncode == 0, rc.stdout + rc.stderr
