"""Aggregation rule properties (Eq. 1 / Eq. 11)."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic env: seeded-random fallback, same assertions
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import aggregation


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(1, 5), st.integers(0, 10_000))
def test_weighted_mean_is_convex_combination(k, d, seed):
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=(k, d))
    w = rng.uniform(0.1, 5.0, size=k)
    out = np.asarray(aggregation.weighted_mean({"x": jnp.asarray(vals)},
                                               jnp.asarray(w))["x"])
    ref = (vals * w[:, None]).sum(0) / w.sum()
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    # convexity: within [min, max] per coordinate
    assert (out <= vals.max(0) + 1e-6).all() and (out >= vals.min(0) - 1e-6).all()


def test_weighted_mean_respects_nk_weighting():
    vals = jnp.asarray([[0.0], [10.0]])
    out = aggregation.weighted_mean({"x": vals}, jnp.asarray([3.0, 1.0]))
    np.testing.assert_allclose(np.asarray(out["x"]), [2.5])


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(0, 10_000))
def test_grouped_mean_ignores_noncontributors(k, seed):
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=(k, 3))
    mask = (rng.uniform(size=k) > 0.5).astype(float)
    prev = rng.normal(size=3)
    out = np.asarray(aggregation.grouped_mean(
        {"x": jnp.asarray(prev)}, {"x": jnp.asarray(vals)}, jnp.asarray(mask))["x"])
    if mask.sum() == 0:
        np.testing.assert_allclose(out, prev, rtol=1e-6)
    else:
        ref = (vals * mask[:, None]).sum(0) / mask.sum()
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
