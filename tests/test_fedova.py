"""FedOVA scheme tests (paper Alg. 2 / Eqs. 4, 11)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, fedova


def test_binary_labels_and_class_mask():
    y = jnp.asarray([0, 2, 2, 1])
    np.testing.assert_array_equal(np.asarray(fedova.binary_labels(y, 2)), [0, 1, 1, 0])
    mask = np.asarray(fedova.client_class_mask(y, 4))
    np.testing.assert_array_equal(mask, [1, 1, 1, 0])


def test_grouped_aggregate_eq11():
    """Eq. (11): mean over contributors only; untouched groups keep server."""
    prev = {"w": jnp.asarray([10.0, 10.0])}
    clients = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0], [100.0, 100.0]])}
    contributed = jnp.asarray([1.0, 1.0, 0.0])
    out = aggregation.grouped_mean(prev, clients, contributed)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0, 3.0])
    none = aggregation.grouped_mean(prev, clients, jnp.zeros(3))
    np.testing.assert_allclose(np.asarray(none["w"]), [10.0, 10.0])


def test_ova_predict_argmax_confidence():
    """Eq. (4) on a hand-built linear OVA model that separates 3 classes."""
    # component c: logit = <w_c, x>; class c points at e_c
    W = jnp.eye(3) * 5.0
    model = fedova.OvaModel(components={"w": W}, n_classes=3)

    def apply_fn(p, x):
        return (x @ p["w"])[:, None]

    x = jnp.asarray([[1.0, 0, 0], [0, 1.0, 0.2], [0.1, 0, 1.0]])
    pred = np.asarray(fedova.predict(apply_fn, model, x))
    np.testing.assert_array_equal(pred, [0, 1, 2])
    assert float(fedova.accuracy(apply_fn, model, x, jnp.asarray([0, 1, 2]))) == 1.0


def test_aggregate_stacks_per_class():
    n = 3
    model = fedova.OvaModel(components={"w": jnp.zeros((n, 2))}, n_classes=n)
    # two clients: client 0 trained classes {0,1}, client 1 trained {1}
    clients = {"w": jnp.asarray([
        [[1.0, 1.0], [2.0, 2.0], [9.0, 9.0]],
        [[5.0, 5.0], [4.0, 4.0], [7.0, 7.0]],
    ])}
    masks = jnp.asarray([[1.0, 1.0, 0.0], [0.0, 1.0, 0.0]])
    out = fedova.aggregate(model, clients, masks)
    got = np.asarray(out.components["w"])
    np.testing.assert_allclose(got[0], [1.0, 1.0])   # only client 0
    np.testing.assert_allclose(got[1], [3.0, 3.0])   # mean of both
    np.testing.assert_allclose(got[2], [0.0, 0.0])   # nobody -> server keeps


def test_add_class_smooth_adaptation():
    """Paper Sec. IV-B Remark: new classes get a fresh component; existing
    experts (and their predictions) are untouched."""
    import jax
    W = jnp.eye(3) * 5.0
    model = fedova.OvaModel(components={"w": W}, n_classes=3)

    def apply_fn(p, x):
        return (x @ p["w"][:3]) [:, None] if p["w"].shape[0] > 3 else (x @ p["w"])[:, None]

    def init_fn(key):
        return {"w": jnp.zeros(3)}

    bigger = fedova.add_class(model, init_fn, jax.random.PRNGKey(0))
    assert bigger.n_classes == 4
    np.testing.assert_allclose(np.asarray(bigger.components["w"][:3]),
                               np.asarray(W))
    np.testing.assert_allclose(np.asarray(bigger.components["w"][3]),
                               np.zeros(3))


def test_int8_quantization_unbiased():
    """Stochastic rounding: E[dequant(quant(x))] = x; error bounded by scale."""
    import jax
    from repro.fed import codecs
    int8 = codecs.make("int8")
    x = {"w": jnp.linspace(-3.0, 3.0, 101)}
    keys = jax.random.split(jax.random.PRNGKey(0), 200)
    acc = np.zeros(101)
    for k in keys:
        acc += np.asarray(int8.roundtrip(x, k)[0]["w"])
    mean = acc / len(keys)
    scale = 3.0 / 127
    np.testing.assert_allclose(mean, np.asarray(x["w"]), atol=scale * 0.5)
    one = int8.roundtrip(x, keys[0])[0]["w"]
    assert float(jnp.max(jnp.abs(one - x["w"]))) <= scale + 1e-6


def test_comm_ledger_thm3_structure():
    """Theorem 3's shape: Alg 1 tree bytes ~ 2 d log2(k) + m² scalars;
    FedAvg star bytes ~ k d."""
    from repro.fed import comm
    led = comm.CommLedger()
    d, k = 1000, 8
    led.broadcast(d, k)
    led.upload(d, k)          # grads
    led.upload(d, k)          # fisher
    led.scalars((2 * 5 + 1) ** 2)
    led.end_round()
    s = led.summary()
    assert s["up_star_MB_per_round"] == 2 * d * k * 4 / 1e6
    assert s["up_tree_MB_per_round"] == 2 * d * 3 * 4 / 1e6  # log2(8)=3
    assert s["scalar_KB_per_round"] == (11 ** 2) * 4 / 1e3
