"""repro.fed.codecs: registry/spec parsing, wire-byte accounting,
round-trip unbiasedness (int8 stochastic rounding per round; top-k /
rand-k error feedback in the long run), the plan == ledger invariant
parametrized over (strategy × codec), the int8-never-a-no-op regression
for all seven registered strategies, and the edge/scheduler coupling —
compressed wire sizes must shrink uplink time and energy too."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.fed import codecs, strategies
from repro.fed.server import FederatedRun

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # hermetic fallback: seeded-random sampling
    from tests._hypothesis_compat import given, settings
    from tests._hypothesis_compat import strategies as st

MCFG = reduced(FMNIST_CNN)
ALL_ALGS = ["fim_lbfgs", "fedavg_sgd", "fedavg_adam", "fedprox", "feddane",
            "fedova", "fedova_lbfgs"]
SUMMABLE_ALGS = ["fim_lbfgs", "fedavg_sgd", "fedavg_adam", "fedprox"]
SPARSIFYING = ["topk:0.1", "randk:0.1"]


def _data(n_train=300, n_test=100, noise=0.5, seed=0):
    return make_classification(MCFG, n_train=n_train, n_test=n_test,
                               seed=seed, noise=noise)


def _fcfg(**kw):
    base = dict(num_clients=8, participation=1.0, local_epochs=1,
                batch_size=32, rounds=2, noniid_l=2, learning_rate=0.05,
                seed=0)
    base.update(kw)
    return FedConfig(**base)


# ------------------------------------------------------------------ registry
def test_registry_roundtrip_and_specs():
    assert {"none", "int8", "topk", "randk"} <= set(codecs.names())
    assert codecs.make("none").identity
    assert codecs.make("int8").spec() == "int8"
    tk = codecs.make("topk:0.05")
    assert isinstance(tk, codecs.TopKCodec) and tk.ratio == 0.05
    assert codecs.make(tk) is tk  # instances pass through
    assert codecs.make(tk.spec()).ratio == tk.ratio
    rk = codecs.make("randk")  # default ratio
    assert rk.ratio == codecs.RandKCodec.default_ratio


def test_unknown_codec_and_bad_params_raise():
    with pytest.raises(ValueError, match="unknown payload codec"):
        codecs.make("int4")
    with pytest.raises(ValueError, match="ratio"):
        codecs.make("topk:0")
    with pytest.raises(ValueError, match="ratio"):
        codecs.make("randk:1.5")
    with pytest.raises(ValueError, match="bad codec spec"):
        codecs.make("int8:3")  # int8 takes no parameter
    with pytest.raises(ValueError, match="compress"):
        FedConfig(compress="gzip")
    with pytest.raises(ValueError, match="compress"):
        FedConfig(compress="topk:-1")


def test_third_party_codec_registers_and_runs():
    """A codec registered from outside the package drives a run end to
    end (the README example's shape: lossless-in-sim fp16 halving)."""
    @codecs.register("_test_fp16")
    class Fp16Codec(codecs.PayloadCodec):
        def wire_bytes(self, n_floats):
            return 2.0 * n_floats

        def roundtrip(self, tree, key, residual=None):
            return jax.tree.map(
                lambda x: x.astype(jnp.float16).astype(jnp.float32),
                tree), None

    try:
        train, test = _data()
        run = FederatedRun(MCFG, _fcfg(compress="_test_fp16"), train, test,
                           "fedavg_sgd")
        hist = run.run(rounds=2, eval_every=2)
        assert np.isfinite(hist[-1]["loss"])
        d = run.strategy.n_params()
        assert run.plan.upload_bytes() == 2.0 * d
    finally:
        codecs._REGISTRY.pop("_test_fp16", None)


# ---------------------------------------------------------------- wire bytes
def test_wire_bytes_per_codec():
    n = 10_000
    assert codecs.make("none").wire_bytes(n) == 4 * n
    assert codecs.make("int8").wire_bytes(n) == n
    # top-k ships value + explicit index (8 B/kept); rand-k shares the
    # index seed with the server, so only values cross the wire (4 B/kept)
    assert codecs.make("topk:0.1").wire_bytes(n) == math.ceil(0.1 * n) * 8
    assert codecs.make("randk:0.1").wire_bytes(n) == math.ceil(0.1 * n) * 4
    # a 50%-sparse top-k costs the same as uncompressed float32
    assert codecs.make("topk:0.5").wire_bytes(n) == 4 * n


# ------------------------------------------------------------- round-trips
def test_topk_keeps_largest_and_returns_residual():
    tk = codecs.make("topk:0.25")
    x = {"w": jnp.asarray([1.0, -8.0, 0.5, 3.0, -0.1, 0.2, 6.0, -2.0])}
    sent, res = tk.roundtrip(x, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(sent["w"]),
                               [0.0, -8.0, 0, 0, 0, 0, 6.0, 0])
    np.testing.assert_allclose(np.asarray(sent["w"]) + np.asarray(res["w"]),
                               np.asarray(x["w"]))


def test_sparsifier_kept_count_equals_billed_wire_elements():
    """The metered wire size and the semantic round-trip must agree:
    selection is global over the flattened payload, so a multi-leaf tree
    transmits exactly the ceil(ratio * n_floats) elements wire_bytes
    bills — per-leaf ceil()s/floors would overshoot on small tensors."""
    tree = {"w": jnp.arange(1.0, 16.0),          # 15 floats
            "b": jnp.arange(1.0, 9.0),           # 8 floats
            "deep": {"k": jnp.ones((3, 4))}}     # 12 floats
    n = 15 + 8 + 12
    for spec, per_el in (("topk:0.1", 8), ("randk:0.1", 4)):
        codec = codecs.make(spec)
        sent, _ = codec.roundtrip(tree, jax.random.PRNGKey(0))
        kept = sum(int((np.asarray(leaf) != 0).sum())
                   for leaf in jax.tree.leaves(sent))
        assert kept == math.ceil(0.1 * n), spec
        assert codec.wire_bytes(n) == kept * per_el, spec


def test_randk_keeps_exactly_k_and_returns_residual():
    rk = codecs.make("randk:0.25")
    x = {"w": jnp.arange(1.0, 17.0)}
    sent, res = rk.roundtrip(x, jax.random.PRNGKey(3))
    assert int((np.asarray(sent["w"]) != 0).sum()) == 4
    np.testing.assert_allclose(np.asarray(sent["w"]) + np.asarray(res["w"]),
                               np.asarray(x["w"]))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_int8_roundtrip_unbiased_property(seed):
    """Property: stochastic rounding is unbiased per round —
    E_key[dequant(quant(x))] = x within the Monte-Carlo tolerance."""
    rng = np.random.default_rng(seed)
    x = {"a": jnp.asarray(rng.normal(0, 2.0, 64).astype(np.float32))}
    n_keys = 300
    keys = jax.random.split(jax.random.PRNGKey(seed), n_keys)
    int8 = codecs.make("int8")
    acc = np.zeros(64)
    for k in keys:
        acc += np.asarray(int8.roundtrip(x, k)[0]["a"])
    scale = float(jnp.max(jnp.abs(x["a"]))) / 127.0
    # per-draw rounding noise has std <= scale/2, so the 300-key mean sits
    # within ~scale/35 of x; 0.2*scale is ~7 sigma yet still 1/5 of a step
    np.testing.assert_allclose(acc / n_keys, np.asarray(x["a"]),
                               atol=0.2 * scale)


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_sparsifier_error_feedback_unbiased_in_the_long_run(seed):
    """Property: with error feedback, the *cumulative* transmitted signal
    tracks the cumulative true signal — the telescoping identity
    sum_t(sent_t) == T*x - residual_T holds exactly, so the per-round
    bias is the (bounded) residual over T and vanishes."""
    rng = np.random.default_rng(seed)
    x = np.asarray(rng.normal(0, 1.0, 40).astype(np.float32))
    rounds = 30
    for spec in SPARSIFYING:
        codec = codecs.make(spec)
        keys = jax.random.split(jax.random.PRNGKey(seed + 1), rounds)
        sent_sum = np.zeros(40)
        res = None
        for k in keys:
            sent, res = codec.roundtrip({"a": jnp.asarray(x)}, k, res)
            sent_sum += np.asarray(sent["a"])
        # sum of sends == rounds*x - final residual, exactly (telescoping)
        np.testing.assert_allclose(
            sent_sum, rounds * x - np.asarray(res["a"]),
            rtol=1e-4, atol=1e-4, err_msg=spec)
        # the long-run average tracks x: for top-k every coordinate is
        # flushed once its accumulated error tops the selection threshold
        # (deterministic); for rand-k selection is uniform, so judge the
        # relative L2 error (a coord missing all 30 draws has p=0.9^30)
        err = np.linalg.norm(sent_sum / rounds - x) / np.linalg.norm(x)
        assert err < 0.5, (spec, err)
        if spec.startswith("topk"):
            assert float(np.abs(np.asarray(res["a"])).max()) <= \
                float(np.abs(x).max()) * (1.0 / codec.ratio + 1.0)


# ------------------------------------------------- empty-payload regression
@pytest.mark.parametrize("spec", ["topk:0.1", "randk:0.1"])
def test_empty_payload_is_a_zero_element_noop(spec):
    """Regression: _k(0) used to return 1, contradicting wire_bytes(0)
    == 0 and crashing jax.lax.top_k on a zero-size array.  An empty
    payload must round-trip as a zero-element no-op."""
    codec = codecs.make(spec)
    assert codec._k(0) == 0
    assert codec.wire_bytes(0) == 0.0
    empty = {"w": jnp.zeros((0,)), "deep": {"b": jnp.zeros((0, 3))}}
    sent, res = codec.roundtrip(empty, jax.random.PRNGKey(0))
    assert jax.tree_util.tree_structure(sent) == \
        jax.tree_util.tree_structure(empty)
    for leaf_s, leaf_e in zip(jax.tree.leaves(sent), jax.tree.leaves(empty),
                              strict=True):
        assert leaf_s.shape == leaf_e.shape
    for leaf in jax.tree.leaves(res):
        assert leaf.size == 0
    # nonempty payloads still keep at least one coordinate
    assert codec._k(1) == 1 and codec._k(3) == 1


# ------------------------------------ kernel fast path == registry oracle
@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_topk_kernel_path_bit_consistent_with_oracle(seed):
    """Property (acceptance): the fused top-k kernel and the registry
    oracle agree bit-for-bit on kept index sets, billed bytes, and
    error-feedback residuals — plan==ledger can't depend on the knob."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 200))
    tree = {"w": jnp.asarray(rng.normal(0, 3.0, n).astype(np.float32)),
            "b": jnp.asarray(rng.normal(0, 0.1, 7).astype(np.float32))}
    key = jax.random.PRNGKey(seed)
    on = codecs.make("topk:0.2", kernels="on")
    off = codecs.make("topk:0.2", kernels="off")
    sent_on, res_on = on.roundtrip(tree, key)
    sent_off, res_off = off.roundtrip(tree, key)
    for a, b in zip(jax.tree.leaves(sent_on), jax.tree.leaves(sent_off),
                    strict=True):
        assert bool(jnp.all(a == b))  # identical kept sets AND values
    for a, b in zip(jax.tree.leaves(res_on), jax.tree.leaves(res_off),
                    strict=True):
        assert bool(jnp.all(a == b))  # identical EF residuals
    kept = sum(int((np.asarray(leaf) != 0).sum())
               for leaf in jax.tree.leaves(sent_on))
    assert kept == math.ceil(0.2 * (n + 7))  # == billed wire elements
    assert on.wire_bytes(n + 7) == math.ceil(0.2 * (n + 7)) * 8


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_int8_kernel_path_bit_consistent_with_oracle(seed):
    """Property (acceptance): the fused int8 kernel reproduces the
    registry oracle (and the historical quantize/dequantize_tree pair)
    bit-for-bit under the shared uniform stream."""
    rng = np.random.default_rng(seed)
    tree = {"w": jnp.asarray(rng.normal(0, 2.0, 130).astype(np.float32)),
            "b": jnp.asarray(rng.normal(0, 5.0, (3, 5)).astype(np.float32))}
    key = jax.random.PRNGKey(seed)
    on, _ = codecs.make("int8", kernels="on").roundtrip(tree, key)
    off, _ = codecs.make("int8", kernels="off").roundtrip(tree, key)
    legacy = codecs.dequantize_tree(*codecs.quantize_tree(tree, key))
    for a, b, c in zip(jax.tree.leaves(on), jax.tree.leaves(off),
                       jax.tree.leaves(legacy), strict=True):
        assert bool(jnp.all(a == b))
        assert bool(jnp.all(a == c))


def test_make_kernels_knob_validation():
    assert codecs.make("topk:0.1").kernels == "auto"
    assert codecs.make("int8", kernels="on").kernels == "on"
    with pytest.raises(ValueError, match="kernels mode"):
        codecs.make("int8", kernels="fast")
    with pytest.raises(ValueError, match="kernels"):
        FedConfig(kernels="fast")


# ------------------------------------------- the int8 no-op regression (bug)
@pytest.mark.parametrize("alg", ALL_ALGS)
def test_int8_shrinks_ledger_for_every_strategy(alg):
    """The bug this PR fixes: compress='int8' silently uploaded float32
    for six of the seven strategies.  Now every registered strategy's
    metered up-bytes must shrink 4x — never a silent no-op."""
    train, test = _data()
    up = {}
    for spec in ("none", "int8"):
        run = FederatedRun(MCFG, _fcfg(compress=spec), train, test, alg)
        run.run(rounds=1, eval_every=1)
        up[spec] = (run.ledger.up_star_bytes, run.ledger.up_tree_bytes)
    assert up["int8"][0] == pytest.approx(up["none"][0] / 4), alg
    assert up["int8"][1] == pytest.approx(up["none"][1] / 4), alg


# ------------------------------------------- plan == ledger × strategy × codec
CODEC_MATRIX = ([(a, s) for a in ALL_ALGS for s in ("none", "int8")]
                + [(a, s) for a in SUMMABLE_ALGS for s in SPARSIFYING])


@pytest.mark.parametrize("alg,spec", CODEC_MATRIX)
def test_roundplan_matches_ledger_under_every_codec(alg, spec):
    train, test = _data()
    run = FederatedRun(MCFG, _fcfg(compress=spec), train, test, alg)
    run.run(rounds=2, eval_every=2)
    k = sum(len(run.partition[i]) > 0 for i in range(run.fcfg.num_clients))
    plan = run.plan
    assert run.ledger.up_star_bytes == pytest.approx(
        plan.upload_bytes() * k * 2), (alg, spec)
    expect_tree = 0.0
    for ph in plan.phases:
        wire = ph.codec.wire_bytes(ph.up_floats)
        depth = max(1, math.ceil(math.log2(max(k, 2))))
        expect_tree += wire * (depth if ph.aggregatable else k)
    assert run.ledger.up_tree_bytes == pytest.approx(expect_tree * 2), (alg, spec)


@pytest.mark.parametrize("alg", ["feddane", "fedova"])
def test_sparsifying_codec_rejected_for_nonsummable(alg):
    """Top-k/rand-k zero coordinates — only additive (summable) payloads
    survive that; distinct-model uploads must raise, not corrupt."""
    train, test = _data()
    with pytest.raises(ValueError, match="sparsif"):
        FederatedRun(MCFG, _fcfg(compress="topk:0.1"), train, test, alg)


def test_error_feedback_state_is_per_client():
    train, test = _data()
    run = FederatedRun(MCFG, _fcfg(compress="topk:0.2"), train, test,
                       "fedavg_sgd")
    run.run(rounds=2, eval_every=2)
    active = {i for i in range(run.fcfg.num_clients)
              if len(run.partition[i]) > 0}
    assert set(run._ef_residual) == active
    # residuals share the payload pytree structure
    one = next(iter(run._ef_residual.values()))
    assert (jax.tree_util.tree_structure(one)
            == jax.tree_util.tree_structure(run.strategy.params))


def test_sparsified_fim_lbfgs_still_learns():
    train, test = _data(n_train=800, n_test=200, noise=0.35)
    run = FederatedRun(MCFG, _fcfg(compress="topk:0.25", rounds=6), train,
                       test, "fim_lbfgs")
    hist = run.run(rounds=6, eval_every=6)
    assert np.isfinite(hist[-1]["loss"])
    assert hist[-1]["accuracy"] > 0.3, hist[-1]


# ----------------------------------------------------- edge/plan coupling
def test_codec_wire_bytes_shrink_edge_time_and_energy():
    """The whole point: the edge runtime must cost the *compressed* wire
    size — uplink seconds and joules scale with the codec, keeping plan,
    ledger, and channel in agreement."""
    from repro.edge import ChannelConfig, DeviceConfig, EdgeConfig

    def run_with(spec):
        edge = EdgeConfig(
            channel=ChannelConfig(bandwidth_hz=2e5, fading="none",
                                  server_rate_bps=1.5e6),
            device=DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=0.0))
        train, test = _data()
        run = FederatedRun(MCFG, _fcfg(compress=spec, edge=edge), train,
                           test, "fim_lbfgs")
        run.run(rounds=2, eval_every=2)
        return run

    base, quant = run_with("none"), run_with("int8")
    assert quant.plan.upload_bytes() == base.plan.upload_bytes() / 4
    assert quant.edge.summary()["wall_clock_s"] < base.edge.summary()["wall_clock_s"]
    assert quant.edge.summary()["energy_j"] < base.edge.summary()["energy_j"]


def test_scheduler_estimates_see_compressed_bytes():
    from repro.edge import ChannelConfig, DeviceConfig, EdgeConfig

    results = {}
    for spec in ("none", "randk:0.05"):
        edge = EdgeConfig(
            channel=ChannelConfig(bandwidth_hz=2e5, fading="none"),
            device=DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=0.0))
        train, test = _data()
        run = FederatedRun(MCFG, _fcfg(compress=spec, edge=edge), train,
                           test, "fedavg_sgd")
        run.sample_clients()
        results[spec] = run._edge_est.time_s.copy()
    assert (results["randk:0.05"] < results["none"]).all()


def test_simulator_from_strategy_threads_codec():
    """The vmapped cohort path compresses payloads inside the jitted
    round when given a key, at the strategy's own codec."""
    from repro.fed import simulator

    train, _ = _data()
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(train.x), size=(4, 32))
    cohort = {"x": jnp.asarray(train.x[idx]), "y": jnp.asarray(train.y[idx])}

    s = strategies.get("fim_lbfgs")(MCFG, _fcfg(compress="topk:0.1"), 10)
    step = simulator.from_strategy(s)
    p1, _, stats = step(s.params, s.opt_state, cohort, jnp.ones(4),
                        key=jax.random.PRNGKey(0))
    assert np.isfinite(float(stats["loss"]))
    # without a key the same step runs uncompressed (backward compatible)
    p2, _, stats2 = step(s.params, s.opt_state, cohort, jnp.ones(4))
    assert np.isfinite(float(stats2["loss"]))
    d1 = jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                                      p1, p2))
    assert max(d1) > 0  # compression actually changed the update


def test_simulator_with_edge_costs_codec_wire_bytes():
    from repro.edge import ChannelConfig, DeviceConfig, EdgeConfig
    from repro.edge.runtime import EdgeRuntime
    from repro.fed import simulator

    train, _ = _data()
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(train.x), size=(4, 32))
    cohort = {"x": jnp.asarray(train.x[idx]), "y": jnp.asarray(train.y[idx])}
    walls = {}
    for spec in ("none", "int8"):
        s = strategies.get("fim_lbfgs")(MCFG, _fcfg(compress=spec), 10)
        step = simulator.from_strategy(s)
        assert step.codec.spec() == spec  # the step advertises its codec
        edge = EdgeRuntime(EdgeConfig(
            channel=ChannelConfig(bandwidth_hz=2e5, fading="none",
                                  snr_db_std=0.0),
            device=DeviceConfig(flops_per_s_mean=2e9,
                                flops_per_s_sigma=0.0)), 8)
        # no compress= here: with_edge derives the wire format from the
        # step itself, so billed bytes can't desync from the round-trip
        estep = simulator.with_edge(step, edge, s.n_params())
        _, _, stats = estep(s.params, s.opt_state, cohort, jnp.ones(4),
                            key=jax.random.PRNGKey(1))
        walls[spec] = stats["wall_s"]
    assert walls["int8"] < walls["none"]
    # billed-compressed + actually-uncompressed must be impossible: a
    # compressing step demands the key that makes the round-trip real
    with pytest.raises(ValueError, match="bills compressed"):
        estep(s.params, s.opt_state, cohort, jnp.ones(4))
    # and an explicit wire format that differs from what the step
    # round-trips is rejected at wrap time (s is the int8 strategy here)
    with pytest.raises(ValueError, match="round-trips"):
        simulator.with_edge(step, edge, s.n_params(), compress="topk:0.1")
