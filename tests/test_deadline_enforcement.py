"""Runtime deadline enforcement (`Allocation.deadline_s` as a contract).

Property-based invariants (hypothesis, or the seeded fallback shim) over
random fleets/deadlines, end-to-end through ``FederatedRun``:

  (a) every client the runtime drops carries a non-empty reason,
  (b) ledger uplink bytes ≤ plan bytes, with equality iff no drops
      (truncated uploads are billed pro rata, payloads discarded whole),
  (c) the enforced barrier is min(deadline, max_k t_k): ≤ deadline + ε
      for every policy under a hard runtime deadline,
  (d) energy_opt allocations never exceed the bandwidth budget and every
      survivor meets the deadline.

Plus the edge cases the tentpole changes what "a round" means for: the
all-clients-dropped round (cohort=0, no server step — the PR-3
empty-cohort behavior extended), ``min_clients`` honored under an
infeasibly tight deadline (policy grants inf to forced keeps), the
predicted-vs-realized agreement between the ``deadline`` admission
policy and the runtime cutoff, and the acceptance benchmark claim:
energy_opt strictly beats uniform on total joules at equal bytes and
equal accuracy on the surviving cohort.
"""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # hermetic env: seeded deterministic fallback
    from tests._hypothesis_compat import given, settings
    from tests._hypothesis_compat import strategies as st

import jax

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.edge import ChannelConfig, DeviceConfig, EdgeConfig
from repro.edge.runtime import EdgeRuntime
from repro.fed.server import FederatedRun

MCFG = reduced(FMNIST_CNN)
UPLINK = ChannelConfig(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=3.0,
                       fading="rayleigh", server_rate_bps=50e6)
HETERO = DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=1.0)
# one model-sized dataset for the whole module: property examples vary
# seeds/deadlines, not shapes, so jit caches carry across examples
TRAIN, TEST = make_classification(MCFG, n_train=300, n_test=100, seed=0,
                                  noise=0.5)
# the deadline grid property examples index into — from "drops everyone"
# through "drops stragglers" to "binds nobody"
DEADLINES = [0.05, 0.3, 0.8, 1.5, 3.0, 10.0, 1e4]


def _run(policy="uniform", alg="fedavg_sgd", rounds=2, seed=0,
         num_clients=8, **edge_kw):
    edge = EdgeConfig(channel=UPLINK, device=HETERO, scheduler=policy,
                      **edge_kw)
    fcfg = FedConfig(num_clients=num_clients, participation=1.0,
                     local_epochs=1, batch_size=32, rounds=rounds,
                     noniid_l=2, seed=seed, edge=edge)
    run = FederatedRun(MCFG, fcfg, TRAIN, TEST, alg)
    hist = run.run(rounds=rounds, eval_every=rounds)
    return run, hist


def _expected_uplink(run):
    """Recompute the expected ledger from decisions + verdicts: per
    client, per phase, under its own codec, scaled by the fraction of
    the upload on the air before its cutoff."""
    star = 0.0
    for dec, ver in zip(run.edge.decisions, run.edge.verdicts, strict=True):
        frac = ({} if ver is None else
                {int(c): float(f)
                 for c, f in zip(ver.clients, ver.tx_frac, strict=True)})
        for ph in run.plan.phases:
            if not ph.up_floats:
                continue
            for i in dec.selected:
                wire = (dec.codec_for(i) or ph.codec).wire_bytes(ph.up_floats)
                star += wire * frac.get(int(i), 1.0)
    return star


# ---------------------------------------------------------------- properties
@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=0, max_value=len(DEADLINES) - 1))
def test_enforcement_invariants_random_fleets(seed, d_idx):
    """(a) reasons, (b) ledger ≤ plan with equality iff no drops, and
    plan == ledger for every landed client — under a hard runtime
    deadline on the uniform policy, over random fleet/channel seeds."""
    deadline = DEADLINES[d_idx]
    run, hist = _run("uniform", seed=seed, enforce_deadline_s=deadline)
    n_drops = 0
    for dec, ver in zip(run.edge.decisions, run.edge.verdicts, strict=True):
        n_drops += len(dec.dropped)
        for cid, why in dec.dropped.items():                       # (a)
            assert why and isinstance(why, str), (seed, deadline, cid)
            assert cid in dec.allocations, "dropped ⊆ allocated"
        if ver is not None:
            # a drop bills strictly less than the plan; a survivor bills
            # exactly the plan (tx_frac is the billing authority)
            for c, f, dr in zip(ver.clients, ver.tx_frac, ver.dropped, strict=True):
                assert (f < 1.0) == bool(dr), (seed, deadline, int(c))
    plan_bytes = sum(
        ph.wire_up_bytes() for ph in run.plan.phases if ph.up_floats) * sum(
        len(d.selected) for d in run.edge.decisions)
    assert run.ledger.up_star_bytes <= plan_bytes + 1e-6            # (b)
    if n_drops == 0:
        assert run.ledger.up_star_bytes == pytest.approx(plan_bytes)
    else:
        assert run.ledger.up_star_bytes < plan_bytes
    assert run.ledger.up_star_bytes == pytest.approx(_expected_uplink(run))


@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=len(DEADLINES) - 2))
def test_barrier_capped_for_all_policies(seed, d_idx):
    """(c) the sync barrier is min(deadline, max_k t_k): with a hard
    runtime deadline every round's client-completion barrier is ≤
    deadline + tolerance, for every bandwidth policy."""
    deadline = DEADLINES[d_idx]
    for policy in ("uniform", "bandwidth_opt", "energy_opt", "deadline"):
        run, hist = _run(policy, seed=seed, rounds=2,
                         enforce_deadline_s=deadline, deadline_s=deadline,
                         min_clients=1)
        for rec in run.edge.history:
            assert rec["barrier_s"] <= deadline + 1e-6, (policy, seed, rec)


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=0, max_value=len(DEADLINES) - 1),
       st.integers(min_value=3, max_value=16))
def test_energy_opt_budget_and_deadline_feasibility(seed, d_idx, n):
    """(d) runtime-level property (no model training): energy_opt never
    over-allocates the budget, every survivor it grants the deadline to
    finishes within it, and every exclusion carries a reason."""
    deadline = DEADLINES[d_idx]
    rt = EdgeRuntime(EdgeConfig(channel=UPLINK, device=HETERO,
                                scheduler="energy_opt", deadline_s=deadline,
                                min_clients=1, seed=seed), n, seed=seed)
    def wire(c):
        return (1.2e5, 0.0)
    selected, est, dec = rt.decide(n, np.arange(n), wire, 1e9)
    assert dec.total_bandwidth_hz() <= dec.budget_hz * (1 + 1e-9)
    assert all(a.bandwidth_hz > 0 for a in dec.allocations.values())
    for cid, why in dec.excluded.items():
        assert why and isinstance(why, str), (seed, deadline, cid)
    assert not set(dec.selected) & set(dec.excluded)
    ver = rt.verdicts[-1]
    for i, cid in enumerate(est.clients):
        grant = dec.allocations[int(cid)].deadline_s
        if math.isfinite(grant):
            assert est.time_s[i] <= grant + 1e-6, (seed, deadline, int(cid))
    # a granted (finite-deadline) client is never dropped at the barrier
    if ver is not None:
        for c, dr in zip(ver.clients, ver.dropped, strict=True):
            assert not (dr and math.isfinite(
                dec.allocations[int(c)].deadline_s)), (seed, deadline, int(c))


# ---------------------------------------------------------------- edge cases
def test_all_dropped_round_records_cohort_zero_no_server_step():
    """An infeasibly tight hard deadline drops the whole cohort: the
    round records cohort=0 with no loss and no server step (the PR-3
    empty-cohort contract), while the partial uploads are still billed
    and the clock advances to the deadline."""
    run, hist = _run("uniform", rounds=2, enforce_deadline_s=0.01)
    ref, _ = _run("uniform", rounds=0, enforce_deadline_s=0.01)
    for h in hist:
        assert h["cohort"] == 0
        assert "loss" not in h
        assert h["dropped"] > 0
        assert h["barrier_s"] <= 0.01 + 1e-6
    # no server step ever ran: params stayed at the init point
    same = jax.tree.map(lambda a, b: bool(np.array_equal(a, b)),
                        run.params, ref.params)
    assert all(jax.tree.leaves(same))
    # the partial uploads were billed (bytes on the air before cutoff)
    assert 0 < run.ledger.up_star_bytes


def test_min_clients_honored_under_infeasible_deadline():
    """The deadline POLICY under an infeasibly tight deadline force-
    keeps the fastest min_clients with no deadline grant (inf) — the
    runtime must not cut them off, so every round lands ≥ min_clients."""
    run, hist = _run("deadline", rounds=3, deadline_s=1e-3, min_clients=2)
    for h in hist:
        assert h["cohort"] >= 2, hist
    assert run.edge.deadline_dropped_total == 0
    for dec in run.edge.decisions:
        # forced keeps carry an inf grant; everyone else was excluded
        # a priori with a reason
        assert len(dec.allocations) == 2
        assert all(not math.isfinite(a.deadline_s)
                   for a in dec.allocations.values())
        assert dec.excluded and all(dec.excluded.values())


def test_energy_opt_min_clients_forced_keeps_survive():
    """energy_opt under an infeasible deadline: min_clients force-kept
    (inf grant), never dropped at the barrier, rest excluded with
    reasons."""
    run, hist = _run("energy_opt", rounds=2, deadline_s=1e-3, min_clients=3)
    assert run.edge.deadline_dropped_total == 0
    for h in hist:
        assert h["cohort"] >= 3
    for dec in run.edge.decisions:
        assert len(dec.allocations) == 3
        assert dec.excluded and all(dec.excluded.values())


# ------------------------------------------------- policy/runtime agreement
def test_deadline_policy_admission_never_dropped_at_barrier():
    """The satellite fix: DeadlinePolicy predicts under the nominal
    equal split, the runtime judges the realized finish at the granted
    width (≥ nominal) under the SAME channel draw — so with zero channel
    noise an admitted client is never dropped at the barrier.  The
    tolerance knob (EdgeConfig.deadline_tolerance_s) only absorbs float
    jitter between the two computations."""
    quiet = ChannelConfig(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=0.0,
                          fading="none", server_rate_bps=50e6)
    # slow, strongly heterogeneous compute so predicted finishes straddle
    # the deadline — some admitted, some excluded, every round
    slow = DeviceConfig(flops_per_s_mean=5e7, flops_per_s_sigma=1.5)
    edge = EdgeConfig(channel=quiet, device=slow, scheduler="deadline",
                      deadline_s=2.0, min_clients=1, seed=3)
    fcfg = FedConfig(num_clients=8, participation=1.0, local_epochs=1,
                     batch_size=32, rounds=4, noniid_l=2, seed=3, edge=edge)
    run = FederatedRun(MCFG, fcfg, TRAIN, TEST, "fedavg_sgd")
    run.run(rounds=4, eval_every=4)
    assert run.edge.deadline_dropped_total == 0
    saw_admitted = saw_excluded = False
    for dec in run.edge.decisions:
        assert not dec.dropped
        saw_excluded |= bool(dec.excluded)
        saw_admitted |= any(math.isfinite(a.deadline_s)
                            for a in dec.allocations.values())
    # the scenario must actually exercise both sides of the admission
    assert saw_admitted and saw_excluded


def test_tolerance_knob_threads_through():
    rt = EdgeRuntime(EdgeConfig(channel=UPLINK, device=HETERO,
                                deadline_tolerance_s=0.25,
                                enforce_deadline_s=1.0), 4)
    assert rt.cfg.deadline_tolerance_s == 0.25
    from repro.edge.events import enforce_deadlines
    v = enforce_deadlines([0, 1], [1.2, 1.3], [0.1, 0.1], 1.0,
                          tolerance_s=0.25)
    # 1.2 ≤ 1.0 + 0.25 admitted; 1.3 > 1.25 dropped, billed at the 1.0s
    # cutoff (tolerance widens admission, never billing)
    assert not v.dropped[0] and v.dropped[1]
    assert v.tx_frac[0] == 1.0
    assert v.tx_frac[1] == pytest.approx(0.9 / 1.2)
    assert v.reasons()[1]


# ---------------------------------------------- acceptance: energy_opt wins
def test_energy_opt_beats_uniform_on_joules_at_equal_bytes():
    """The acceptance invariant: with a loose (non-binding) deadline the
    three bandwidth-only policies land the same cohorts, the same
    CommLedger bytes, and the same accuracy (allocation never changes
    WHAT is learned) — but energy_opt's Σ joules is the constrained
    minimum: strictly below uniform on a heterogeneous fleet, and no
    worse than bandwidth_opt."""
    runs = {}
    for policy in ("uniform", "bandwidth_opt", "energy_opt"):
        runs[policy], hist = _run(policy, rounds=3, deadline_s=1e4,
                                  min_clients=1)
        runs[policy]._acc = hist[-1]["accuracy"]
    for f in ("down_bytes", "up_star_bytes", "up_tree_bytes",
              "scalar_bytes", "rounds"):
        assert (getattr(runs["uniform"].ledger, f)
                == getattr(runs["energy_opt"].ledger, f)
                == getattr(runs["bandwidth_opt"].ledger, f)), f
    assert runs["energy_opt"]._acc == pytest.approx(runs["uniform"]._acc)
    e = {p: r.edge.summary()["energy_j"] for p, r in runs.items()}
    assert e["energy_opt"] < e["uniform"], e
    assert e["energy_opt"] <= e["bandwidth_opt"] * (1 + 1e-9), e
    # nobody was dropped or excluded: equal cohorts by construction
    for r in runs.values():
        assert r.edge.summary()["deadline_dropped_total"] == 0
        assert all(not d.excluded for d in r.edge.decisions)


def test_enforced_drop_keeps_plan_ledger_for_landed_clients():
    """A runtime-enforced deadline round drops stragglers with reasons
    while plan == ledger holds for every landed client (the acceptance
    criterion, asserted per client through the verdict)."""
    run, _ = _run("uniform", rounds=3, seed=1, enforce_deadline_s=0.8)
    total_drops = sum(len(d.dropped) for d in run.edge.decisions)
    assert total_drops > 0, "scenario must actually drop stragglers"
    for dec in run.edge.decisions:
        for _cid, why in dec.dropped.items():
            assert why
    assert run.ledger.up_star_bytes == pytest.approx(_expected_uplink(run))
    # and per landed client the bill is exactly the plan's wire bytes
    for ver in run.edge.verdicts:
        if ver is None:
            continue
        np.testing.assert_array_equal(ver.tx_frac[~ver.dropped], 1.0)


def test_energy_opt_force_keeps_get_real_widths_not_slack_slivers():
    """Regression: a force-kept (infeasible) client must hold at least
    an equal-split-scale subchannel, like DeadlinePolicy's keeps — not
    the vanishing bisection slack left after feasible floors (a ~0 Hz
    width with an inf deadline would blow the barrier and Σ energy
    unboundedly)."""
    quiet = ChannelConfig(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=0.0,
                          fading="none", server_rate_bps=50e6)
    flat = DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=0.0)
    # uplink needs ~1.3s at the full 8e5 Hz budget per client, so a 2.0s
    # deadline is infeasible for 4 clients sharing it: every slot is
    # force-kept at the equal split (no deadline grants)
    rt = EdgeRuntime(EdgeConfig(channel=quiet, device=flat,
                                scheduler="energy_opt", deadline_s=2.0,
                                min_clients=1,
                                bandwidth_budget_hz=8e5), 4, seed=0)
    est, dec = rt.allocate_for(np.arange(4), lambda c: (1.2e6, 0.0), 1e9)
    share = dec.budget_hz / 4
    for a in dec.allocations.values():
        assert a.bandwidth_hz >= share * 0.99, dec.allocations
    # bounded barrier: the equal-split finish, not a 1e15-second sliver
    assert float(est.time_s.max()) < 1e3
    # and when the deadline IS feasible for the forced width, the grant
    # is re-derived from the width actually handed out
    rt2 = EdgeRuntime(EdgeConfig(channel=quiet, device=flat,
                                 scheduler="energy_opt", deadline_s=60.0,
                                 bandwidth_budget_hz=8e5), 4, seed=0)
    _, dec2 = rt2.allocate_for(np.arange(4), lambda c: (1.2e6, 0.0), 1e9)
    assert all(math.isfinite(a.deadline_s)
               for a in dec2.allocations.values())


# ------------------------------------------------------- async + simulator
def test_async_expiry_releases_spectrum_and_busy():
    """Async dispatches get per-client expiry events: a client past its
    deadline never lands in the buffer; once the clock passes its cutoff
    the granted subchannel returns to the pool and the device becomes
    selectable again."""
    run, hist = _run("uniform", rounds=5, mode="async", buffer_size=2,
                     enforce_deadline_s=1.0)
    s = run.edge.summary()
    assert s["deadline_dropped_total"] > 0
    # every hold belongs to a client that is either still uploading or
    # waiting out its expiry — never both released and held
    assert set(run.edge._held_hz) <= (run.edge.busy | set(run.edge._expiry))
    for _cl, t in run.edge._expiry.items():
        assert t > run.edge.clock.now  # pending expiries are in the future
    # conservation: every dispatched client either landed in a buffer,
    # is still in flight, or was dropped at its deadline — drops never
    # reach the aggregation buffer
    landed = sum(h.get("aggregated", 0) for h in hist)
    dispatched = sum(len(d.selected) for d in run.edge.decisions)
    assert (landed + s["in_flight"] + s["deadline_dropped_total"]
            == dispatched)


def test_async_underfilled_pop_does_not_chase_expiry_events():
    """Regression: when the aggregation buffer underfills (fewer
    completions in flight than buffer_size), draining it must not pop a
    dropped client's far-future expiry marker and drag the clock to its
    cutoff — a cut-off straggler never holds the round open."""
    rt = EdgeRuntime(EdgeConfig(channel=UPLINK, device=HETERO,
                                scheduler="uniform", mode="async",
                                buffer_size=4, enforce_deadline_s=60.0), 8,
                     seed=0)
    selected, est, dec = rt.decide(4, np.arange(8), lambda c: (1.2e6, 0.0),
                                   1e11)
    n_surv = len(selected) - len(dec.dropped)
    assert dec.dropped and n_surv > 0, \
        (dec.dropped, "scenario must mix survivors and drops")
    rt.dispatch_async(est, [32.0] * n_surv, [object()] * n_surv, 1e5)
    entries, _ = rt.pop_async_buffer()
    assert len(entries) == n_surv        # underfilled: only real arrivals
    # the clock stopped at the last completion, before the 60s cutoff
    assert rt.clock.now < 60.0
    assert all(t > rt.clock.now for t in rt._expiry.values())


def test_with_edge_masks_dropped_slots():
    """The vmapped path: a dropped cohort slot's weight is zeroed so the
    in-jit weighted_mean re-normalizes over the on-time partial cohort;
    the enforced barrier caps wall time."""
    import jax.numpy as jnp
    from repro.fed import simulator, strategies

    s = strategies.get("fim_lbfgs")(MCFG, FedConfig(num_clients=8, seed=0),
                                    10)
    step = simulator.from_strategy(s)
    edge = EdgeRuntime(EdgeConfig(channel=UPLINK, device=HETERO,
                                  enforce_deadline_s=2.0), 8)
    estep = simulator.with_edge(step, edge, s.n_params())
    rng = np.random.default_rng(0)
    idx = rng.integers(0, len(TRAIN.x), size=(6, 32))
    cohort = {"x": jnp.asarray(TRAIN.x[idx]), "y": jnp.asarray(TRAIN.y[idx])}
    new_params, _, stats = estep(s.params, s.opt_state, cohort, jnp.ones(6),
                                 clients=np.arange(6))
    dec = edge.decisions[-1]
    assert stats["barrier_s"] <= 2.0 + 1e-6
    assert stats["dropped"] == len(dec.dropped)
    if len(dec.dropped) == 6:
        same = jax.tree.map(lambda a, b: bool(np.array_equal(a, b)),
                            new_params, s.params)
        assert all(jax.tree.leaves(same))
    for _cid, why in dec.dropped.items():
        assert why
