"""Chunked-attention equivalence with the naive oracle, incl. GQA/windows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.phi4_mini import smoke_config
from repro.kernels import ref
from repro.models import attention


@pytest.mark.parametrize("variant,window", [("full", 0), ("sliding_window", 24)])
@pytest.mark.parametrize("q_chunk", [16, 64, 999])
def test_chunked_attention_matches_oracle(variant, window, q_chunk):
    cfg = smoke_config().replace(attn_variant=variant, window=window or 4096,
                                 attn_q_chunk=q_chunk, qk_norm=False)
    B, S = 2, 64
    hd, H, KV = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    pos = jnp.arange(S)
    out = attention._chunked_attention(q, k, v, cfg, pos, causal=True)
    ref_out = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=True, window=window if variant == "sliding_window" else 0,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-5, atol=2e-5)


def test_encoder_attention_is_symmetric_in_position():
    """Non-causal attention of a position-independent input (no rope effect
    checked here — just that masking doesn't leak -inf)."""
    cfg = smoke_config().replace(qk_norm=False)
    B, S = 1, 32
    hd, H, KV = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    q = jnp.ones((B, S, H, hd))
    k = jnp.ones((B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(0), (B, S, KV, hd))
    out = attention._chunked_attention(q, k, v, cfg, jnp.arange(S), causal=False)
    # uniform attention -> every position sees the same mean of v
    ref_mean = jnp.mean(v, axis=1, keepdims=True)
    got = out.reshape(B, S, KV, H // KV, hd).mean(axis=3)
    np.testing.assert_allclose(np.asarray(got), np.broadcast_to(
        np.asarray(ref_mean)[:, :1], got.shape).repeat(1, 0), rtol=1e-5, atol=1e-5)


def test_ring_buffer_decode_beyond_window():
    """Decode far past the window: ring must keep exactly the last W keys."""
    cfg = smoke_config().replace(attn_variant="sliding_window", window=4,
                                 qk_norm=False)
    B = 1
    p, _ = attention.attn_init(jax.random.PRNGKey(0), cfg)
    cache = attention.cache_init(cfg, B, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 12, cfg.d_model))
    outs = []
    for t in range(12):
        y, cache = attention.attn_decode(p, cfg, x[:, t:t + 1], cache)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    ref_out = attention.attn_apply(p, cfg, x)  # windowed full-seq oracle
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref_out),
                               rtol=1e-4, atol=1e-4)
