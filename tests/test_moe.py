"""MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.dbrx_132b import smoke_config
from repro.models import moe


def _cfg(**kw):
    return smoke_config().replace(**kw)


def test_single_expert_equals_dense_mlp():
    """E=1, top-1, ample capacity: MoE must equal the plain SwiGLU MLP with
    the same weights (the router is forced to the only expert)."""
    cfg = _cfg(num_experts=1, top_k=1, capacity_factor=4.0, moe_group=64)
    key = jax.random.PRNGKey(0)
    p, _ = moe.moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    out, (aux, dropped) = moe.moe_apply(p, cfg, x)

    dense = {"wi": p["wi"][0], "wg": p["wg"][0], "wo": p["wo"][0]}
    from repro.models.layers import mlp_apply
    ref = mlp_apply(dense, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(dropped) == 0.0
    assert abs(float(aux) - 1.0) < 1e-5  # E * (1) * (1) for a 1-expert router


def test_no_drops_with_ample_capacity_and_gates_normalized():
    cfg = _cfg(capacity_factor=8.0)
    p, _ = moe.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, cfg.d_model)) * 0.5
    out, (aux, dropped) = moe.moe_apply(p, cfg, x)
    assert float(dropped) == 0.0
    assert jnp.all(jnp.isfinite(out))
    assert float(aux) >= 1.0 - 1e-4  # Switch aux loss is minimized at 1


def test_capacity_drops_monotone():
    """Shrinking capacity can only increase the dropped fraction."""
    p, _ = moe.moe_init(jax.random.PRNGKey(0), _cfg())
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, _cfg().d_model))
    drops = []
    for cf in (4.0, 1.0, 0.25):
        _, (_, d) = moe.moe_apply(p, _cfg(capacity_factor=cf), x)
        drops.append(float(d))
    assert drops[0] <= drops[1] <= drops[2]
    assert drops[0] == 0.0


def test_group_size_does_not_change_routing_semantics():
    """Different dispatch group sizes pick the same experts (the capacity
    rounding differs, so compare with ample capacity)."""
    cfg_a = _cfg(capacity_factor=8.0, moe_group=64)
    cfg_b = _cfg(capacity_factor=8.0, moe_group=256)
    p, _ = moe.moe_init(jax.random.PRNGKey(0), cfg_a)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, cfg_a.d_model))
    out_a, _ = moe.moe_apply(p, cfg_a, x)
    out_b, _ = moe.moe_apply(p, cfg_b, x)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_b),
                               rtol=2e-4, atol=2e-4)
