"""Decode-vs-prefill equivalence: the serve path (KV cache / SSM recurrence /
ring buffer) must reproduce the training-path logits token by token."""
import importlib

import jax
import jax.numpy as jnp

from repro.models import hybrid, model, transformer


def _roundtrip(cfg, T, batch=1, seed=0):
    params, _ = model.init(cfg, jax.random.PRNGKey(seed))
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (batch, T), 0,
                              cfg.vocab_size)
    fwd = hybrid.forward if cfg.family == "hybrid" else transformer.forward
    hidden, _ = fwd(params, cfg, toks)
    ref = transformer.logits_fn(params, cfg, hidden)
    cache, _ = model.init_cache(cfg, batch=batch, context=T)
    step = jax.jit(lambda p, c, t: model.decode_fn(p, cfg, c, t))
    outs = []
    for t in range(T):
        lg, cache = step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    return jnp.stack(outs, axis=1), ref


def test_dense_gqa_decode_matches_prefill():
    cfg = importlib.import_module("repro.configs.phi4_mini").smoke_config()
    dec, ref = _roundtrip(cfg, T=24)
    assert float(jnp.max(jnp.abs(dec - ref))) < 1e-4


def test_qknorm_decode_matches_prefill():
    cfg = importlib.import_module("repro.configs.qwen3_32b").smoke_config()
    assert cfg.qk_norm
    dec, ref = _roundtrip(cfg, T=16)
    assert float(jnp.max(jnp.abs(dec - ref))) < 1e-4


def test_mamba2_ssd_duality():
    """Chunked SSD (training) == recurrent form (decode): Dao & Gu Thm 1."""
    cfg = importlib.import_module("repro.configs.mamba2_370m").smoke_config()
    dec, ref = _roundtrip(cfg, T=48, batch=2)  # 48 % chunk(32) != 0 path
    assert float(jnp.max(jnp.abs(dec - ref))) < 1e-3


def test_jamba_hybrid_decode():
    cfg = importlib.import_module("repro.configs.jamba_52b").smoke_config()
    cfg = cfg.replace(capacity_factor=8.0)  # avoid router drops in the oracle
    dec, ref = _roundtrip(cfg, T=32)
    assert float(jnp.max(jnp.abs(dec - ref))) < 1e-3


def test_sliding_window_decode_matches_windowed_prefill():
    cfg = importlib.import_module("repro.configs.granite_8b").smoke_config()
    cfg = cfg.replace(attn_variant="sliding_window", window=8)
    T = 24
    params, _ = model.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, T), 0, cfg.vocab_size)
    hidden, _ = transformer.forward(params, cfg, toks)
    ref = transformer.logits_fn(params, cfg, hidden)
    cache, _ = model.init_cache(cfg, batch=1, context=T)
    # ring buffer sized by window, not context
    assert jax.tree.leaves(cache.layer_cache)[0].shape[2] == 8
    step = jax.jit(lambda p, c, t: model.decode_fn(p, cfg, c, t))
    outs = []
    for t in range(T):
        lg, cache = step(params, cache, toks[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(dec - ref))) < 1e-4
