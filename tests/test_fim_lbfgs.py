"""Integration tests for Algorithm 1 (the paper's optimizer)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, fim, fim_lbfgs


def _quadratic(rng, d):
    A = rng.normal(size=(d, d))
    Q = jnp.asarray(A @ A.T / d + 0.5 * np.eye(d))
    b = jnp.asarray(rng.normal(size=d))
    def loss(p):
        return 0.5 * p["w"] @ Q @ p["w"] - b @ p["w"]
    wstar = jnp.linalg.solve(Q, b)
    return loss, Q, float(loss({"w": wstar}))


def test_converges_with_curvature_oracle():
    """With a consistent diagonal curvature, Alg. 1 ≈ preconditioned L-BFGS
    and crushes SGD on a quadratic (optimizer mechanics check)."""
    rng = np.random.default_rng(0)
    loss, Q, fstar = _quadratic(rng, 40)
    qdiag = {"w": jnp.diag(Q)}
    cfg = fim_lbfgs.FimLbfgsConfig(learning_rate=0.5, m=10, damping=1e-2, fim_ema=0.0)
    p = {"w": jnp.zeros(40)}
    st = fim_lbfgs.init(p, cfg)
    for _ in range(40):
        g = jax.grad(loss)(p)
        p, st, _ = fim_lbfgs.update(st, p, g, qdiag, cfg)
    gap_lbfgs = float(loss(p)) - fstar

    p = {"w": jnp.zeros(40)}
    st2 = baselines.sgd_init(p)
    for _ in range(40):
        g = jax.grad(loss)(p)
        p, st2, _ = baselines.sgd_update(st2, p, g, 0.05)
    gap_sgd = float(loss(p)) - fstar
    assert gap_lbfgs < 1e-3 * gap_sgd


def test_faster_than_sgd_on_logistic_regression():
    """The paper's setting: CE-type loss, per-example empirical Fisher.
    Rounds-to-threshold must beat one-step-per-round SGD (Table II claim)."""
    rng = np.random.default_rng(0)
    d, n = 30, 256
    X = jnp.asarray(rng.normal(size=(n, d)))
    wtrue = jnp.asarray(rng.normal(size=d))
    y = (jax.nn.sigmoid(X @ wtrue) > jnp.asarray(rng.uniform(size=n))).astype(jnp.float32)

    def loss(p, Xb=X, Yb=y):
        z = Xb @ p["w"]
        return jnp.mean(jnp.maximum(z, 0) - z * Yb + jnp.log1p(jnp.exp(-jnp.abs(z))))

    def per_ex(p, xb, yb):
        return loss(p, xb[None], yb[None])

    target = 0.4
    cfg = fim_lbfgs.FimLbfgsConfig(learning_rate=1.0, m=10, damping=1e-3,
                                   fim_ema=0.9, max_step_norm=1.0)
    p = {"w": jnp.zeros(d)}
    st = fim_lbfgs.init(p, cfg)
    r_lbfgs = 99
    for t in range(30):
        g = jax.grad(loss)(p)
        fd = fim.per_example_diag(per_ex, p, X, y)
        p, st, _ = fim_lbfgs.update(st, p, g, fd, cfg)
        if float(loss(p)) < target:
            r_lbfgs = t + 1
            break

    p = {"w": jnp.zeros(d)}
    st2 = baselines.sgd_init(p)
    r_sgd = 99
    for t in range(30):
        g = jax.grad(loss)(p)
        p, st2, _ = baselines.sgd_update(st2, p, g, 1.0)
        if float(loss(p)) < target:
            r_sgd = t + 1
            break
    assert r_lbfgs < r_sgd, (r_lbfgs, r_sgd)


def test_curvature_pair_skip():
    """A degenerate (zero-FIM, zero-damping) pair must not enter the history."""
    cfg = fim_lbfgs.FimLbfgsConfig(learning_rate=0.1, m=4, damping=0.0,
                                   rel_damping=0.0, curvature_eps=0.5)
    p = {"w": jnp.ones(3)}
    st = fim_lbfgs.init(p, cfg)
    g = {"w": jnp.asarray([1.0, 1.0, 1.0])}
    zero_fim = {"w": jnp.zeros(3)}
    _, st2, stats = fim_lbfgs.update(st, p, g, zero_fim, cfg)
    assert float(stats["pair_accepted"]) == 0.0
    assert int(st2.history.count) == 0


def test_trust_region_clips_step_norm():
    cfg = fim_lbfgs.FimLbfgsConfig(learning_rate=100.0, m=4, damping=1e-2,
                                   max_step_norm=0.5)
    p = {"w": jnp.zeros(8)}
    st = fim_lbfgs.init(p, cfg)
    g = {"w": jnp.full((8,), 3.0)}
    fd = {"w": jnp.ones(8)}
    _, _, stats = fim_lbfgs.update(st, p, g, fd, cfg)
    assert float(stats["step_norm"]) <= 0.5 + 1e-5
