"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,D", [(8, 256), (64, 1000), (256, 4096), (5, 131)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fim_diag_kernel(B, D, dtype):
    key = jax.random.PRNGKey(B * D)
    g = jax.random.normal(key, (B, D), dtype)
    old = jax.random.uniform(jax.random.PRNGKey(1), (D,), jnp.float32)
    out_k = ops.fim_diag_update(g, old, 0.9, force_kernel=True)
    out_r = ref.fim_diag_ref(g, old, 0.9)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,D", [(5, 512), (21, 4096), (21, 10_001), (9, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vlbfgs_gram_kernel(n, D, dtype):
    key = jax.random.PRNGKey(n + D)
    basis = jax.random.normal(key, (n, D), dtype)
    gk = np.asarray(ops.vlbfgs_gram(basis, force_kernel=True))
    gr = np.asarray(ref.vlbfgs_gram_ref(basis))
    scale = max(np.abs(gr).max(), 1.0)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(gk / scale, gr / scale, rtol=tol, atol=tol)


FLASH_CASES = [
    # B, H, KV, S, hd, causal, window
    (1, 4, 2, 256, 64, True, 0),
    (2, 8, 8, 128, 32, True, 0),    # MHA
    (1, 8, 1, 256, 64, True, 0),    # MQA
    (1, 4, 4, 256, 64, True, 96),   # sliding window
    (1, 2, 1, 128, 64, False, 0),   # encoder (non-causal)
]


@pytest.mark.parametrize("B,H,KV,S,hd,causal,window", FLASH_CASES)
def test_flash_attention_kernel(B, H, KV, S, hd, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
    out_k = ops.flash_attention(q, k, v, causal=causal, window=window,
                                force_kernel=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.bfloat16)
    out_k = ops.flash_attention(q, k, v, force_kernel=True).astype(jnp.float32)
    out_r = ref.flash_attention_ref(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=5e-2, atol=5e-2)


def test_gram_kernel_feeds_lbfgs_identically():
    """End-to-end: a direction computed from the kernel Gram equals the
    pure-jnp one (the optimizer consumes either interchangeably)."""
    from repro.core import lbfgs
    rng = np.random.default_rng(0)
    m, d = 4, 200
    params = {"w": jnp.zeros(d)}
    h = lbfgs.init(params, m)
    for _ in range(m):
        s = rng.normal(size=d)
        h = lbfgs.push(h, {"w": jnp.asarray(s)},
                       {"w": jnp.asarray(s * rng.uniform(0.5, 2, d))})
    g = {"w": jnp.asarray(rng.normal(size=d))}
    basis = jnp.concatenate([
        np.asarray(h.s["w"]), np.asarray(h.y["w"]), np.asarray(g["w"])[None]
    ], axis=0)
    M_kernel = ops.vlbfgs_gram(basis, force_kernel=True)
    M_ref = lbfgs.gram_matrix(h, g)
    np.testing.assert_allclose(np.asarray(M_kernel), np.asarray(M_ref),
                               rtol=1e-5, atol=1e-5)
