"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# (300, 3000) / (300, 5000): true multi-block tails on BOTH grid axes
# (B % B_BLK and D % D_BLK nonzero) — the tail-tile leak regression
@pytest.mark.parametrize("B,D", [(8, 256), (64, 1000), (256, 4096), (5, 131),
                                 (300, 3000), (300, 5000), (257, 2049)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fim_diag_kernel(B, D, dtype):
    key = jax.random.PRNGKey(B * D)
    g = jax.random.normal(key, (B, D), dtype)
    old = jax.random.uniform(jax.random.PRNGKey(1), (D,), jnp.float32)
    out_k = ops.fim_diag_update(g, old, 0.9, force_kernel=True)
    out_r = ref.fim_diag_ref(g, old, 0.9)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n,D", [(5, 512), (21, 4096), (21, 10_001), (9, 64),
                                 (9, 12_300)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vlbfgs_gram_kernel(n, D, dtype):
    key = jax.random.PRNGKey(n + D)
    basis = jax.random.normal(key, (n, D), dtype)
    gk = np.asarray(ops.vlbfgs_gram(basis, force_kernel=True))
    gr = np.asarray(ref.vlbfgs_gram_ref(basis))
    scale = max(np.abs(gr).max(), 1.0)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(gk / scale, gr / scale, rtol=tol, atol=tol)


FLASH_CASES = [
    # B, H, KV, S, hd, causal, window
    (1, 4, 2, 256, 64, True, 0),
    (2, 8, 8, 128, 32, True, 0),    # MHA
    (1, 8, 1, 256, 64, True, 0),    # MQA
    (1, 4, 4, 256, 64, True, 96),   # sliding window
    (1, 2, 1, 128, 64, False, 0),   # encoder (non-causal)
]


@pytest.mark.parametrize("B,H,KV,S,hd,causal,window", FLASH_CASES)
def test_flash_attention_kernel(B, H, KV, S, hd, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
    out_k = ops.flash_attention(q, k, v, causal=causal, window=window,
                                force_kernel=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.bfloat16)
    out_k = ops.flash_attention(q, k, v, force_kernel=True).astype(jnp.float32)
    out_r = ref.flash_attention_ref(q, k, v).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=5e-2, atol=5e-2)


# ------------------------------------------------------- codec kernels
@pytest.mark.parametrize("shape", [(7,), (1000,), (33, 129), (4096,),
                                   (300, 17)])
def test_int8_roundtrip_kernel_bit_identical_to_oracle(shape):
    """The fused int8 kernel and the jnp oracle consume the same uniform
    draws, so they must agree bit-for-bit (not allclose)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(int(np.prod(shape))))
    x = jax.random.normal(k1, shape) * 3.0
    u = jax.random.uniform(k2, shape)
    from repro.kernels import codec_ops
    scale = ref.int8_scale(x)
    out_k = codec_ops.int8_roundtrip(x, u, scale, interpret=True)
    out_r = ref.int8_roundtrip_ref(x, u, scale)
    assert out_k.shape == x.shape
    assert bool(jnp.all(out_k == out_r))


@pytest.mark.parametrize("n,k", [(8, 2), (35, 4), (1000, 100), (5000, 1),
                                 (2048, 2048), (1537, 700), (1024, 1)])
def test_topk_select_kernel_bit_identical_to_oracle(n, k):
    """Histogram + threshold-select kernel vs the jnp oracle: identical
    integer bucket logic, so keep masks match bit-for-bit and exactly k
    coordinates survive (the wire_bytes billing invariant)."""
    flat = jax.random.normal(jax.random.PRNGKey(n + k), (n,))
    from repro.kernels import codec_ops
    out_k = codec_ops.topk_select(flat, k, interpret=True)
    out_r = ref.topk_select_ref(flat, k)
    assert bool(jnp.all(out_k == out_r))
    assert int(jnp.sum(out_k != 0)) == k
    # magnitude correctness: every kept |x| dominates every dropped |x|
    # up to the radix tie band (< 1.5x by construction)
    absx = jnp.abs(flat)
    kept = out_k != 0
    mn_kept = float(jnp.min(jnp.where(kept, absx, jnp.inf)))
    mx_drop = float(jnp.max(jnp.where(kept, -jnp.inf, absx))) if k < n else 0.0
    assert mn_kept * 1.5 >= mx_drop


def test_topk_select_handles_threshold_ties():
    """Duplicate magnitudes on the threshold bucket break by index order
    — still exactly k kept, and kernel == oracle on the chosen set."""
    from repro.kernels import codec_ops
    flat = jnp.asarray([3.0, -1.0, 1.0, 1.0, -3.0, 1.0, 0.5, -1.0])
    for k in (1, 2, 3, 4, 5, 8):
        out_k = codec_ops.topk_select(flat, k, interpret=True)
        out_r = ref.topk_select_ref(flat, k)
        assert bool(jnp.all(out_k == out_r)), k
        assert int(jnp.sum(out_k != 0)) == k


def test_topk_select_matches_sort_semantics():
    """On distinct magnitudes the bucketed select must reproduce the
    exact jax.lax.top_k set whenever no two survivors share the
    threshold bucket — checked here with well-separated values."""
    vals = jnp.asarray([1.0, -8.0, 0.5, 3.0, -0.1, 0.2, 6.0, -2.0])
    got = ref.topk_select_ref(vals, 2)
    np.testing.assert_allclose(np.asarray(got),
                               [0.0, -8.0, 0, 0, 0, 0, 6.0, 0])


def test_ops_mode_dispatch():
    """mode knob semantics off-TPU: "off"/"auto" -> oracle, "on" ->
    interpret kernel; force_kernel stays an alias for "on"."""
    assert ops.resolve("off") == "oracle"
    assert ops.resolve("auto") == "oracle"  # CPU container
    assert ops.resolve("on") == "interpret"
    assert ops.resolve("auto", force_kernel=True) == "interpret"
    with pytest.raises(ValueError, match="kernels mode"):
        ops.resolve("sometimes")
    x = jax.random.normal(jax.random.PRNGKey(0), (257,))
    key = jax.random.PRNGKey(1)
    for mode in ("auto", "on", "off"):
        assert bool(jnp.all(ops.int8_roundtrip(x, key, mode=mode)
                            == ops.int8_roundtrip(x, key, mode="off")))
        assert bool(jnp.all(ops.topk_select(x, 31, mode=mode)
                            == ops.topk_select(x, 31, mode="off")))


def test_gram_kernel_feeds_lbfgs_identically():
    """End-to-end: a direction computed from the kernel Gram equals the
    pure-jnp one (the optimizer consumes either interchangeably)."""
    from repro.core import lbfgs
    rng = np.random.default_rng(0)
    m, d = 4, 200
    params = {"w": jnp.zeros(d)}
    h = lbfgs.init(params, m)
    for _ in range(m):
        s = rng.normal(size=d)
        h = lbfgs.push(h, {"w": jnp.asarray(s)},
                       {"w": jnp.asarray(s * rng.uniform(0.5, 2, d))})
    g = {"w": jnp.asarray(rng.normal(size=d))}
    basis = jnp.concatenate([
        np.asarray(h.s["w"]), np.asarray(h.y["w"]), np.asarray(g["w"])[None]
    ], axis=0)
    M_kernel = ops.vlbfgs_gram(basis, force_kernel=True)
    M_ref = lbfgs.gram_matrix(h, g)
    np.testing.assert_allclose(np.asarray(M_kernel), np.asarray(M_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------- convergence fingerprint
def test_fim_lbfgs_convergence_fingerprint_invariant_under_kernels():
    """Routing the client Fisher diagonal and the server Gram matrix
    through the Pallas ops must not move the optimizer's trajectory:
    kernels="on" (interpret kernels everywhere) and kernels="off" (the
    historical pure-jnp path) produce the same iterates to f32 tolerance
    on a deterministic quadratic."""
    from repro.core import fim_lbfgs
    from repro.fed import client as fed_client

    rng = np.random.default_rng(7)
    d = 300
    target = jnp.asarray(rng.normal(size=d).astype(np.float32))
    curv = jnp.asarray(rng.uniform(0.5, 2.0, size=d).astype(np.float32))

    def loss_fn(params, batch):
        r = (params["w"] - target) * batch["x"][:, None]
        return jnp.mean(jnp.sum(curv * r * r, axis=1))

    def per_example_loss(params, x, y):
        r = (params["w"] - target) * x
        return jnp.sum(curv * r * r)

    batch = {"x": jnp.ones((8,)), "y": jnp.zeros((8,), jnp.int32)}

    def run(kernels: str):
        grad_fim = fed_client.make_grad_fim_fn(
            loss_fn, per_example_loss, "per_example", kernels=kernels)
        cfg = fim_lbfgs.FimLbfgsConfig(learning_rate=0.3, m=4,
                                       kernels=kernels)
        params = {"w": jnp.zeros((d,), jnp.float32)}
        state = fim_lbfgs.init(params, cfg)
        losses = []
        for _ in range(8):
            g, diag, loss = grad_fim(params, batch)
            params, state, _ = fim_lbfgs.update(state, params, g, diag, cfg)
            losses.append(float(loss))
        return params, losses

    p_off, l_off = run("off")
    p_on, l_on = run("on")
    assert l_off[-1] < l_off[0]  # it actually converges
    np.testing.assert_allclose(l_on, l_off, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(p_on["w"]), np.asarray(p_off["w"]),
                               rtol=1e-4, atol=1e-5)
