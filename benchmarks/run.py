"""Benchmark driver: one module per paper table/figure + kernel microbench +
the roofline table (from existing dry-run artifacts).  Prints
``name,us_per_call,derived``-style CSVs and writes copies to experiments/.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only tableX]
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (hours); default is quick mode")
    ap.add_argument("--only", default=None, help="run a single benchmark")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (edge_tradeoff, fig4_hyperparams, kernels_bench,
                            roofline, table2_optimizers, table3_noniid,
                            table4_datasharing, table5_clients,
                            thm3_comm_cost)

    benches = {
        "table2": lambda: table2_optimizers.run(quick),
        "table3": lambda: table3_noniid.run(quick),
        "table4": lambda: table4_datasharing.run(quick),
        "table5": lambda: table5_clients.run(quick),
        "fig4": lambda: fig4_hyperparams.run(quick),
        "thm3": lambda: thm3_comm_cost.run(quick),
        "edge": lambda: edge_tradeoff.run(quick),
        "kernels": lambda: kernels_bench.run(quick),
        "roofline": roofline.run,
    }
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        fn()
        print(f"[{name}] done in {time.time()-t0:.1f}s\n")


if __name__ == "__main__":
    main()
