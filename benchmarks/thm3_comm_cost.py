"""Theorem 3 validation: measured communication per round and total bytes
to reach the target accuracy, for Algorithm 1 vs FedAvg — under both the
star topology (server link, O(k·d)) and in-network tree aggregation
(O(d·log τ), the reading under which Theorem 3's bound holds and the
analogue of the TPU all-reduce).  Also runs the beyond-paper int8
stochastic-rounding upload compression (related-work axis [27], [28]).
"""
from __future__ import annotations

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.fed.server import FederatedRun

from benchmarks.common import emit


def run(quick: bool = True):
    mcfg = reduced(FMNIST_CNN)
    train, test = make_classification(mcfg, n_train=1500, n_test=400,
                                      seed=0, noise=1.2)
    target = 0.55
    rounds_cap = 16 if quick else 40
    rows = []
    for alg, compress in (("fim_lbfgs", "none"), ("fim_lbfgs", "int8"),
                          ("fedavg_sgd", "none")):
        fcfg = FedConfig(num_clients=20, participation=0.25, local_epochs=1,
                         batch_size=10_000, rounds=rounds_cap, noniid_l=3,
                         learning_rate=0.05, compress=compress, seed=0)
        r = FederatedRun(mcfg, fcfg, train, test, alg)
        hist = r.run(rounds=rounds_cap, eval_every=4, target_accuracy=target)
        hits = [h["round"] for h in hist if h.get("accuracy", 0) >= target]
        rounds_to = hits[0] if hits else rounds_cap
        s = r.ledger.summary()
        rows.append([
            f"{alg}+{compress}" if compress != "none" else alg,
            rounds_to,
            round(s["up_star_MB_per_round"], 3),
            round(s["up_tree_MB_per_round"], 3),
            round(s["scalar_KB_per_round"], 3),
            round(s["up_star_MB_per_round"] * rounds_to, 2),
            round(s["up_tree_MB_per_round"] * rounds_to, 2),
        ])
    return emit(rows, ["scheme", "rounds_to_target", "up_star_MB_per_round",
                       "up_tree_MB_per_round", "gram_scalar_KB_per_round",
                       "total_star_MB", "total_tree_MB"],
                "thm3_comm_cost")


if __name__ == "__main__":
    run()
