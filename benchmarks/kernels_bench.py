"""Kernel micro-benchmarks.

On this CPU container the numbers time the pure-jnp reference paths (the
Pallas kernels execute only under interpret=True, whose timing is
meaningless); the derived column reports achieved GB/s or GFLOP/s so the
CPU baseline is comparable against the analytic v5e roofline targets.

The codec-encode rows pit the bucketed threshold-select (the fused
algorithm TopKCodec now ships) against the ``jax.lax.top_k`` global sort
it replaced — the sort survives *only here*, as the baseline — and the
fused one-pass int8 round-trip against the historical two-step
quantize/dequantize pair.

Every run is regression-compared against the committed
``BENCH_kernels.json`` snapshot *before* overwriting it: a row whose
median wall-time exceeds 2x its committed value fails the run (the CI
kernels-bench smoke lane turns this into a red build).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks.common import emit, emit_json, time_call  # noqa: E402

from repro.fed import codecs  # noqa: E402  (common inserts src/ on path)
from repro.kernels import ref  # noqa: E402

# wall-time may regress this much vs the committed snapshot before the
# run fails (headroom for machine-to-machine noise on CPU runners)
REGRESSION_FACTOR = 2.0

_SNAPSHOT = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_kernels.json")


def _topk_sort_baseline(flat, k: int):
    """The O(n log n) encode path this repo retired from TopKCodec._keep,
    kept only as the benchmark baseline for the bucketed select."""
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros(flat.shape, bool).at[idx].set(True)
    return jnp.where(mask, flat, jnp.zeros_like(flat))


def _load_snapshot():
    """-> {row_name: us_per_call} from the committed BENCH_kernels.json
    (empty when absent/unreadable — first run on a fresh clone)."""
    try:
        with open(_SNAPSHOT) as f:
            doc = json.load(f)
        return {r[0]: float(r[1]) for r in doc.get("rows", [])}
    except (OSError, ValueError, IndexError):
        return {}


def _check_regressions(rows, committed) -> list[str]:
    """-> human-readable failures for rows >REGRESSION_FACTOR x slower
    than their committed counterpart (new rows are skipped)."""
    failures = []
    for name, us, _ in rows:
        old = committed.get(name)
        if old is not None and float(us) > REGRESSION_FACTOR * old:
            failures.append(
                f"{name}: {us}us vs committed {old}us "
                f"(>{REGRESSION_FACTOR}x)")
    return failures


def run(smoke: bool = False):
    """Smoke mode keeps every row (names must match the committed
    snapshot for the regression guard to bite) but halves the timing
    iterations; row sizes are identical in both modes."""
    iters = 3 if smoke else 5
    rows = []
    key = jax.random.PRNGKey(0)

    # fim_diag: memory-bound; bytes = B*D*4 read + 2*D*4
    for B, D in [(256, 65536), (64, 262144)]:
        g = jax.random.normal(key, (B, D), jnp.float32)
        old = jnp.zeros((D,), jnp.float32)
        fn = jax.jit(lambda g, o: ref.fim_diag_ref(g, o, 0.9))
        us = time_call(fn, g, old, iters=iters)
        gbps = (B * D * 4 + 2 * D * 4) / (us * 1e-6) / 1e9
        rows.append([f"fim_diag_B{B}_D{D}", round(us, 1), f"{gbps:.2f}GB/s"])

    # vlbfgs gram: memory-bound over (2m+1)*D
    for n, D in [(21, 1_048_576)]:
        basis = jax.random.normal(key, (n, D), jnp.float32)
        fn = jax.jit(ref.vlbfgs_gram_ref)
        us = time_call(fn, basis, iters=iters)
        gbps = n * D * 4 / (us * 1e-6) / 1e9
        rows.append([f"vlbfgs_gram_n{n}_D{D}", round(us, 1), f"{gbps:.2f}GB/s"])

    # codec encode: bucketed threshold select (shipped) vs global sort
    # (retired baseline); 2 streaming passes vs an O(n log n) sort
    for D in [262_144, 1_048_576]:
        k = max(1, D // 100)  # the 1% sparsifier setting
        flat = jax.random.normal(jax.random.PRNGKey(D), (D,), jnp.float32)
        fused = jax.jit(lambda x, kk=k: ref.topk_select_ref(x, kk))
        baseline = jax.jit(lambda x, kk=k: _topk_sort_baseline(x, kk))
        us_f = time_call(fused, flat, iters=iters)
        us_s = time_call(baseline, flat, iters=iters)
        gbps = 2 * D * 4 / (us_f * 1e-6) / 1e9
        rows.append([f"topk_fused_D{D}", round(us_f, 1), f"{gbps:.2f}GB/s"])
        rows.append([f"topk_sort_D{D}", round(us_s, 1),
                     f"{us_s / us_f:.2f}x_fused"])

    # codec encode: fused int8 round-trip vs the two-step wire pair
    for D in [1_048_576]:
        x = jax.random.normal(jax.random.PRNGKey(D + 1), (D,), jnp.float32)
        u = jax.random.uniform(jax.random.PRNGKey(2), (D,))
        fused = jax.jit(ref.int8_roundtrip_ref)

        def unfused(tree, key):
            return codecs.dequantize_tree(*codecs.quantize_tree(tree, key))

        unfused_fn = jax.jit(unfused)
        us_f = time_call(fused, x, u, iters=iters)
        us_u = time_call(unfused_fn, {"w": x}, jax.random.PRNGKey(3),
                         iters=iters)
        gbps = 2 * D * 4 / (us_f * 1e-6) / 1e9
        rows.append([f"int8_fused_D{D}", round(us_f, 1), f"{gbps:.2f}GB/s"])
        rows.append([f"int8_unfused_D{D}", round(us_u, 1),
                     f"{us_u / us_f:.2f}x_fused"])

    # flash attention ref: compute-bound
    for B, H, KV, S, hd in [(1, 8, 2, 1024, 64)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
        fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
        us = time_call(fn, q, k, v, iters=iters)
        flops = 4 * B * H * S * S * hd
        rows.append([f"flash_ref_B{B}H{H}S{S}", round(us, 1),
                     f"{flops / (us * 1e-6) / 1e9:.2f}GFLOP/s"])

    # read the committed snapshot BEFORE emit_json overwrites it
    committed = _load_snapshot()
    failures = _check_regressions(rows, committed)

    header = ["name", "us_per_call", "derived"]
    emit_json("kernels", rows, header=header,
              meta={"mode": "smoke" if smoke else "full"})
    path = emit(rows, header, "kernels_bench")
    if failures:
        print("PERF REGRESSION vs committed BENCH_kernels.json:")
        for f in failures:
            print(f"  {f}")
        raise SystemExit(1)
    compared = sum(1 for r in rows if r[0] in committed)
    print(f"regression check: {compared}/{len(rows)} rows compared, "
          f"all within {REGRESSION_FACTOR}x of the committed snapshot")
    return path


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI lane: same rows, fewer timing iterations")
    args = ap.parse_args()
    sys.exit(0 if run(smoke=args.smoke) else 1)
