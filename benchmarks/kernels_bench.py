"""Kernel micro-benchmarks.

On this CPU container the numbers time the pure-jnp reference paths (the
Pallas kernels execute only under interpret=True, whose timing is
meaningless); the derived column reports achieved GB/s or GFLOP/s so the
CPU baseline is comparable against the analytic v5e roofline targets.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref

from benchmarks.common import emit, emit_json, time_call


def run(quick: bool = True):
    rows = []
    key = jax.random.PRNGKey(0)

    # fim_diag: memory-bound; bytes = B*D*4 read + 2*D*4
    for B, D in [(256, 65536), (64, 262144)]:
        g = jax.random.normal(key, (B, D), jnp.float32)
        old = jnp.zeros((D,), jnp.float32)
        fn = jax.jit(lambda g, o: ref.fim_diag_ref(g, o, 0.9))
        us = time_call(fn, g, old)
        gbps = (B * D * 4 + 2 * D * 4) / (us * 1e-6) / 1e9
        rows.append([f"fim_diag_B{B}_D{D}", round(us, 1), f"{gbps:.2f}GB/s"])

    # vlbfgs gram: memory-bound over (2m+1)*D
    for n, D in [(21, 1_048_576)]:
        basis = jax.random.normal(key, (n, D), jnp.float32)
        fn = jax.jit(ref.vlbfgs_gram_ref)
        us = time_call(fn, basis)
        gbps = n * D * 4 / (us * 1e-6) / 1e9
        rows.append([f"vlbfgs_gram_n{n}_D{D}", round(us, 1), f"{gbps:.2f}GB/s"])

    # flash attention ref: compute-bound
    for B, H, KV, S, hd in [(1, 8, 2, 1024, 64)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32)
        fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
        us = time_call(fn, q, k, v)
        flops = 4 * B * H * S * S * hd
        rows.append([f"flash_ref_B{B}H{H}S{S}", round(us, 1),
                     f"{flops / (us * 1e-6) / 1e9:.2f}GFLOP/s"])

    header = ["name", "us_per_call", "derived"]
    emit_json("kernels", rows, header=header, meta={"quick": bool(quick)})
    return emit(rows, header, "kernels_bench")


if __name__ == "__main__":
    run()
