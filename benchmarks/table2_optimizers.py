"""Table II reproduction: convergence rounds + accuracy per optimizer.

Protocol: one-update-per-communication-round for ALL methods (the DONE/
GIANT protocol this paper's optimizer comparison follows — each round is one
aggregation), on the three synthetic dataset stand-ins.  Reported: rounds to
the target accuracy and the final accuracy — the paper's claim is the
*ordering* (ours < FedDANE < first-order in rounds; slight accuracy gap),
not absolute values, since the real datasets are unavailable offline.
"""
from __future__ import annotations

from repro.configs.base import FedConfig
from repro.configs.paper_models import CNN_CONFIGS, reduced
from repro.data.synthetic import make_classification
from repro.fed.server import FederatedRun

from benchmarks.common import emit

ALGS = ["fim_lbfgs", "fedavg_sgd", "fedavg_adam", "feddane"]


def run(quick: bool = True):
    rows = []
    datasets = ["fmnist_cnn", "kws_cnn"] if quick else list(CNN_CONFIGS)
    rounds_cap = 20 if quick else 60
    for ds in datasets:
        mcfg = reduced(CNN_CONFIGS[ds]) if quick else CNN_CONFIGS[ds]
        train, test = make_classification(
            mcfg, n_train=1200 if quick else 4000,
            n_test=300 if quick else 1000, seed=0, noise=1.0)
        target = 0.55 if quick else 0.8
        for alg in ALGS:
            fcfg = FedConfig(
                num_clients=16 if quick else 100,
                participation=0.5 if quick else 0.2,
                local_epochs=1, batch_size=10_000,  # one-step protocol
                rounds=rounds_cap, noniid_l=0, learning_rate=0.05, seed=0)
            runner = FederatedRun(mcfg, fcfg, train, test, alg)
            hist = runner.run(rounds=rounds_cap, eval_every=2,
                              target_accuracy=target)
            hits = [h["round"] for h in hist if h.get("accuracy", 0) >= target]
            rounds_to = hits[0] if hits else f">{rounds_cap}"
            final = max(h.get("accuracy", 0.0) for h in hist)
            rows.append([ds, alg, rounds_to, round(final, 4)])
    return emit(rows, ["dataset", "optimizer", "rounds_to_target", "best_accuracy"],
                "table2_optimizers")


if __name__ == "__main__":
    run()
