"""Fig. 4 reproduction: FedOVA accuracy under varying local epochs E and
batch size B (convergence speeds up with more local gradient steps)."""
from __future__ import annotations

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.fed.server import FederatedRun

from benchmarks.common import emit


def run(quick: bool = True):
    mcfg = reduced(FMNIST_CNN) if quick else FMNIST_CNN
    train, test = make_classification(
        mcfg, n_train=1500 if quick else 4000, n_test=400, seed=0, noise=1.2)
    rows = []
    rounds = 6 if quick else 30
    base = dict(num_clients=16 if quick else 100,
                participation=0.25 if quick else 0.2, rounds=rounds,
                noniid_l=2, learning_rate=0.05, seed=0)
    for B in ((8, 32, 10_000) if quick else (15, 50, 100, 10_000)):
        fcfg = FedConfig(local_epochs=2, batch_size=B, **base)
        r = FederatedRun(mcfg, fcfg, train, test, "fedova")
        hist = r.run(rounds=rounds, eval_every=rounds // 2)
        rows.append([f"B={'inf' if B >= 10_000 else B}", "E=2",
                     round(max(h.get("accuracy", 0) for h in hist), 4)])
    for E in ((1, 3) if quick else (1, 3, 5)):
        fcfg = FedConfig(local_epochs=E, batch_size=16, **base)
        r = FederatedRun(mcfg, fcfg, train, test, "fedova")
        hist = r.run(rounds=rounds, eval_every=rounds // 2)
        rows.append(["B=16", f"E={E}",
                     round(max(h.get("accuracy", 0) for h in hist), 4)])
    return emit(rows, ["batch", "epochs", "accuracy"], "fig4_hyperparams")


if __name__ == "__main__":
    run()
