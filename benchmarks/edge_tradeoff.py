"""Edge trade-off sweep: time-to-accuracy and energy-to-accuracy under a
resource-constrained wireless uplink (repro.edge).

Part A — fim_lbfgs (Algorithm 1) vs fedavg_sgd under star and tree
topologies, sync and buffered-async aggregation, with and without int8
upload compression.  The wall-clock column is where Theorem 3's
communication claims become *time*: under in-network (tree) aggregation
Algorithm 1 pays O(d log τ) per round and needs fewer rounds, while
FedAvg's k distinct models keep the root link at O(k·d) per round.

Part B — allocation policies on a heterogeneous fleet (lognormal device
speeds): deadline-aware straggler dropping and capacity-proportional
selection vs the paper's uniform sampling.

Part C — the (codec × strategy) grid: every payload codec in
repro.fed.codecs (none / int8 / top-k / rand-k error-feedback
sparsification) against the summable strategies.  Metered uplink bytes,
simulated uplink wall-clock, and energy must all scale with the codec's
wire size, and the ledger's actuals equal the plan's prediction under
every codec — the grid checks both, mapping sparsity ratio to
time/energy-to-accuracy.

Part D — per-client bandwidth allocation (repro.edge.allocation): the
``bandwidth_opt`` policy (minimize the sync-round barrier max_k t_k by
bisection on the arXiv:1910.13067 capacity form) vs the uniform equal
split at EQUAL total bandwidth.  Bytes are identical by construction —
allocation changes who/when/how-fast, never what is counted — so the
whole win shows up as wall time.

Part E — energy-aware allocation: ``energy_opt`` (minimize Σ_k E_k
subject to every client finishing within the deadline — the dual of
bandwidth_opt) vs uniform vs bandwidth_opt at equal budget and a
non-binding deadline, so all three land the same cohorts, the same
bytes, and the same accuracy per round on the surviving cohort — the
whole win is Σ joules, asserted strictly below uniform (and never above
bandwidth_opt).

    PYTHONPATH=src python -m benchmarks.run --only edge
"""
from __future__ import annotations

from repro import obs
from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.edge import ChannelConfig, DeviceConfig, EdgeConfig
from repro.fed.server import FederatedRun

from benchmarks.common import emit, emit_json

# Constrained uplink: ~100 kB/s per subchannel and a ~190 kB/s shared
# server slice — a ~100 KB model update costs seconds and the cohort's
# payloads queue at the base station, so communication dominates the
# round (the FEEL regime the paper targets).
UPLINK = dict(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=3.0,
              fading="rayleigh", tx_power_w=0.5, downlink_rate_bps=20e6,
              server_rate_bps=1.5e6)
HETERO_FLEET = DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=1.0)


def _data(mcfg, quick):
    return make_classification(mcfg, n_train=1500, n_test=400, seed=0,
                               noise=1.2)


def _fcfg(rounds, compress="none", edge=None):
    return FedConfig(num_clients=20, participation=1.0, local_epochs=1,
                     batch_size=10_000, rounds=rounds, noniid_l=3,
                     learning_rate=0.05, compress=compress, seed=0, edge=edge)


def _to_target(run, rounds_cap, target):
    hist = run.run(rounds=rounds_cap, eval_every=1, target_accuracy=target)
    hits = [h for h in hist if h.get("accuracy", 0) >= target]
    last = hits[0] if hits else hist[-1]
    s = run.edge.summary()
    led = run.ledger.summary()
    t = last.get("sim_time_s", s["wall_clock_s"])
    e = last.get("energy_j", s["energy_j"])
    return {
        "rounds": last["round"] if hits else rounds_cap,
        "hit": bool(hits),
        "time_s": t,
        "energy_j": e,
        "up_star_MB": led["up_star_MB_per_round"] * last["round"],
        "up_tree_MB": led["up_tree_MB_per_round"] * last["round"],
    }


def _verdict_curve(tracer):
    """Per-round ``DeadlineVerdict`` history from a traced run: one row
    ``[round, landed, dropped, mean_cutoff_s]`` per round, where the
    realized cutoff is min(finish, deadline) per judged client (ROADMAP:
    "Surface DeadlineVerdict history in the time-to-accuracy curves").
    Rounds with no judged cohort carry a null cutoff."""
    cuts: dict = {}
    for e in tracer.events_named(obs.VERDICT):
        cut = (e.args["finish_s"] if e.args["deadline_s"] is None
               else min(e.args["finish_s"], e.args["deadline_s"]))
        cuts.setdefault(e.round_id, []).append(cut)
    curve = []
    for i, r in enumerate(tracer.records):
        c = cuts.get(i, [])
        curve.append([i, r["cohort"], r["dropped"],
                      round(sum(c) / len(c), 4) if c else None])
    return curve


def run(quick: bool = True):
    mcfg = reduced(FMNIST_CNN)
    train, test = _data(mcfg, quick)
    target = 0.55
    rounds_cap = 16 if quick else 40

    # ---- Part A: algorithm x topology x mode x compression -------------
    rows = []
    cases = [
        ("fim_lbfgs", "none", "star", "sync"),
        ("fim_lbfgs", "none", "tree", "sync"),
        ("fim_lbfgs", "int8", "star", "sync"),
        ("fim_lbfgs", "none", "star", "async"),
        ("fedavg_sgd", "none", "star", "sync"),
        ("fedavg_sgd", "none", "tree", "sync"),
        ("fedavg_sgd", "none", "star", "async"),
    ]
    if not quick:
        cases += [("fim_lbfgs", "int8", "tree", "sync"),
                  ("fedavg_adam", "none", "star", "sync"),
                  ("feddane", "none", "tree", "sync")]
    results = {}
    for alg, compress, topo, mode in cases:
        edge = EdgeConfig(
            channel=ChannelConfig(topology=topo, **UPLINK),
            device=HETERO_FLEET, mode=mode,
            # near-full buffer: cuts the straggler tail without starving
            # the (staleness-sensitive) second-order aggregation
            buffer_size=16 if mode == "async" else 0)
        run_ = FederatedRun(mcfg, _fcfg(rounds_cap, compress, edge),
                            train, test, alg)
        r = _to_target(run_, rounds_cap, target)
        results[(alg, compress, topo, mode)] = r
        rows.append([
            f"{alg}+{compress}" if compress != "none" else alg, topo, mode,
            r["rounds"] if r["hit"] else f">{rounds_cap}",
            round(r["time_s"], 1), round(r["energy_j"], 1),
            round(r["up_star_MB" if topo == "star" else "up_tree_MB"], 2),
        ])
    emit(rows, ["scheme", "topology", "mode", "rounds_to_acc55",
                "sim_time_s", "energy_J", "uplink_MB"], "edge_tradeoff")

    fim = results[("fim_lbfgs", "none", "tree", "sync")]
    avg = results[("fedavg_sgd", "none", "tree", "sync")]
    print(f"[edge] tree sync: fim_lbfgs {fim['time_s']:.1f}s "
          f"/ {fim['energy_j']:.1f}J vs fedavg_sgd {avg['time_s']:.1f}s "
          f"/ {avg['energy_j']:.1f}J to acc {target} -> "
          f"{'fim_lbfgs WINS' if fim['time_s'] < avg['time_s'] else 'fedavg wins'}")

    # ---- Part B: scheduler policies on a heterogeneous fleet -----------
    sched_rows = []
    policies = [("uniform", {}),
                ("deadline", {"deadline_s": 8.0, "min_clients": 4}),
                ("capacity_proportional", {})]
    if not quick:
        policies.append(("energy_threshold", {"battery_floor_j": 5.0}))
    for name, kw in policies:
        edge = EdgeConfig(
            channel=ChannelConfig(topology="star", **UPLINK),
            device=DeviceConfig(flops_per_s_mean=5e8, flops_per_s_sigma=1.5),
            scheduler=name, **kw)
        fcfg = FedConfig(num_clients=20, participation=0.5, local_epochs=1,
                         batch_size=10_000, rounds=rounds_cap, noniid_l=3,
                         learning_rate=0.05, seed=0, edge=edge)
        run_ = FederatedRun(mcfg, fcfg, train, test, "fedavg_sgd")
        r = _to_target(run_, rounds_cap, 0.5)
        s = run_.edge.summary()
        sched_rows.append([name, r["rounds"] if r["hit"] else f">{rounds_cap}",
                           round(r["time_s"], 1), round(r["energy_j"], 1),
                           s["dropped_total"]])
    emit(sched_rows, ["scheduler", "rounds_to_acc50", "sim_time_s",
                      "energy_J", "dropped"], "edge_schedulers")

    # ---- Part C: codec x strategy grid (wire size -> time/energy) ------
    codec_rows = run_codec_grid(mcfg, train, test, quick)

    # ---- Part D: bandwidth allocation at equal total budget ------------
    alloc_rows = run_bandwidth_sweep(mcfg, train, test, quick)

    # ---- Part E: energy-aware allocation under a deadline --------------
    energy_rows, energy_curves = run_energy_sweep(mcfg, train, test, quick)

    # ---- Part F: diurnal churn + mid-round re-allocation ---------------
    churn_rows, churn_curves = run_churn_sweep(mcfg, train, test, quick)

    # the tracked perf-trajectory snapshot: one machine-diffable JSON per
    # commit with every part's rows (CI archives it as BENCH_edge_tradeoff)
    emit_json("edge_tradeoff", rows,
              header=["scheme", "topology", "mode", "rounds_to_acc55",
                      "sim_time_s", "energy_J", "uplink_MB"],
              meta={"quick": bool(quick),
                    "schedulers": sched_rows, "codec_grid": codec_rows,
                    "bandwidth_opt": alloc_rows, "energy_opt": energy_rows,
                    "verdict_curves": energy_curves,
                    "churn_realloc": churn_rows,
                    "churn_curves": churn_curves})
    return (rows, sched_rows, codec_rows, alloc_rows, energy_rows,
            churn_rows)


def run_codec_grid(mcfg, train, test, quick: bool = True):
    """Fixed-round sweep over (codec × strategy): per-round uplink MB,
    simulated seconds and joules, each normalized against the uncoded
    run — all three must track the codec's wire ratio.  Also asserts the
    plan == ledger invariant under every codec."""
    codec_specs = ["none", "int8", "topk:0.25", "topk:0.1", "randk:0.1"]
    algs = ["fim_lbfgs", "fedavg_sgd"] + ([] if quick else ["fedprox"])
    rounds = 3 if quick else 8
    codec_rows = []
    for alg in algs:
        base = None
        for spec in codec_specs:
            edge = EdgeConfig(channel=ChannelConfig(topology="star", **UPLINK),
                              device=HETERO_FLEET)
            run_ = FederatedRun(mcfg, _fcfg(rounds, spec, edge),
                                train, test, alg)
            hist = run_.run(rounds=rounds, eval_every=rounds)
            cohorts = sum(h["cohort"] for h in hist)
            # the invariant the codecs PR exists to keep: metered actuals
            # == plan prediction, under every codec
            expect = run_.plan.upload_bytes() * cohorts
            assert abs(run_.ledger.up_star_bytes - expect) < 1e-6 * max(expect, 1), \
                (alg, spec, run_.ledger.up_star_bytes, expect)
            s = run_.edge.summary()
            led = run_.ledger.summary()
            row = {
                "up_MB_round": led["up_star_MB_per_round"],
                "time_s": s["wall_clock_s"] / rounds,
                "energy_j": s["energy_j"] / rounds,
                "acc": hist[-1].get("accuracy", float("nan")),
            }
            if base is None:
                base = row
            codec_rows.append([
                alg, spec,
                round(run_.plan.upload_bytes() / 1e3, 1),
                round(row["up_MB_round"], 3),
                round(row["up_MB_round"] / base["up_MB_round"], 3),
                round(row["time_s"], 1),
                round(row["time_s"] / base["time_s"], 3),
                round(row["energy_j"], 1),
                round(row["energy_j"] / base["energy_j"], 3),
                round(row["acc"], 3),
            ])
    emit(codec_rows, ["scheme", "codec", "plan_up_KB", "up_MB_per_round",
                      "bytes_ratio", "sim_s_per_round", "time_ratio",
                      "J_per_round", "energy_ratio", f"acc@r{rounds}"],
         "edge_codec_grid")
    return codec_rows


def run_bandwidth_sweep(mcfg, train, test, quick: bool = True):
    """Part D: ``bandwidth_opt`` vs the uniform equal split at equal
    total bandwidth (the shared round budget, identical seeds -> the
    same cohorts and channel draws).  The convex reallocation shifts
    subchannel width toward slow-compute/deep-fade clients, so the
    sync-round barrier max_k t_k — and therefore wall time for the same
    round count — shrinks, while CommLedger bytes are unchanged to the
    byte: allocation changes who/when/how-fast, never what is counted."""
    rounds = 4 if quick else 10
    algs = ["fim_lbfgs"] + ([] if quick else ["fedavg_sgd"])
    # fat server slice: the barrier is the per-client air time the
    # allocator can actually reshape, not the shared drain
    channel = ChannelConfig(topology="star", **{**UPLINK,
                                                "server_rate_bps": 50e6})
    alloc_rows = []
    for alg in algs:
        walls, led = {}, {}
        for policy in ("uniform", "bandwidth_opt"):
            edge = EdgeConfig(channel=channel,
                              device=DeviceConfig(flops_per_s_mean=5e8,
                                                  flops_per_s_sigma=1.5),
                              scheduler=policy)
            fcfg = FedConfig(num_clients=20, participation=0.5,
                             local_epochs=1, batch_size=10_000,
                             rounds=rounds, noniid_l=3, learning_rate=0.05,
                             seed=0, edge=edge)
            run_ = FederatedRun(mcfg, fcfg, train, test, alg)
            run_.run(rounds=rounds, eval_every=rounds)
            s = run_.edge.summary()
            walls[policy] = s["wall_clock_s"]
            led[policy] = run_.ledger.up_star_bytes
            budget = run_.edge.decisions[-1].budget_hz
            alloc_rows.append([alg, policy, round(budget / 1e6, 2),
                               round(s["wall_clock_s"] / rounds, 2),
                               round(s["energy_j"] / rounds, 1),
                               round(run_.ledger.up_star_bytes / 1e6, 3)])
        # the acceptance invariant: same bytes, strictly less wall time
        assert led["bandwidth_opt"] == led["uniform"], \
            (alg, led)
        assert walls["bandwidth_opt"] < walls["uniform"], (alg, walls)
        print(f"[edge D] {alg}: bandwidth_opt {walls['bandwidth_opt']:.1f}s "
              f"vs uniform {walls['uniform']:.1f}s for {rounds} rounds at "
              f"equal budget -> barrier x"
              f"{walls['uniform'] / walls['bandwidth_opt']:.2f} smaller, "
              "bytes identical")
    emit(alloc_rows, ["scheme", "policy", "budget_MHz", "sim_s_per_round",
                      "J_per_round", "uplink_MB_total"], "edge_bandwidth_opt")
    return alloc_rows


def run_energy_sweep(mcfg, train, test, quick: bool = True):
    """Part E: ``energy_opt`` vs uniform vs ``bandwidth_opt`` at equal
    total bandwidth and a loose (non-binding) deadline.  All three are
    bandwidth-only policies over the same uniform cohort at the same
    seed, so CommLedger bytes and accuracy-per-round are identical on
    the surviving cohort (nobody is excluded or dropped) — the KKT
    allocation W_k = max(W_min,k, √c_k/λ) spends the same budget where
    it buys the most air-time reduction, so Σ joules is the constrained
    minimum: strictly below the uniform split whenever the per-client
    costs c_k = bits/s_k are heterogeneous, and never above the
    barrier-minimizing bandwidth_opt point."""
    rounds = 3 if quick else 8
    algs = ["fedavg_sgd"] + ([] if quick else ["fim_lbfgs"])
    channel = ChannelConfig(topology="star", **{**UPLINK,
                                                "server_rate_bps": 50e6})
    energy_rows = []
    curves = {}
    for alg in algs:
        led, joules, acc = {}, {}, {}
        for policy in ("uniform", "bandwidth_opt", "energy_opt"):
            edge = EdgeConfig(channel=channel, device=HETERO_FLEET,
                              scheduler=policy, deadline_s=1e4,
                              min_clients=1)
            fcfg = FedConfig(num_clients=20, participation=0.5,
                             local_epochs=1, batch_size=10_000,
                             rounds=rounds, noniid_l=3, learning_rate=0.05,
                             seed=0, edge=edge)
            # trace the run: landed/dropped counts and realized cutoff
            # times come from the tracer's records + verdict events, not
            # re-derived from runtime internals
            tracer = obs.Tracer(sink=lambda line: None)
            run_ = FederatedRun(mcfg, fcfg, train, test, alg, tracer=tracer)
            hist = run_.run(rounds=rounds, eval_every=rounds)
            s = run_.edge.summary()
            assert s["deadline_dropped_total"] == 0 and \
                all(not d.excluded for d in run_.edge.decisions), \
                (alg, policy, "the deadline must not bind in Part E")
            tracer.audit.verify(run_.ledger)
            curves[f"{alg}/{policy}"] = _verdict_curve(tracer)
            landed = sum(r["cohort"] for r in tracer.records)
            dropped_n = sum(r["dropped"] for r in tracer.records)
            cuts = [min(e.args["finish_s"],
                        float("inf") if e.args["deadline_s"] is None
                        else e.args["deadline_s"])
                    for e in tracer.events_named(obs.VERDICT)]
            mean_cut = sum(cuts) / len(cuts) if cuts else float("nan")
            led[policy] = run_.ledger.up_star_bytes
            joules[policy] = s["energy_j"]
            acc[policy] = hist[-1].get("accuracy", float("nan"))
            energy_rows.append([alg, policy,
                                round(s["energy_j"] / rounds, 2),
                                round(s["wall_clock_s"] / rounds, 2),
                                round(run_.ledger.up_star_bytes / 1e6, 3),
                                round(acc[policy], 3),
                                round(landed / rounds, 2),
                                round(dropped_n / rounds, 2),
                                round(mean_cut, 3)])
        # equal bytes + equal accuracy on the surviving cohort ...
        assert led["energy_opt"] == led["uniform"] == led["bandwidth_opt"], \
            (alg, led)
        assert acc["energy_opt"] == acc["uniform"], (alg, acc)
        # ... and the acceptance invariant: strictly fewer joules
        assert joules["energy_opt"] < joules["uniform"], (alg, joules)
        assert joules["energy_opt"] <= joules["bandwidth_opt"] * (1 + 1e-9), \
            (alg, joules)
        print(f"[edge E] {alg}: energy_opt {joules['energy_opt']:.1f}J vs "
              f"uniform {joules['uniform']:.1f}J vs bandwidth_opt "
              f"{joules['bandwidth_opt']:.1f}J for {rounds} rounds at equal "
              f"bytes/accuracy -> "
              f"x{joules['uniform'] / joules['energy_opt']:.2f} less energy")
    emit(energy_rows, ["scheme", "policy", "J_per_round", "sim_s_per_round",
                       "uplink_MB_total", f"acc@r{rounds}",
                       "landed_per_round", "dropped_per_round",
                       "mean_cutoff_s"],
         "edge_energy_opt")
    return energy_rows, curves


def run_churn_sweep(mcfg, train, test, quick: bool = True):
    """Part F: time-to-accuracy under diurnal churn, with vs without
    mid-round re-allocation (``EdgeConfig.reallocate``).

    Both arms share seed, churn, and faults; the diurnal period is in
    *round* units so the availability draws cannot read the (diverging)
    clock — cohorts, drop sets, billed bytes, and the accuracy
    trajectory are then identical by construction, and the only
    difference is the realized barrier: a cut straggler's granted width
    re-lands on the survivors still on the air, so every fired round
    closes earlier.  The acceptance row: the same rounds-to-target at
    equal billed bytes, reached in strictly less simulated time."""
    rounds = 4 if quick else 10
    target = 0.45
    churn = ("diurnal:period=8,amp=0.5,base=0.6,unit=round|"
             "snr_burst:prob=0.5,scale=0.05")
    # channel-bound stragglers: tight compute spread, wide SNR spread —
    # the force-kept tail is on the air (not still computing) when the
    # freed spectrum arrives, which is where re-allocation pays
    channel = ChannelConfig(topology="star", bandwidth_hz=2e5,
                            snr_db_mean=8.0, snr_db_std=7.0,
                            fading="rayleigh", tx_power_w=0.5,
                            downlink_rate_bps=20e6, server_rate_bps=50e6)
    fleet = DeviceConfig(flops_per_s_mean=4e9, flops_per_s_sigma=0.3)
    churn_rows, curves, res = [], {}, {}
    for realloc in (False, True):
        edge = EdgeConfig(channel=channel, device=fleet,
                          scheduler="deadline", deadline_s=1.5,
                          min_clients=6, scenario=churn,
                          reallocate=realloc)
        fcfg = FedConfig(num_clients=20, participation=0.5,
                         local_epochs=1, batch_size=10_000, rounds=rounds,
                         noniid_l=3, learning_rate=0.05, seed=0, edge=edge)
        tracer = obs.Tracer(sink=lambda line: None)
        run_ = FederatedRun(mcfg, fcfg, train, test, "fedavg_sgd",
                            tracer=tracer)
        r = _to_target(run_, rounds, target)
        tracer.audit.verify(run_.ledger)
        s = run_.edge.summary()
        curve = _verdict_curve(tracer)
        key = "realloc" if realloc else "baseline"
        curves[key] = curve
        res[key] = (r, s, run_.ledger.up_star_bytes,
                    [row[1:3] for row in curve])
        churn_rows.append([
            key, r["rounds"] if r["hit"] else f">{rounds}",
            round(r["time_s"], 2), round(r["energy_j"], 1),
            round(run_.ledger.up_star_bytes / 1e6, 3),
            s["realloc_rounds"], s["deadline_dropped_total"],
            s["unavailable_total"]])
    (rb, _sb, led_b, hist_b) = res["baseline"]
    (rr, sr, led_r, hist_r) = res["realloc"]
    # equal billed bytes + identical landed/drop history per round ...
    assert led_b == led_r, (led_b, led_r)
    assert hist_b == hist_r, "churn must be clock-shift-invariant"
    assert (rb["rounds"], rb["hit"]) == (rr["rounds"], rr["hit"]), (rb, rr)
    # ... and the acceptance invariant: re-allocation fired, and the
    # same accuracy arrived strictly earlier on the simulated clock
    assert sr["realloc_rounds"] > 0, sr
    assert rr["time_s"] < rb["time_s"], (rr["time_s"], rb["time_s"])
    saved = 1.0 - rr["time_s"] / rb["time_s"]
    print(f"[edge F] diurnal churn: reallocate reaches acc {target} "
          f"(round {rr['rounds']}) in {rr['time_s']:.1f}s vs "
          f"{rb['time_s']:.1f}s without -> {saved:.0%} less simulated "
          f"time at equal billed bytes "
          f"({sr['realloc_rounds']} rounds re-allocated)")
    emit(churn_rows, ["mode", f"rounds_to_acc{int(target * 100)}",
                      "sim_time_s", "energy_J", "billed_MB",
                      "realloc_rounds", "deadline_dropped", "unavailable"],
         "edge_churn_realloc")
    return churn_rows, curves


if __name__ == "__main__":
    run()
