"""Roofline analysis (deliverable g): turns the dry-run artifacts into the
three-term roofline table of EXPERIMENTS.md §Roofline.

    compute    = HLO_FLOPs_per_chip / peak_FLOPs        (197 TF/s bf16, v5e)
    memory     = HLO_bytes_per_chip / HBM_bw            (819 GB/s)
    collective = collective_bytes_per_chip / link_bw    (~50 GB/s/link)

cost_analysis() on the SPMD-partitioned module is already per-chip;
collective bytes are parsed from the compiled HLO (launch/dryrun.py) and are
also per-chip.  MODEL_FLOPS uses 6·N_active·tokens for training and
2·N_active·tokens for inference; the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat/recompute and routing waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

from benchmarks.common import emit


def roofline_terms(rec: dict) -> dict:
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    hc = rec.get("hlo_cost")
    if hc:  # trip-count-aware analyzer (preferred; see repro/launch/hlo_cost)
        flops = hc["flops"]
        byts = hc["hbm_bytes"]
        coll = hc["collective_total"]
    else:  # legacy artifacts: XLA cost_analysis (undercounts scan bodies)
        flops = max(rec.get("flops", 0.0), 0.0)
        byts = max(rec.get("bytes_accessed", 0.0), 0.0)
        coll = rec.get("collectives", {}).get("total_bytes", 0.0)
    t_comp = flops / PEAK_FLOPS_BF16
    t_mem = byts / HBM_BW
    t_coll = coll / ICI_BW
    dominant = max((t_comp, "compute"), (t_mem, "memory"), (t_coll, "collective"))
    # model flops PER CHIP
    n_act = rec.get("n_active_params", 0)
    mult = 6 if rec["kind"] == "train" else 2
    model_flops = mult * n_act * rec.get("tokens", 0) / chips
    return {
        "compute_s": t_comp,
        "memory_s": t_mem,
        "collective_s": t_coll,
        "bottleneck": dominant[1],
        "model_flops": model_flops,
        "useful_ratio": (model_flops / flops) if flops else 0.0,
        "chips": chips,
    }


SUGGESTIONS = {
    ("compute", "train"): "remat recompute + causal-mask waste: flash kernel skips masked blocks; relax remat on small layers",
    ("compute", "prefill"): "causal-masked full-K scores burn 2x FLOPs; Pallas flash kernel skips upper-triangle blocks",
    ("compute", "decode"): "batched GEMV underutilizes MXU; fuse QKV projections and batch heads",
    ("memory", "train"): "optimizer+history traffic dominates: fuse the Gamma update (fim_diag kernel) and keep history bf16",
    ("memory", "prefill"): "KV/activation streaming bound; widen q-chunk to raise arithmetic intensity",
    ("memory", "decode"): "weight+KV streaming bound (expected for decode); shrink KV via window/quantization or raise batch",
    ("collective", "train"): "grad/Fisher all-reduce + ZeRO gathers: overlap with compute, reduce-scatter instead of all-reduce",
    ("collective", "prefill"): "TP all-reduces per layer: overlap or shift sharding toward data axis",
    ("collective", "decode"): "per-token TP all-reduces dominate tiny GEMVs: duplicate small weights, all-gather KV once",
}


def load(out_dir: str = "experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def run(out_dir: str = "experiments/dryrun"):
    rows = []
    md = ["| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
          "| bottleneck | MODEL_FLOPs/chip | useful ratio | next lever |",
          "|---|---|---|---|---|---|---|---|---|---|"]
    for rec in load(out_dir):
        if rec.get("status") == "skipped":
            rows.append([rec["arch"], rec["shape"], rec["mesh"], "skipped",
                         rec.get("reason", ""), "", "", "", ""])
            md.append(f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | — | — | — "
                      f"| skipped: {rec.get('reason','')} | — | — | — |")
            continue
        if rec.get("status") != "ok":
            rows.append([rec["arch"], rec["shape"], rec["mesh"], "error",
                         rec.get("error", "")[:60], "", "", "", ""])
            continue
        t = roofline_terms(rec)
        sugg = SUGGESTIONS.get((t["bottleneck"], rec["kind"]), "")
        rows.append([
            rec["arch"], rec["shape"], rec["mesh"],
            f"{t['compute_s']:.3e}", f"{t['memory_s']:.3e}",
            f"{t['collective_s']:.3e}", t["bottleneck"],
            f"{t['model_flops']:.3e}", f"{t['useful_ratio']:.3f}",
        ])
        md.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} "
            f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} "
            f"| {t['collective_s']:.2e} | **{t['bottleneck']}** "
            f"| {t['model_flops']:.2e} | {t['useful_ratio']:.2f} | {sugg} |")
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline.md", "w") as f:
        f.write("\n".join(md) + "\n")
    return emit(rows, ["arch", "shape", "mesh", "compute_s", "memory_s",
                       "collective_s", "bottleneck", "model_flops_chip",
                       "useful_ratio"], "roofline")


if __name__ == "__main__":
    run()
