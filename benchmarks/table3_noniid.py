"""Table III / Fig. 3 reproduction: FedOVA vs FedAvg across non-IID-l."""
from __future__ import annotations

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.fed.server import FederatedRun

from benchmarks.common import emit


def run(quick: bool = True):
    mcfg = reduced(FMNIST_CNN) if quick else FMNIST_CNN
    train, test = make_classification(
        mcfg, n_train=1500 if quick else 4000, n_test=400, seed=0, noise=1.4)
    rows = []
    rounds = 8 if quick else 40
    for ell in (2, 3, 5):
        for alg in ("fedavg_sgd", "fedova"):
            fcfg = FedConfig(num_clients=20 if quick else 100,
                             participation=0.25 if quick else 0.2,
                             local_epochs=2 if quick else 5,
                             batch_size=16, rounds=rounds, noniid_l=ell,
                             learning_rate=0.05, seed=0)
            runner = FederatedRun(mcfg, fcfg, train, test, alg)
            hist = runner.run(rounds=rounds, eval_every=rounds // 2)
            acc = max(h.get("accuracy", 0.0) for h in hist)
            rows.append([f"non-IID-{l}", alg, round(acc, 4)])
    return emit(rows, ["config", "scheme", "accuracy"], "table3_noniid")


if __name__ == "__main__":
    run()
