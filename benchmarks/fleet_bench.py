"""Fleet engine benchmark: the struct-of-arrays sync-round hot path at
10³–10⁶-client populations (repro.edge.fleet).

Part A — dict path vs fleet fast path at 10⁴ clients on the SAME config
and seed.  ``EdgeConfig.fleet`` only switches the implementation — the
decide → allocate → verdict → commit round is bit-identical (see
tests/test_determinism.py) — so the whole delta is wall time.  Full mode
asserts the fleet path is ≥ 10× faster per round; ``--smoke`` (the CI
lane) asserts a looser 5× plus an absolute per-round wall bound.

Part B — the ``FleetEngine`` jit backend (fused x64 lax kernels) swept
over population sizes, full participation, deadline enforcement on: in
full mode the top scale is a **10⁶-client round**.  The first round is
reported separately as compile+run; steady-state rounds are the metric.

Emits ``BENCH_fleet.json`` (benchmarks/common.emit_json) — the tracked
perf-trajectory artifact CI archives per commit.

    PYTHONPATH=src python benchmarks/fleet_bench.py [--smoke]
    PYTHONPATH=src python -m benchmarks.fleet_bench --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                ".."))

import numpy as np  # noqa: E402

from benchmarks.common import emit_json  # noqa: E402  (inserts src/ on path)

from repro.edge import (ChannelConfig, DeviceConfig,  # noqa: E402
                        EdgeConfig, EdgeRuntime, FleetEngine)

# the determinism-suite uplink/fleet, scaled to a shared server slice
# that keeps the drain term visible at mega-scale
UPLINK = ChannelConfig(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=3.0,
                       fading="rayleigh", server_rate_bps=50e6)
HETERO = DeviceConfig(flops_per_s_mean=2e9, flops_per_s_sigma=1.0)
UP_BYTES = 80_000.0     # ~a 10k-param grad+FIM payload at f32
DOWN_BYTES = 40_000.0
FLOPS = 1e9             # per-client local step


def _cfg(policy: str, fleet: str = "on", backend: str = "exact") -> EdgeConfig:
    # enforce cuts the lognormal compute tail (~a few % of the cohort),
    # not the equalized bandwidth_opt barrier itself
    return EdgeConfig(channel=UPLINK, device=HETERO, scheduler=policy,
                      deadline_s=5.0, min_clients=1, enforce_deadline_s=3.0,
                      fleet=fleet, fleet_backend=backend)


def _drive_dict(cfg: EdgeConfig, pop: int, k: int, rounds: int,
                seed: int = 0):
    """The per-client dict path: an EdgeRuntime with the fleet fast path
    forced off, driven round-by-round exactly as FleetEngine's exact
    backend drives its internal runtime."""
    rt = EdgeRuntime(dataclasses.replace(cfg, fleet="off"), pop, seed=seed)

    def wire(codec=None):
        return (UP_BYTES, 0.0)

    t0 = time.perf_counter()
    for _ in range(rounds):
        _, est, _ = rt.decide(k, np.arange(pop), wire, FLOPS, summable=True)
        rt.finish_round_sync(est, UP_BYTES, DOWN_BYTES, aggregatable=True)
    dt = time.perf_counter() - t0
    return dt / rounds, rt


def _drive_fleet(cfg: EdgeConfig, pop: int, k: int, rounds: int,
                 backend: str, seed: int = 0):
    eng = FleetEngine(cfg, pop, up_bytes=UP_BYTES, flops=FLOPS,
                      down_bytes=DOWN_BYTES, seed=seed, backend=backend)
    # round 0 separately: on the jit backend it includes XLA compilation
    t0 = time.perf_counter()
    eng.run_round(k)
    first_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(rounds - 1):
        eng.run_round(k)
    steady_s = ((time.perf_counter() - t0) / (rounds - 1)
                if rounds > 1 else first_s)
    return first_s, steady_s, eng


def run(smoke: bool = False):
    rows, header = [], ["part", "backend", "policy", "population", "cohort",
                       "rounds", "round_ms", "first_round_ms", "clock_s",
                       "energy_j", "dropped"]
    meta = {"mode": "smoke" if smoke else "full"}

    # ---- Part A: dict vs fleet at 10^4, same config + seed -------------
    pop_a, k_a = 10_000, 10_000
    rounds_a = 2 if smoke else 3
    policy = "bandwidth_opt"
    dict_s, rt_dict = _drive_dict(_cfg(policy), pop_a, k_a, rounds_a)
    _, fleet_s, eng = _drive_fleet(_cfg(policy), pop_a, k_a, rounds_a,
                                   backend="exact")
    speedup = dict_s / fleet_s
    rows.append(["A", "dict", policy, pop_a, k_a, rounds_a, dict_s * 1e3,
                 dict_s * 1e3, rt_dict.clock.now, rt_dict.energy_j,
                 rt_dict.dropped_total + rt_dict.deadline_dropped_total])
    rows.append(["A", "fleet_exact", policy, pop_a, k_a, rounds_a,
                 fleet_s * 1e3, fleet_s * 1e3, eng.clock_s, eng.energy_j,
                 eng.dropped_total + eng.deadline_dropped_total])
    meta["speedup_10k"] = speedup
    print(f"Part A: dict {dict_s*1e3:.1f} ms/round vs fleet "
          f"{fleet_s*1e3:.1f} ms/round -> {speedup:.1f}x")
    # both paths replay the same simulation — the speedup must be free
    assert np.isclose(rt_dict.clock.now, eng.clock_s, rtol=1e-12), \
        (rt_dict.clock.now, eng.clock_s)
    assert np.isclose(rt_dict.energy_j, eng.energy_j, rtol=1e-12), \
        (rt_dict.energy_j, eng.energy_j)
    floor = 5.0 if smoke else 10.0
    assert speedup >= floor, \
        f"fleet path only {speedup:.1f}x faster at n={pop_a} (need {floor}x)"
    if smoke:
        # the CI wall bound: a 10^4-client fleet round stays interactive
        assert fleet_s < 2.0, f"10^4 fleet round took {fleet_s:.2f}s"

    # ---- Part B: jit backend scale sweep (full participation) ----------
    # uniform split: finish times vary per client, so the deadline cuts
    # the lognormal compute tail — the partial-drop / capped-spend kernel
    # path runs at scale (bandwidth_opt's equalized barrier would make
    # the verdict all-or-nothing)
    pops = [1_000, 10_000] if smoke else [10_000, 100_000, 1_000_000]
    for pop in pops:
        rounds_b = 3 if smoke else 4
        first_s, steady_s, eng = _drive_fleet(_cfg("uniform"), pop, pop,
                                              rounds_b, backend="jit")
        rows.append(["B", "fleet_jit", "uniform", pop, pop, rounds_b,
                     steady_s * 1e3, first_s * 1e3, eng.clock_s,
                     eng.energy_j,
                     eng.dropped_total + eng.deadline_dropped_total])
        print(f"Part B: n={pop:>9,d}  first {first_s*1e3:8.1f} ms  "
              f"steady {steady_s*1e3:8.1f} ms/round  "
              f"dropped {eng.deadline_dropped_total}")
        assert len(eng.history) == rounds_b
        assert eng.clock_s > 0.0 and eng.energy_j > 0.0
    meta["max_population"] = pops[-1]

    emit_json("fleet", rows, header=header, meta=meta)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI lane: 10^4-client ceiling + wall-clock bound")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
