"""Shared benchmark utilities: CSV emission + timing."""
from __future__ import annotations

import os
import time


def emit(rows, header, name):
    """Print `name,us_per_call,derived` style CSV and save a copy under
    experiments/."""
    os.makedirs("experiments", exist_ok=True)
    path = os.path.join("experiments", f"{name}.csv")
    lines = [",".join(header)] + [",".join(str(v) for v in r) for r in rows]
    text = "\n".join(lines)
    print(f"--- {name} ---")
    print(text)
    with open(path, "w") as f:
        f.write(text + "\n")
    return path


def time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (CPU reference numbers)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
