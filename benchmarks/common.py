"""Shared benchmark utilities: CSV/JSON emission + timing."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.export import write_bench_json  # noqa: E402


def emit(rows, header, name):
    """Print `name,us_per_call,derived` style CSV and save a copy under
    experiments/."""
    os.makedirs("experiments", exist_ok=True)
    path = os.path.join("experiments", f"{name}.csv")
    lines = [",".join(header)] + [",".join(str(v) for v in r) for r in rows]
    text = "\n".join(lines)
    print(f"--- {name} ---")
    print(text)
    with open(path, "w") as f:
        f.write(text + "\n")
    return path


def emit_json(name, rows, header=None, meta=None):
    """Write the tracked perf-trajectory snapshot ``BENCH_<name>.json``
    at the repo root: {name, git_rev, timestamp, header, rows[, meta]}.
    Complements :func:`emit` (the CSV keeps its behavior); the JSON is
    the machine-diffable artifact CI archives per commit."""
    root = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")
    path = write_bench_json(name, rows, header=header, meta=meta, root=root)
    print(f"wrote {os.path.relpath(path)}")
    return path


def time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (CPU reference numbers)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
