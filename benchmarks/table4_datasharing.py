"""Table IV reproduction: FedOVA vs the data-sharing mechanism of Zhao et
al. [22] at sharing rates beta in {5%, 10%}.

Data sharing: the server holds a globally-shared dataset D_s (beta x local
size, sampled from the global distribution) that is appended to every
client's local data — trading privacy for IID-ness.  FedOVA shares nothing.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.fed.server import FederatedRun

from benchmarks.common import emit


class DataSharingRun(FederatedRun):
    """FedAvg + server-shared IID subset appended to each client's data."""

    def __init__(self, mcfg, fcfg, train, test, beta: float):
        super().__init__(mcfg, fcfg, train, test, "fedavg_sgd")
        rng = np.random.default_rng(123)
        avg_local = max(1, len(train.x) // fcfg.num_clients)
        n_share = max(1, int(beta * avg_local))
        self._share_idx = rng.choice(len(train.x), size=n_share, replace=False)

    def _client_data(self, k):
        xs, ys = super()._client_data(k)
        return (np.concatenate([xs, self.train.x[self._share_idx]]),
                np.concatenate([ys, self.train.y[self._share_idx]]))


def run(quick: bool = True):
    mcfg = reduced(FMNIST_CNN) if quick else FMNIST_CNN
    train, test = make_classification(
        mcfg, n_train=1500 if quick else 4000, n_test=400, seed=0, noise=1.2)
    rounds = 8 if quick else 40
    fcfg = FedConfig(num_clients=20 if quick else 100,
                     participation=0.25 if quick else 0.2,
                     local_epochs=2 if quick else 5, batch_size=16,
                     rounds=rounds, noniid_l=2, learning_rate=0.05, seed=0)
    rows = []
    for beta in (0.05, 0.10):
        r = DataSharingRun(mcfg, fcfg, train, test, beta)
        hist = r.run(rounds=rounds, eval_every=rounds // 2)
        rows.append([f"data_sharing_beta={int(beta*100)}%",
                     round(max(h.get("accuracy", 0) for h in hist), 4)])
    r = FederatedRun(mcfg, fcfg, train, test, "fedova")
    hist = r.run(rounds=rounds, eval_every=rounds // 2)
    rows.append(["fedova(no sharing)",
                 round(max(h.get("accuracy", 0) for h in hist), 4)])
    return emit(rows, ["scheme", "accuracy"], "table4_datasharing")


if __name__ == "__main__":
    run()
