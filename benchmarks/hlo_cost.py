"""Re-export: the trip-count-aware HLO cost analyzer lives in the library
(repro.launch.hlo_cost) so the dry run can embed its results in artifacts."""
from repro.launch.hlo_cost import analyze, parse_module  # noqa: F401
