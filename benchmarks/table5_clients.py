"""Table V reproduction: accuracy vs number of clients K (constant total
data, so more clients = fewer samples each)."""
from __future__ import annotations

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.fed.server import FederatedRun

from benchmarks.common import emit


def run(quick: bool = True):
    mcfg = reduced(FMNIST_CNN) if quick else FMNIST_CNN
    train, test = make_classification(
        mcfg, n_train=1600 if quick else 6000, n_test=400, seed=0, noise=1.2)
    rows = []
    rounds = 8 if quick else 40
    for K in ((16, 64) if quick else (100, 1000)):
        for alg in ("fedavg_sgd", "fedova"):
            fcfg = FedConfig(num_clients=K, participation=0.2,
                             local_epochs=2 if quick else 5, batch_size=8,
                             rounds=rounds, noniid_l=2, learning_rate=0.05,
                             seed=0)
            r = FederatedRun(mcfg, fcfg, train, test, alg)
            hist = r.run(rounds=rounds, eval_every=rounds // 2)
            rows.append([K, alg, round(max(h.get("accuracy", 0) for h in hist), 4)])
    return emit(rows, ["num_clients", "scheme", "accuracy"], "table5_clients")


if __name__ == "__main__":
    run()
