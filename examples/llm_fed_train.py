"""End-to-end driver: federated FIM-L-BFGS training of a ~100M-parameter
LLM (granite-8b family, reduced width/depth) on synthetic Zipf token data
for a few hundred steps on CPU — the llm-scale path of launch/train.py with
microbatch cohorts playing the client role.

    PYTHONPATH=src python examples/llm_fed_train.py [--steps 200]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs.granite_8b import CONFIG
from repro.data.synthetic import zipf_tokens
from repro.launch import train as trainlib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--full", action="store_true",
                    help="~100M-param variant (slow on 1 CPU: ~15s/step)")
    ap.add_argument("--ckpt", default="/tmp/repro_llm_ck.npz")
    args = ap.parse_args()

    # reduced member of the granite family (exact arch, scaled dims);
    # --full gives the ~100M-param variant of the same stack.
    if args.full:
        cfg = CONFIG.replace(
            name="granite-100m", num_layers=12, d_model=768, num_heads=12,
            num_kv_heads=4, head_dim=64, d_ff=2304, vocab_size=16384,
            dtype="float32", remat=False, attn_q_chunk=64, lbfgs_m=10,
            lbfgs_dtype="float32")
    else:
        cfg = CONFIG.replace(
            name="granite-12m", num_layers=6, d_model=384, num_heads=6,
            num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=4096,
            dtype="float32", remat=False, attn_q_chunk=64, lbfgs_m=10,
            lbfgs_dtype="float32")
    n_params_m = cfg.param_count() / 1e6
    print(f"arch {cfg.name}: {n_params_m:.1f}M params")

    ocfg = trainlib.opt_config(cfg, learning_rate=0.3)
    params, _, opt, _ = trainlib.init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(trainlib.make_train_step(cfg, ocfg, n_micro=2))

    data = zipf_tokens(512, args.seq + 1, cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for t in range(args.steps):
        idx = rng.integers(0, len(data), size=args.batch)
        batch = {"tokens": jnp.asarray(data[idx, :args.seq])}
        params, opt, stats = step(params, opt, batch)
        if (t + 1) % 20 == 0:
            print(f"step {t+1:4d} loss {float(stats['loss']):.4f} "
                  f"|g| {float(stats['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(t+1):.2f}s/step)")
    checkpoint.save(args.ckpt, params)
    print(f"saved checkpoint to {args.ckpt}")


if __name__ == "__main__":
    main()
