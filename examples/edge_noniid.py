"""Resource-constrained FEEL demo: the paper's optimizer under a wireless
edge with heterogeneous devices and non-IID-2 data (repro.edge).

Runs Algorithm 1 (fim_lbfgs) and FedAvg through the same constrained
uplink and prints simulated wall-clock and energy per round, then shows
what buffered-async aggregation, deadline scheduling, runtime-ENFORCED
deadlines (stragglers cut off at the barrier), and energy-optimal
bandwidth allocation buy when the fleet has stragglers.

    PYTHONPATH=src python examples/edge_noniid.py
"""
import dataclasses

from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.edge import ChannelConfig, DeviceConfig, EdgeConfig

CHANNEL = ChannelConfig(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=3.0,
                        fading="rayleigh", server_rate_bps=1.5e6,
                        topology="tree")
FLEET = DeviceConfig(flops_per_s_mean=1e9, flops_per_s_sigma=1.2)


def run_one(mcfg, train, test, alg, edge, rounds=8, compress="none"):
    from repro.fed.server import FederatedRun

    # second-order knobs pinned to the stabilized point (see
    # tests/test_fed_integration.py): partial cohorts make the aggregated
    # Fisher jump between rounds, so the Newton-type step needs the
    # tighter trust region
    fcfg = FedConfig(num_clients=16, participation=0.5, local_epochs=2,
                     batch_size=16, rounds=rounds, noniid_l=2,
                     learning_rate=0.05, seed=0, edge=edge,
                     compress=compress,
                     max_step_norm=0.5, fim_damping=0.05, fim_ema=0.9)
    run = FederatedRun(mcfg, fcfg, train, test, alg)
    hist = run.run(rounds=rounds, eval_every=2, verbose=True)
    s = run.edge.summary()
    best = max(h.get("accuracy", 0) for h in hist)
    print(f"   -> best acc {best:.3f} in {s['wall_clock_s']:.1f} simulated "
          f"seconds, {s['energy_j']:.1f} J, {s['dropped_total']} excluded, "
          f"{s['deadline_dropped_total']} cut off at the deadline\n")
    return best, s


def main():
    mcfg = reduced(FMNIST_CNN)
    train, test = make_classification(mcfg, n_train=1500, n_test=400,
                                      seed=0, noise=0.8)
    print("== Algorithm 1 (fim_lbfgs) vs FedAvg over a constrained uplink ==")
    results = {}
    for alg in ("fim_lbfgs", "fedavg_sgd"):
        print(f"-- {alg}, sync, tree aggregation --")
        results[alg] = run_one(mcfg, train, test, alg,
                               EdgeConfig(channel=CHANNEL, device=FLEET))

    print("-- fedavg_sgd, buffered async (stragglers land late, "
          "staleness-discounted) --")
    results["async"] = run_one(
        mcfg, train, test, "fedavg_sgd",
        EdgeConfig(channel=CHANNEL, device=FLEET, mode="async",
                   buffer_size=6, staleness_alpha=0.5))

    print("-- fim_lbfgs + int8 codec (4x fewer uplink bytes -> time/energy) --")
    results["int8"] = run_one(
        mcfg, train, test, "fim_lbfgs",
        EdgeConfig(channel=CHANNEL, device=FLEET), compress="int8")

    print("-- fim_lbfgs + rand-k 10% with error feedback (10x fewer bytes) --")
    results["randk"] = run_one(
        mcfg, train, test, "fim_lbfgs",
        EdgeConfig(channel=CHANNEL, device=FLEET), compress="randk:0.1")

    print("-- fedavg_sgd, deadline policy (drop predicted stragglers; "
          "survivors inherit their budget share) --")
    results["deadline"] = run_one(
        mcfg, train, test, "fedavg_sgd",
        EdgeConfig(channel=CHANNEL, device=FLEET, scheduler="deadline",
                   deadline_s=5.0, min_clients=3))

    # bandwidth_opt minimizes the STAR barrier max_k(t_comp,k + t_up,k);
    # under tree aggregation the wall is depth x the median hop, a
    # different objective (see ROADMAP: tree-aware allocation is open)
    star = dataclasses.replace(CHANNEL, topology="star")
    print("-- fim_lbfgs, star, bandwidth_opt vs uniform (same bytes, the "
          "sync barrier reshaped over the shared budget) --")
    results["star_uni"] = run_one(
        mcfg, train, test, "fim_lbfgs",
        EdgeConfig(channel=star, device=FLEET, scheduler="uniform"))
    results["bw_opt"] = run_one(
        mcfg, train, test, "fim_lbfgs",
        EdgeConfig(channel=star, device=FLEET, scheduler="bandwidth_opt"))

    print("-- fedavg_sgd, adaptive_codec (per-client top-k ratio from the "
          "sampled channel rate) --")
    results["adaptive"] = run_one(
        mcfg, train, test, "fedavg_sgd",
        EdgeConfig(channel=CHANNEL, device=FLEET, scheduler="adaptive_codec",
                   adaptive_ratio=0.25, adaptive_ratio_floor=0.05))

    print("-- fim_lbfgs, star, energy_opt (minimize sum energy s.t. the "
          "deadline; same bytes as uniform, fewer joules) --")
    results["energy_opt"] = run_one(
        mcfg, train, test, "fim_lbfgs",
        EdgeConfig(channel=star, device=FLEET, scheduler="energy_opt",
                   deadline_s=60.0, min_clients=2))

    print("-- fedavg_sgd, star, uniform + ENFORCED runtime deadline "
          "(stragglers cut off at the barrier: partial uploads billed, "
          "payloads discarded, the on-time cohort aggregated) --")
    results["enforced"] = run_one(
        mcfg, train, test, "fedavg_sgd",
        EdgeConfig(channel=star, device=FLEET, scheduler="uniform",
                   enforce_deadline_s=8.0))

    print("summary (best_acc, sim_seconds):")
    for name, (best, s) in results.items():
        print(f"  {name:12s} acc {best:.3f}  t {s['wall_clock_s']:8.1f}s  "
              f"E {s['energy_j']:7.1f}J")


if __name__ == "__main__":
    main()
