"""Resource-constrained FEEL demo: the paper's optimizer under a wireless
edge with heterogeneous devices and non-IID-2 data (repro.edge).

Runs Algorithm 1 (fim_lbfgs) and FedAvg through the same constrained
uplink and prints simulated wall-clock and energy per round, then shows
what buffered-async aggregation, deadline scheduling, runtime-ENFORCED
deadlines (stragglers cut off at the barrier), and energy-optimal
bandwidth allocation buy when the fleet has stragglers.

    PYTHONPATH=src python examples/edge_noniid.py
    # one named case, traced (Chrome trace + JSONL + metrics CSV):
    PYTHONPATH=src python examples/edge_noniid.py --only enforced \\
        --trace-out trace_enforced

Tracing attaches a ``repro.obs.Tracer`` to the run: round/client spans
on the simulated timeline, deadline verdicts, byte/energy metrics, and
the plan==ledger audit — exported as ``<trace-out>.json`` (load at
ui.perfetto.dev), ``<trace-out>.jsonl``, and ``<trace-out>_metrics.csv``.
"""
import argparse
import dataclasses

from repro import obs
from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.edge import ChannelConfig, DeviceConfig, EdgeConfig

CHANNEL = ChannelConfig(bandwidth_hz=2e5, snr_db_mean=10.0, snr_db_std=3.0,
                        fading="rayleigh", server_rate_bps=1.5e6,
                        topology="tree")
FLEET = DeviceConfig(flops_per_s_mean=1e9, flops_per_s_sigma=1.2)


def run_one(mcfg, train, test, alg, edge, rounds=8, compress="none",
            tracer=None):
    from repro.fed.server import FederatedRun

    # second-order knobs pinned to the stabilized point (see
    # tests/test_fed_integration.py): partial cohorts make the aggregated
    # Fisher jump between rounds, so the Newton-type step needs the
    # tighter trust region
    fcfg = FedConfig(num_clients=16, participation=0.5, local_epochs=2,
                     batch_size=16, rounds=rounds, noniid_l=2,
                     learning_rate=0.05, seed=0, edge=edge,
                     compress=compress,
                     max_step_norm=0.5, fim_damping=0.05, fim_ema=0.9)
    run = FederatedRun(mcfg, fcfg, train, test, alg, tracer=tracer)
    hist = run.run(rounds=rounds, eval_every=2, verbose=True)
    s = run.edge.summary()
    best = max(h.get("accuracy", 0) for h in hist)
    print(f"   -> best acc {best:.3f} in {s['wall_clock_s']:.1f} simulated "
          f"seconds, {s['energy_j']:.1f} J, {s['dropped_total']} excluded, "
          f"{s['deadline_dropped_total']} cut off at the deadline\n")
    if tracer is not None and tracer.enabled:
        tracer.audit.verify(run.ledger)
    return best, s


def demo_cases(mcfg, train, test, rounds):
    """name -> zero-arg callable running that demo case (lazy, so --only
    builds and runs exactly one)."""
    star = dataclasses.replace(CHANNEL, topology="star")

    def case(alg, edge, compress="none", tracer=None):
        return lambda tr=None: run_one(mcfg, train, test, alg, edge,
                                       rounds=rounds, compress=compress,
                                       tracer=tr)

    return {
        "fim_lbfgs": case("fim_lbfgs", EdgeConfig(channel=CHANNEL,
                                                  device=FLEET)),
        "fedavg_sgd": case("fedavg_sgd", EdgeConfig(channel=CHANNEL,
                                                    device=FLEET)),
        "async": case("fedavg_sgd",
                      EdgeConfig(channel=CHANNEL, device=FLEET, mode="async",
                                 buffer_size=6, staleness_alpha=0.5)),
        "int8": case("fim_lbfgs", EdgeConfig(channel=CHANNEL, device=FLEET),
                     compress="int8"),
        "randk": case("fim_lbfgs", EdgeConfig(channel=CHANNEL, device=FLEET),
                      compress="randk:0.1"),
        "deadline": case("fedavg_sgd",
                         EdgeConfig(channel=CHANNEL, device=FLEET,
                                    scheduler="deadline", deadline_s=5.0,
                                    min_clients=3)),
        # bandwidth_opt minimizes the STAR barrier max_k(t_comp,k+t_up,k);
        # under tree aggregation the wall is depth x the median hop, a
        # different objective (see ROADMAP: tree-aware allocation is open)
        "star_uni": case("fim_lbfgs",
                         EdgeConfig(channel=star, device=FLEET,
                                    scheduler="uniform")),
        "bw_opt": case("fim_lbfgs",
                       EdgeConfig(channel=star, device=FLEET,
                                  scheduler="bandwidth_opt")),
        "adaptive": case("fedavg_sgd",
                         EdgeConfig(channel=CHANNEL, device=FLEET,
                                    scheduler="adaptive_codec",
                                    adaptive_ratio=0.25,
                                    adaptive_ratio_floor=0.05)),
        "energy_opt": case("fim_lbfgs",
                           EdgeConfig(channel=star, device=FLEET,
                                      scheduler="energy_opt",
                                      deadline_s=60.0, min_clients=2)),
        "enforced": case("fedavg_sgd",
                         EdgeConfig(channel=star, device=FLEET,
                                    scheduler="uniform",
                                    enforce_deadline_s=8.0)),
        "churn": case("fedavg_sgd",
                      EdgeConfig(channel=star, device=FLEET,
                                 scheduler="deadline", deadline_s=6.0,
                                 min_clients=3,
                                 scenario=("diurnal:period=8,amp=0.4,"
                                           "base=0.7,unit=round|"
                                           "snr_burst:prob=0.3,scale=0.1"),
                                 reallocate=True)),
    }


BLURBS = {
    "fim_lbfgs": "Algorithm 1 (fim_lbfgs), sync, tree aggregation",
    "fedavg_sgd": "fedavg_sgd, sync, tree aggregation",
    "async": ("fedavg_sgd, buffered async (stragglers land late, "
              "staleness-discounted)"),
    "int8": "fim_lbfgs + int8 codec (4x fewer uplink bytes -> time/energy)",
    "randk": "fim_lbfgs + rand-k 10% with error feedback (10x fewer bytes)",
    "deadline": ("fedavg_sgd, deadline policy (drop predicted stragglers; "
                 "survivors inherit their budget share)"),
    "star_uni": "fim_lbfgs, star, uniform split baseline",
    "bw_opt": ("fim_lbfgs, star, bandwidth_opt (same bytes, the sync "
               "barrier reshaped over the shared budget)"),
    "adaptive": ("fedavg_sgd, adaptive_codec (per-client top-k ratio from "
                 "the sampled channel rate)"),
    "energy_opt": ("fim_lbfgs, star, energy_opt (minimize sum energy s.t. "
                   "the deadline; same bytes as uniform, fewer joules)"),
    "enforced": ("fedavg_sgd, star, uniform + ENFORCED runtime deadline "
                 "(stragglers cut off at the barrier: partial uploads "
                 "billed, payloads discarded, on-time cohort aggregated)"),
    "churn": ("fedavg_sgd, star, diurnal churn + SNR bursts "
              "(repro.edge.scenario) under the deadline policy, with "
              "mid-round re-allocation: a cut straggler's spectrum "
              "re-lands on the survivors still on the air"),
}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--only", default=None, metavar="CASE",
                    help="run one named demo case (default: all)")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--trace-out", default=None, metavar="PREFIX",
                    help="attach a Tracer and export <PREFIX>.json (Chrome "
                         "trace for Perfetto), <PREFIX>.jsonl, and "
                         "<PREFIX>_metrics.csv")
    args = ap.parse_args(argv)

    mcfg = reduced(FMNIST_CNN)
    train, test = make_classification(mcfg, n_train=1500, n_test=400,
                                      seed=0, noise=0.8)
    cases = demo_cases(mcfg, train, test, args.rounds)
    if args.only is not None and args.only not in cases:
        ap.error(f"unknown case {args.only!r}; known: {sorted(cases)}")
    names = [args.only] if args.only else list(cases)

    tracer = obs.Tracer() if args.trace_out else None
    print("== Algorithm 1 (fim_lbfgs) vs FedAvg over a constrained uplink ==")
    results = {}
    for name in names:
        print(f"-- {BLURBS[name]} --")
        results[name] = cases[name](tracer)

    if tracer is not None:
        chrome = obs.write_chrome(tracer, f"{args.trace_out}.json")
        jsonl = obs.write_jsonl(tracer, f"{args.trace_out}.jsonl")
        csv = obs.write_metrics_csv(tracer.metrics,
                                    f"{args.trace_out}_metrics.csv")
        print(f"trace: {chrome} (load at ui.perfetto.dev), {jsonl}, {csv}")

    print("summary (best_acc, sim_seconds):")
    for name, (best, s) in results.items():
        print(f"  {name:12s} acc {best:.3f}  t {s['wall_clock_s']:8.1f}s  "
              f"E {s['energy_j']:7.1f}J")


if __name__ == "__main__":
    main()
