"""FedOVA (Algorithm 2) vs FedAvg under pathological non-IID-2: each client
holds only two classes.  Reproduces the Fig. 3 behaviour on the synthetic
F-MNIST stand-in.

    PYTHONPATH=src python examples/fedova_noniid.py
"""
from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.fed.server import FederatedRun


def main():
    mcfg = reduced(FMNIST_CNN)
    train, test = make_classification(mcfg, n_train=1500, n_test=400,
                                      seed=0, noise=0.8)
    fcfg = FedConfig(num_clients=20, participation=0.25, local_epochs=2,
                     batch_size=16, rounds=8, noniid_l=2,
                     learning_rate=0.05, seed=0)
    results = {}
    for alg in ("fedavg_sgd", "fedova", "fedova_lbfgs"):
        run = FederatedRun(mcfg, fcfg, train, test, alg)
        print(f"== {alg} (each client sees only 2 of 10 classes) ==")
        hist = run.run(rounds=8, eval_every=4, verbose=True)
        results[alg] = max(h.get("accuracy", 0) for h in hist)
    print("\nbest accuracy:", results)


if __name__ == "__main__":
    main()
