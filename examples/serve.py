"""Serve a small model with batched decode requests through the serve_step
path (KV cache / SSM state), demonstrating the inference side of the
framework on any assigned architecture family.

    PYTHONPATH=src python examples/serve.py --arch mamba2-370m --tokens 32
"""
import argparse
import importlib
import time

import jax
import jax.numpy as jnp

from repro.models import model as zoo

ARCH_MODULES = {
    "granite-8b": "granite_8b", "mamba2-370m": "mamba2_370m",
    "jamba-v0.1-52b": "jamba_52b", "dbrx-132b": "dbrx_132b",
    "phi4-mini-3.8b": "phi4_mini",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m", choices=sorted(ARCH_MODULES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = importlib.import_module(
        f"repro.configs.{ARCH_MODULES[args.arch]}").smoke_config()
    params, _ = zoo.init(cfg, jax.random.PRNGKey(0))
    cache, _ = zoo.init_cache(cfg, batch=args.batch, context=args.tokens + 8)
    step = jax.jit(lambda p, c, t: zoo.decode_fn(p, cfg, c, t))

    tok = jnp.zeros((args.batch, 1), jnp.int32)
    t0 = time.time()
    out = []
    for _ in range(args.tokens):
        logits, cache = step(params, cache, tok)     # (B, 1, V)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # greedy (B, 1)
        out.append(tok[:, 0])
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"{args.arch} ({cfg.name}): generated {gen.shape} tokens in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s on CPU)")
    print("sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
