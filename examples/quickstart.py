"""Quickstart: train a federated classifier with the paper's FIM-L-BFGS
optimizer (Algorithm 1) and compare one round of accuracy against FedAvg.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.base import FedConfig
from repro.configs.paper_models import FMNIST_CNN, reduced
from repro.data.synthetic import make_classification
from repro.fed.server import FederatedRun


def main():
    mcfg = reduced(FMNIST_CNN)  # paper CNN family, reduced for CPU
    train, test = make_classification(mcfg, n_train=1500, n_test=400,
                                      seed=0, noise=1.2)
    fcfg = FedConfig(num_clients=20, participation=0.25, local_epochs=1,
                     batch_size=10_000, rounds=16, noniid_l=3,
                     learning_rate=0.05, seed=0)

    for alg in ("fim_lbfgs", "fedavg_sgd"):
        run = FederatedRun(mcfg, fcfg, train, test, alg)
        print(f"== {alg} ==")
        run.run(rounds=16, eval_every=4, verbose=True)


if __name__ == "__main__":
    main()
